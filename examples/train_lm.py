"""End-to-end training driver example: train a small LM, kill it, resume.

    PYTHONPATH=src python examples/train_lm.py

Runs a ~25M-parameter qwen-family model for a few hundred steps on CPU (the
full-size configs are exercised by the dry-run; this demonstrates the real
loop: data pipeline → jitted train step → async atomic checkpoints →
crash-resume).  Scale knobs are CLI flags of repro.launch.train; this wrapper
also simulates a mid-run failure and verifies the resume path.
"""
import shutil
import subprocess
import sys

CKPT = "/tmp/repro_example_train"


def run(extra):
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen2.5-32b", "--smoke",
           "--steps", "60", "--batch", "4", "--seq", "128",
           "--ckpt-dir", CKPT, "--ckpt-every", "20"] + extra
    print("+", " ".join(cmd))
    return subprocess.run(cmd, env={"PYTHONPATH": "src",
                                    "PATH": "/usr/bin:/bin"},
                          text=True)


def main() -> None:
    shutil.rmtree(CKPT, ignore_errors=True)
    # phase 1: train from scratch
    assert run([]).returncode == 0
    # phase 2: "crash" happened; resume from the last committed checkpoint
    assert run(["--resume", "--steps", "80"]).returncode == 0
    print("resume-after-crash drill passed")


if __name__ == "__main__":
    main()
