"""LeaFi as the retrieval layer for an LM backbone (serving example).

    PYTHONPATH=src python examples/retrieval_serving.py

This is the integration the DESIGN.md §Arch-applicability table describes:
the paper's technique does not live *inside* a transformer — it accelerates
the similarity-search substrate that serves it.  Here a (smoke-sized)
qwen-family backbone embeds a corpus of token sequences; a LeaFi-enhanced
index is built over the embeddings; then batched retrieval requests are
answered at a 99% recall target, with the learned filters pruning the
candidate leaves (kNN-LM / RAG-style serving).
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import build, filter_training
from repro.core.summaries import znormalize
from repro.models import transformer


def embed_corpus(cfg, params, tokens, batch=64):
    """Mean-pooled final hidden states as document embeddings."""
    outs = []
    fwd = jax.jit(lambda p, t: transformer.forward(cfg, p, {"tokens": t})[0])
    for i in range(0, len(tokens), batch):
        logits = fwd(params, tokens[i:i + batch])
        outs.append(np.asarray(logits.mean(axis=1)))   # (b, V) pooled
    emb = np.concatenate(outs)[:, :128]                # truncate for demo
    return znormalize(emb)


def main() -> None:
    cfg = configs.get_smoke("qwen2.5-32b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    print("embedding 6k documents with the backbone...")
    docs = jnp.asarray(rng.integers(0, cfg.vocab, (6000, 32)), jnp.int32)
    emb = embed_corpus(cfg, params, docs)

    print("building LeaFi index over embeddings (Alg. 1)...")
    lfi = build.build_leafi(emb, build.LeaFiConfig(
        backbone="dstree", leaf_capacity=96, n_global=200, n_local=60,
        t_filter_over_t_series=20.0,
        train=filter_training.TrainConfig(epochs=60)))

    print("serving batched retrieval requests...")
    q_docs = jnp.asarray(rng.integers(0, cfg.vocab, (32, 32)), jnp.int32)
    q_emb = embed_corpus(cfg, params, q_docs)

    t0 = time.perf_counter()
    res = lfi.search(q_emb, k=5, quality_target=0.99)
    t_leafi = time.perf_counter() - t0
    exact = lfi.search_exact(q_emb, k=5)
    recall1 = float((res.dists[:, 0] <= exact.dists[:, 0] * 1.00001 + 1e-6)
                    .mean())
    print(f"  32 requests, k=5: {t_leafi*1e3:.0f}ms, "
          f"searched {res.searched.mean():.1f} vs exact "
          f"{exact.searched.mean():.1f} leaves/query, recall@1 {recall1:.1%}")
    print("  top-5 doc ids for request 0:", res.ids[0].tolist())


if __name__ == "__main__":
    main()
