"""Quickstart: build a LeaFi-enhanced index and search it (paper Alg. 1+2).

    PYTHONPATH=src python examples/quickstart.py

Builds a DSTree-backed LeaFi index over a RandWalk collection, then answers
the same query set three ways: exact (filters off — always available), LeaFi
at a 99% recall target, and LeaFi at a 95% target, printing the
pruning/recall trade-off the paper's Figure 7/9 measures.
"""
import sys

sys.path.insert(0, "src")


from repro.core import build, filter_training
from repro.data.series import make_query_set, make_series_dataset


def main() -> None:
    print("generating 20k RandWalk series (len 128)...")
    series = make_series_dataset("randwalk", 20_000, 128, seed=0)

    config = build.LeaFiConfig(
        backbone="dstree",
        leaf_capacity=128,
        n_global=300, n_local=100,             # 3:1 split as in the paper
        t_filter_over_t_series=25.0,
        train=filter_training.TrainConfig(epochs=80),
    )
    print("building LeaFi-enhanced index (Alg. 1)...")
    lfi = build.build_leafi(series, config)
    rep = lfi.build_report
    print(f"  leaves={int(rep['n_leaves'])} filters={int(rep['n_filters'])} "
          f"collect={rep['t_collect']:.1f}s train={rep['t_train']:.1f}s "
          f"calibrate={rep['t_calibrate']:.1f}s")

    queries = make_query_set(series, 64, noise=0.2, seed=42)
    exact = lfi.search_exact(queries)
    print(f"\nexact search:       searched {exact.searched.mean():6.1f} "
          f"leaves/query, pruning {exact.pruning_ratio.mean():.1%}")

    for target in (0.99, 0.95):
        res = lfi.search(queries, quality_target=target)
        recall = float((res.dists[:, 0] <= exact.dists[:, 0] * 1.00001 + 1e-6)
                       .mean())
        speedup = exact.searched.mean() / max(res.searched.mean(), 1e-9)
        print(f"LeaFi @ {target:.0%} target: searched {res.searched.mean():6.1f} "
              f"leaves/query, pruning {res.pruning_ratio.mean():.1%}, "
              f"recall {recall:.1%}, {speedup:.1f}x fewer leaf scans")


if __name__ == "__main__":
    main()
