"""Comparison-approach simulators: semantics and orderings from the paper."""
import numpy as np
import pytest

from repro.core import baselines


@pytest.fixture(scope="module")
def sim_matrices():
    rng = np.random.default_rng(1)
    Q, L = 40, 80
    d_L = rng.uniform(1, 20, (Q, L)).astype(np.float32)
    d_lb = (d_L * rng.uniform(0.2, 0.95, (Q, L))).astype(np.float32)
    return d_lb, d_L


def test_exact_search_full_recall(sim_matrices):
    d_lb, d_L = sim_matrices
    res = baselines.exact_search(d_lb, d_L)
    assert res.recall.mean() == 1.0
    np.testing.assert_allclose(res.bsf, d_L.min(1))


def test_epsilon_prunes_more_recall_may_drop(sim_matrices):
    d_lb, d_L = sim_matrices
    r0 = baselines.exact_search(d_lb, d_L)
    r2 = baselines.epsilon_search(d_lb, d_L, epsilon=2.0)
    assert r2.searched.mean() <= r0.searched.mean()
    # ε-search guarantee: answer within (1+ε) of the true NN
    assert (r2.bsf <= d_L.min(1) * 3.0 + 1e-5).all()


def test_lr_optimal_reordering_dominates_exact(sim_matrices):
    d_lb, d_L = sim_matrices
    r0 = baselines.exact_search(d_lb, d_L)
    r1 = baselines.lr_optimal_search(d_lb, d_L)
    assert r1.recall.mean() == 1.0
    assert r1.searched.mean() <= r0.searched.mean() + 1e-9


def test_leafi_sim_with_oracle_filters_is_optimal(sim_matrices):
    """Perfect filters (d_F = d_L) ⇒ only leaves that improve bsf are
    searched — the paper's Figure 3 'optimal' curve."""
    d_lb, d_L = sim_matrices
    res = baselines.leafi_search(d_lb, d_L, d_F=d_L)
    assert res.recall.mean() == 1.0
    base = baselines.exact_search(d_lb, d_L)
    assert res.searched.mean() < base.searched.mean()


def test_delta_epsilon_stops_early(sim_matrices):
    d_lb, d_L = sim_matrices
    thr = float(np.quantile(d_L.min(1), 0.5))
    res = baselines.delta_epsilon_search(d_lb, d_L, thr)
    base = baselines.exact_search(d_lb, d_L)
    assert res.searched.mean() <= base.searched.mean()


def test_pros_and_lt_train_and_run(sim_matrices):
    d_lb, d_L = sim_matrices
    pros = baselines.train_pros(d_lb, d_L, checkpoints=(4, 8, 16))
    r = baselines.pros_search(d_lb, d_L, pros)
    assert 0.0 <= r.recall.mean() <= 1.0
    lt = baselines.train_lt(d_lb, d_L, checkpoints=(1, 2, 4))
    r2 = baselines.lt_search(d_lb, d_L, lt)
    assert r2.recall.mean() >= 0.5
