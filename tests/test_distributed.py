"""Distributed (shard_map) LeaFi search parity suite.

For each backbone this pins, on a 4-device host mesh:

  * the headline padding-leaf bugfix: shards deliberately carry extra
    padding leaf slots (size 0, (−inf, +inf) boxes), whose pre-fix lower
    bound of 0 let phase 1's argmin probe an empty leaf and waste the bsf
    seed — the probed global bsf (read out of the real shard body) must
    stay finite;
  * the tentpole: the fixed-width compact shard strategy
    (``engine.compact_bsf_cascade``) agrees with the masked-scan shard
    body — through a dual-strategy shard_map program that computes the
    pruning inputs once and runs both strategies on them, and through the
    production ``make_distributed_search`` wiring;
  * the overflow (survivors > capacity) → masked-scan fallback path and a
    shard containing only padding leaves, through the same dual body;
  * the accounting satellite: the psum'd ``total_searched`` return equals
    the sum of the per-shard single-device cascade counts — exactly within
    one program, and within a small cross-program slack against an eager
    single-device oracle;
  * the exact-search recall floor.

A note on assertion strength: the *bitwise* compact==scan contract (given
identical inputs, including borderline prune thresholds) is pinned
in-process in tests/test_engine.py, where both forms consume literally the
same arrays through the same per-op programs.  Inside fused XLA programs
that guarantee does not survive: the scan's slab-sliced distances and the
compaction's gathered distances may differ in the last ulp depending on
the surrounding fusion, a trained filter's prediction is ≈ the bsf *by
construction*, and iSAX leaves share quantized lb values — so a
`threshold > bsf` decision sitting within an ulp can legitimately flip
between compiled programs (observed on CPU for both trained and synthetic
filters).  The distributed assertions therefore check structure exactly
(accounting identity, finiteness) and floats/counts to tight tolerance —
real regressions (a probed padding leaf, a lost shard, a broken fallback)
move these by orders of magnitude more than an ulp tie does.

Runs in subprocesses so the placeholder host devices don't leak into the
rest of the suite.
"""
import subprocess
import sys

import pytest

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.core import build, distributed, engine, filter_training
from repro.core.summaries import znormalize

backbone = "%(backbone)s"
rng = np.random.default_rng(0)
S = rng.standard_normal((3000, 64), dtype=np.float32).cumsum(axis=1)
cfg = build.LeaFiConfig(backbone=backbone, leaf_capacity=64, n_global=120,
                        n_local=24, t_filter_over_t_series=10.0,
                        train=filter_training.TrainConfig(epochs=20))
lfi = build.build_leafi(S, cfg)
Q = znormalize(S[rng.integers(0, len(S), 16)]
               + 0.3 * rng.standard_normal((16, 64)).astype(np.float32))
Qj = jnp.asarray(Q)

mesh = distributed.make_search_mesh(2, 2)   # jax-version-guarded make_mesh
sharded = distributed.shard_leafi(lfi, n_shards=2, quality_target=0.99)

def pad_leaves(sh, extra):
    # deliberately unbalanced shards: every shard gains `extra` padding
    # leaf slots (size 0, (-inf, +inf) boxes) -- the probe-bug trigger
    def pad2(a, cv=0):
        w = [(0, 0), (0, extra)] + [(0, 0)] * (a.ndim - 2)
        return jnp.pad(a, w, constant_values=cv)
    return dataclasses.replace(
        sh, leaf_start=pad2(sh.leaf_start), leaf_size=pad2(sh.leaf_size),
        lb_lo=pad2(sh.lb_lo, -np.inf), lb_hi=pad2(sh.lb_hi, np.inf),
        w1=pad2(sh.w1), b1=pad2(sh.b1), w2=pad2(sh.w2), b2=pad2(sh.b2),
        y_mean=pad2(sh.y_mean), y_std=pad2(sh.y_std, 1.0),
        offsets=pad2(sh.offsets), has_filter=pad2(sh.has_filter, False))

sharded = pad_leaves(sharded, 3)

def synthetic_filters(sh):
    # zero the stacked MLPs and filter-prune a checkerboard of real leaves
    # via a huge bias: d_F is then -inf or ~1e30, so no *filter* decision
    # can sit within an ulp of the bsf (lb ties remain possible — see the
    # module docstring); exercises an aggressive, deterministic filter
    # cascade independent of training noise
    valid = np.asarray(sh.leaf_size) > 0
    prune = valid & ((np.indices(valid.shape).sum(0) %% 2) == 0)
    return dataclasses.replace(
        sh, w1=jnp.zeros_like(sh.w1), b1=jnp.zeros_like(sh.b1),
        w2=jnp.zeros_like(sh.w2),
        b2=jnp.asarray(np.where(prune, np.float32(1e30), 0.0)),
        y_mean=jnp.zeros_like(sh.y_mean), y_std=jnp.ones_like(sh.y_std),
        offsets=jnp.zeros_like(sh.offsets), has_filter=jnp.asarray(prune))

def blank_shard(sh):                # shard 1 becomes all padding leaves
    return dataclasses.replace(
        sh, leaf_size=sh.leaf_size.at[1].set(0),
        lb_lo=sh.lb_lo.at[1].set(-np.inf),
        lb_hi=sh.lb_hi.at[1].set(np.inf),
        has_filter=sh.has_filter.at[1].set(False))

synth = synthetic_filters(sharded)

def idx_args(sh):
    return (sh.series, sh.leaf_start, sh.leaf_size, sh.lb_lo, sh.lb_hi,
            sh.w1, sh.b1, sh.w2, sh.b2, sh.y_mean, sh.y_std,
            sh.offsets, sh.has_filter)

def dual_run(sh, max_survivors=None):
    # one shard_map program computing the pruning inputs once and running
    # BOTH phase-2 strategies on them: the only sound way to assert bitwise
    # scan==compact parity (see module docstring)
    max_leaf = sh.max_leaf
    def body(series, start, size, lo, hi, w1, b1, w2, b2, y_mean, y_std,
             offsets, has_filter, queries, qcoords):
        series, start, size = series[0], start[0], size[0]
        lb, d_F = distributed._shard_pruning_inputs(
            lo[0], hi[0], w1[0], b1[0], w2[0], b2[0], y_mean[0], y_std[0],
            offsets[0], has_filter[0], size, queries, qcoords)
        probe = engine.probe_best_leaf(series, start, size, lb, queries,
                                       max_leaf)
        bsf0 = jax.lax.pmin(probe, "model")
        bsf_s, ns_s = engine.masked_bsf_scan(series, start, size, lb, d_F,
                                             queries, max_leaf, bsf0)
        bsf_c, ns_c = engine.compact_bsf_cascade(
            series, start, size, lb, d_F, queries, max_leaf, bsf0,
            max_survivors=max_survivors)
        return (jax.lax.pmin(bsf_s, "model")[None],
                jax.lax.psum(ns_s, "model")[None],
                jax.lax.pmin(bsf_c, "model")[None],
                jax.lax.psum(ns_c, "model")[None],
                ns_s[None], bsf0[None])
    smapped = shard_map(
        body, mesh=mesh,
        in_specs=(P("model"),) * 13 + (P(("data",)), P(("data",))),
        out_specs=(P("model", "data"),) * 6, check_rep=False)
    out = jax.jit(smapped)(*idx_args(sh), Qj, sh.query_coords(Qj))
    nn_s, tot_s, nn_c, tot_c, ns_shard, bsf0 = map(np.asarray, out)
    return nn_s[0], tot_s[0], nn_c[0], tot_c[0], ns_shard, bsf0[0]

def oracle(sh):
    # the two-phase exchange, replayed eagerly with the single-device
    # engine pieces (cross-program: compare with tolerance only)
    qc = sh.query_coords(Qj)
    n_sh = sh.leaf_size.shape[0]
    lbs, dFs, probes = [], [], []
    for s in range(n_sh):
        lb, d_F = distributed._shard_pruning_inputs(
            sh.lb_lo[s], sh.lb_hi[s], sh.w1[s], sh.b1[s], sh.w2[s],
            sh.b2[s], sh.y_mean[s], sh.y_std[s], sh.offsets[s],
            sh.has_filter[s], sh.leaf_size[s], Qj, qc)
        lbs.append(lb); dFs.append(d_F)
        probes.append(engine.probe_best_leaf(
            sh.series[s], sh.leaf_start[s], sh.leaf_size[s], lb, Qj,
            sh.max_leaf))
    bsf0 = jnp.stack(probes).min(0)
    bsfs, ns = [], []
    for s in range(n_sh):
        b, n = engine.masked_bsf_scan(
            sh.series[s], sh.leaf_start[s], sh.leaf_size[s], lbs[s],
            dFs[s], Qj, sh.max_leaf, bsf0)
        bsfs.append(b); ns.append(n)
    return (np.asarray(jnp.stack(bsfs).min(0)),
            np.asarray(jnp.stack(ns).sum(0)), np.asarray(bsf0))

def dist_run(sh, **kw):
    run, *_ = distributed.make_distributed_search(mesh, sh, **kw)
    with mesh:
        nn, total = run(Qj)
    return np.asarray(nn), np.asarray(total)

SLACK = 8      # cross-program searched-count slack (ulp-tied prune flips)

# --- dual-body pins: trained, synthetic, blank, overflow -------------------
for name, sh in (("trained", sharded), ("synthetic", synth),
                 ("blank-shard", blank_shard(synth))):
    ref_nn, ref_tot, _ = oracle(sh)
    for cap in (None, 1):          # default capacity; capacity-1 = overflow
        nn_s, tot_s, nn_c, tot_c, ns_shard, bsf0 = dual_run(
            sh, max_survivors=cap)
        tag = (name, cap)
        # headline regression: the probed global bsf is finite even though
        # every shard carries padding leaves (pre-fix: +inf on such shards)
        assert np.isfinite(bsf0).all(), (tag, bsf0)
        # accounting: psum total == sum of per-shard cascade counts, exact
        np.testing.assert_array_equal(tot_s, ns_shard.sum(0),
                                      err_msg=str(tag))
        assert np.isfinite(nn_s).all(), tag
        # tentpole: compact agrees with the masked-scan body (shared
        # pruning inputs; tolerance per the module docstring)
        np.testing.assert_allclose(nn_c, nn_s, rtol=2e-6, err_msg=str(tag))
        assert np.abs(tot_c.astype(int)
                      - tot_s.astype(int)).max() <= SLACK, (tag, tot_c,
                                                            tot_s)
        # cross-program: the eager single-device oracle agrees
        np.testing.assert_allclose(nn_s, ref_nn, rtol=2e-6, err_msg=str(tag))
        assert np.abs(tot_s.astype(int)
                      - ref_tot.astype(int)).max() <= SLACK, (tag, tot_s,
                                                              ref_tot)

ref_nn, ref_tot, _ = oracle(sharded)

# production wiring: make_distributed_search (both strategies) vs oracle
nn_by = {}
for strategy in ("scan", "compact"):
    nn, tot = dist_run(sharded, strategy=strategy)
    np.testing.assert_allclose(nn, ref_nn, rtol=2e-6, err_msg=strategy)
    assert np.abs(tot.astype(int) - ref_tot.astype(int)).max() <= SLACK
    nn_by[strategy] = nn
np.testing.assert_allclose(nn_by["compact"], nn_by["scan"], rtol=2e-6)

# exactness floor: recall vs exact single-device search
ref_exact = lfi.search_exact(Q)
nn_c = nn_by["compact"]
recall = (nn_c <= ref_exact.dists[:, 0] * (1 + 1e-5) + 1e-6).mean()
assert recall >= 0.9, recall
assert (nn_c >= ref_exact.dists[:, 0] - 1e-4).all()

print("DIST_OK", backbone, "recall", recall)
"""


@pytest.mark.parametrize("backbone", ["dstree", "isax"])
def test_distributed_search_matches(backbone):
    code = CODE % {"backbone": backbone}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900)
    assert f"DIST_OK {backbone}" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-4000:]


PQ_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.core import build, conformal, distributed, filter_training, search
from repro.core.summaries import znormalize

backbone = "%(backbone)s"
rng = np.random.default_rng(0)
S = rng.standard_normal((3000, 64), dtype=np.float32).cumsum(axis=1)
cfg = build.LeaFiConfig(backbone=backbone, leaf_capacity=64, n_global=120,
                        n_local=24, t_filter_over_t_series=10.0,
                        train=filter_training.TrainConfig(epochs=20))
lfi = build.build_leafi(S, cfg)
Q = znormalize(S[rng.integers(0, len(S), 16)]
               + 0.3 * rng.standard_normal((16, 64)).astype(np.float32))
Qj = jnp.asarray(Q)
L = lfi.index.n_leaves
TARGETS = np.asarray([0.9, 0.95, 0.99])
targets = TARGETS[rng.integers(0, 3, 16)]            # mixed micro-batch

mesh = distributed.make_search_mesh(2, 2)
sharded = distributed.shard_leafi(lfi, n_shards=2, quality_target=0.99)
assert sharded.leaf_global is not None
lg = np.asarray(sharded.leaf_global)
real = np.asarray(sharded.leaf_size) > 0
# the slot->global map covers every leaf exactly once; padding slots carry L
assert sorted(lg[real].tolist()) == list(range(L))
assert (lg[~real] == L).all()

run, *_ = distributed.make_distributed_search(
    mesh, sharded, per_query_offsets=True)
qoff = conformal.scatter_offsets(lfi.tuner, lfi.leaf_ids, L, targets)
inf_ub = np.full(16, np.inf, np.float32)
with mesh:
    nn, tot = run(Qj, jnp.asarray(qoff), jnp.asarray(inf_ub))
nn, tot = np.asarray(nn), np.asarray(tot)

# parity vs the single-device per-query-offset search, pinned per target
# group (cross-program: tolerance, cf. the module docstring)
ref = search.search_batched(lfi.index, Q, k=1, quality_target=targets,
                            filter_params=lfi.filter_params,
                            leaf_ids=lfi.leaf_ids, tuner=lfi.tuner)
for t in TARGETS:
    sel = targets == t
    if sel.any():
        np.testing.assert_allclose(nn[sel], ref.dists[sel, 0], rtol=2e-6,
                                   err_msg=str(t))

# homogeneous rows == the baked single-offset program (same target)
run1, *_ = distributed.make_distributed_search(mesh, sharded)
qoff99 = conformal.scatter_offsets(lfi.tuner, lfi.leaf_ids, L,
                                   np.full(16, 0.99))
with mesh:
    nn_pq, _ = run(Qj, jnp.asarray(qoff99), jnp.asarray(inf_ub))
    nn_1, _ = run1(Qj)
np.testing.assert_allclose(np.asarray(nn_pq), np.asarray(nn_1), rtol=2e-6)

# +inf offset rows disable every filter: exact answers from the same program
inf_rows = jnp.full((16, L), np.inf, jnp.float32)
with mesh:
    nn_ex, tot_ex = run(Qj, inf_rows, jnp.asarray(inf_ub))
exact = lfi.search_exact(Q)
np.testing.assert_allclose(np.asarray(nn_ex), exact.dists[:, 0], rtol=2e-6)

# a valid prune-only warm bound on the exact path (where its bitwise
# contract holds: it only tightens the lb test) never changes the answer
# and never scans more leaves
ub = (exact.dists[:, 0] * (1 + 1e-6) + 1e-6).astype(np.float32)
with mesh:
    nn_w, tot_w = run(Qj, inf_rows, jnp.asarray(ub))
np.testing.assert_allclose(np.asarray(nn_w), np.asarray(nn_ex), rtol=2e-6)
assert np.asarray(tot_w).sum() <= np.asarray(tot_ex).sum()

print("PQ_OK", backbone)
"""


@pytest.mark.parametrize("backbone", ["dstree", "isax"])
def test_distributed_per_query_offsets(backbone):
    code = PQ_CODE % {"backbone": backbone}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900)
    assert f"PQ_OK {backbone}" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-4000:]


SERVE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import numpy as np, jax
from repro.core import build, distributed, filter_training
from repro.core.summaries import znormalize
from repro.serving import (DistributedExecutor, MicroBatcher,
                           ServingSession, poisson_trace)

rng = np.random.default_rng(0)
S = rng.standard_normal((3000, 64), dtype=np.float32).cumsum(axis=1)
cfg = build.LeaFiConfig(backbone="dstree", leaf_capacity=64, n_global=120,
                        n_local=24, t_filter_over_t_series=10.0,
                        train=filter_training.TrainConfig(epochs=20))
lfi = build.build_leafi(S, cfg)
pool = znormalize(S[rng.integers(0, len(S), 32)]
                  + 0.3 * rng.standard_normal((32, 64)).astype(np.float32))
trace = poisson_trace(pool, rate=800.0, n_requests=48,
                      targets=(0.9, 0.99), ks=(1,), seed=3)
svc = lambda b: 1e-3 * max(b.bucket / 8, 0.25)

mesh = distributed.make_search_mesh(1, 2)            # 1x2 host mesh

def serve(pipeline):
    ex = DistributedExecutor(lfi, mesh)
    s = ServingSession(lfi, warm_start=True, executor=ex)
    with mesh:
        s.warmup(max_batch=8, ks=(1,), queries=pool)
        return s.serve(trace,
                       batcher=MicroBatcher(max_batch=8, max_wait=0.004),
                       service_time=svc, pipeline=pipeline)

r0 = serve(0)
r1 = serve(1)
host = ("wall", "dispatch_s", "harvest_s", "t_disp", "t_done")
strip = lambda log: [{k: v for k, v in b.items() if k not in host}
                     for b in log]
assert strip(r0["batches"]) == strip(r1["batches"])
for rid in r0["completions"]:
    assert r0["completions"][rid]["result"] == \
        r1["completions"][rid]["result"], rid        # bitwise

# the shard_map answers match the single-host session on the same trace
single = ServingSession(lfi)
single.warmup(max_batch=8, ks=(1,), queries=pool)
rs = single.serve(trace, batcher=MicroBatcher(max_batch=8, max_wait=0.004),
                  service_time=svc)
for rid in rs["completions"]:
    a = rs["completions"][rid]["result"]["dist"]
    b = r0["completions"][rid]["result"]["dist"]
    assert abs(a - b) <= 2e-5 * max(abs(a), 1.0), (rid, a, b)

print("DIST_SERVE_OK")
"""


def test_distributed_serving_pipelined_parity_on_host_mesh():
    """1×2 host mesh: the DistributedExecutor session serves the identical
    trace bitwise under serial and pipelined dispatch, and its answers match
    the single-host session to float tolerance."""
    r = subprocess.run([sys.executable, "-c", SERVE_CODE],
                       capture_output=True, text=True, timeout=900)
    assert "DIST_SERVE_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-4000:]


TRACE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.core import build, distributed, filter_training
from repro.core.summaries import znormalize

rng = np.random.default_rng(0)
S = rng.standard_normal((3000, 64), dtype=np.float32).cumsum(axis=1)
cfg = build.LeaFiConfig(backbone="dstree", leaf_capacity=64, n_global=120,
                        n_local=24, t_filter_over_t_series=10.0,
                        train=filter_training.TrainConfig(epochs=20))
lfi = build.build_leafi(S, cfg)
Q = znormalize(S[rng.integers(0, len(S), 16)]
               + 0.3 * rng.standard_normal((16, 64)).astype(np.float32))
Qj = jnp.asarray(Q)

mesh = distributed.make_search_mesh(2, 2)
sharded = distributed.shard_leafi(lfi, n_shards=2, quality_target=0.99)
n_shards, P_slots = sharded.leaf_size.shape

for strategy in ("scan", "compact"):
    run0, *_ = distributed.make_distributed_search(mesh, sharded,
                                                   strategy=strategy)
    runt, *_ = distributed.make_distributed_search(mesh, sharded,
                                                   strategy=strategy,
                                                   trace=True)
    with mesh:
        nn0, tot0 = run0(Qj)
        nn1, tot1, tr = runt(Qj)
    # trace=True must not perturb the exchange (same programs modulo the
    # psum'd int32 side outputs)
    np.testing.assert_array_equal(np.asarray(nn0), np.asarray(nn1),
                                  err_msg=strategy)
    np.testing.assert_array_equal(np.asarray(tot0), np.asarray(tot1),
                                  err_msg=strategy)
    # global accounting identity (see distributed._make_shard_body): each
    # shard probes one leaf that stays cascade-accounted, so probed == S
    # and the pruned counts partition the S*P slot grid minus survivors
    pruned = (np.asarray(tr.pruned_box) + np.asarray(tr.pruned_seed)
              + np.asarray(tr.pruned_filter))
    np.testing.assert_array_equal(
        pruned, n_shards * P_slots - np.asarray(tr.survivors),
        err_msg=strategy)
    np.testing.assert_array_equal(np.asarray(tr.probed),
                                  np.full(16, n_shards), err_msg=strategy)
    assert (np.asarray(tr.distances) > 0).all(), strategy

print("TRACE_OK")
"""


def test_distributed_trace_parity_and_global_accounting():
    """2-shard host mesh: the traced shard body returns bitwise-identical
    nn/searched outputs and a psum'd CascadeTrace whose counts satisfy the
    global identity (sum pruned == S*P - survivors, probed == S)."""
    r = subprocess.run([sys.executable, "-c", TRACE_CODE],
                       capture_output=True, text=True, timeout=900)
    assert "TRACE_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-4000:]


AUDIT_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.core import build, distributed, filter_training
from repro.core.summaries import znormalize
from repro.obs import audit as obs_audit

rng = np.random.default_rng(0)
S = rng.standard_normal((3000, 64), dtype=np.float32).cumsum(axis=1)
cfg = build.LeaFiConfig(backbone="dstree", leaf_capacity=64, n_global=120,
                        n_local=24, t_filter_over_t_series=10.0,
                        train=filter_training.TrainConfig(epochs=20))
lfi = build.build_leafi(S, cfg)
Q = znormalize(S[rng.integers(0, len(S), 16)]
               + 0.3 * rng.standard_normal((16, 64)).astype(np.float32))
Qj = jnp.asarray(Q)
L = lfi.index.n_leaves

mesh = distributed.make_search_mesh(2, 2)
sharded = distributed.shard_leafi(lfi, n_shards=2, quality_target=0.99)
n_shards, P_slots = sharded.leaf_size.shape
SLACK = 8      # cross-program searched-count slack (ulp-tied prune flips)

for strategy in ("scan", "compact"):
    run0, *_ = distributed.make_distributed_search(mesh, sharded,
                                                   strategy=strategy)
    runa, *_ = distributed.make_distributed_search(mesh, sharded,
                                                   strategy=strategy,
                                                   audit=True)
    with mesh:
        nn0, tot0 = run0(Qj)
        nn1, tot1, fa = runa(Qj)
    # the audited program's answers are bitwise; the searched count may
    # sit an ulp-tie away across differently-fused programs (cf. the
    # module docstring's assertion-strength note)
    np.testing.assert_array_equal(np.asarray(nn0), np.asarray(nn1),
                                  err_msg=strategy)
    assert np.abs(np.asarray(tot1).astype(int)
                  - np.asarray(tot0).astype(int)).max() <= SLACK, strategy
    fa_np = jax.tree.map(np.asarray, fa)
    assert fa_np.kept.shape == (n_shards, P_slots), strategy
    assert fa_np.resid_buckets.shape == (n_shards, P_slots,
                                         obs_audit.N_BUCKETS), strategy
    # per-shard-slot accounting identity, exact: after the data-axis psum
    # every (shard, slot) has partitioned the full 16-query batch
    resid = np.asarray(obs_audit.accounting_residual_leaf(fa, 16))
    assert not resid.any(), (strategy, resid)
    # padding slots never enter a distance pass
    pad = np.asarray(sharded.leaf_size) == 0
    assert not fa_np.kept[pad].any(), strategy
    assert not fa_np.scored[pad].any(), strategy
    # fold to global leaf order: identity again, scratch row absorbed
    g = obs_audit.scatter_global(fa, sharded.leaf_global, L)
    g_np = jax.tree.map(np.asarray, g)
    assert g_np.kept.shape == (L,), strategy
    assert not np.asarray(
        obs_audit.accounting_residual_leaf(g, 16)).any(), strategy
    # residual bookkeeping survives the collectives + the fold
    np.testing.assert_array_equal(g_np.resid_buckets.sum(-1),
                                  g_np.resid_count, err_msg=strategy)
    assert (g_np.violations <= g_np.resid_count).all(), strategy
    assert (g_np.resid_count <= g_np.scored).all(), strategy
    assert g_np.kept.sum() > 0, strategy
    assert (g_np.pruned_box + g_np.pruned_seed
            + g_np.pruned_filter).sum() > 0, strategy

print("AUDIT_OK")
"""


def test_distributed_audit_accounting_and_parity():
    """2-shard host mesh: the audited shard body answers bitwise, its
    per-(shard, slot) FilterAudit satisfies the accounting identity exactly
    after the data-axis psum, and the scatter_global fold to leaf order
    preserves both the identity and the residual bookkeeping."""
    r = subprocess.run([sys.executable, "-c", AUDIT_CODE],
                       capture_output=True, text=True, timeout=900)
    assert "AUDIT_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-4000:]
