"""Distributed (shard_map) LeaFi search == single-device search.

Runs in a subprocess so the 4 placeholder host devices don't leak into the
rest of the suite.
"""
import subprocess
import sys

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.core import build, distributed, filter_training
from repro.core.summaries import znormalize

rng = np.random.default_rng(0)
S = rng.standard_normal((3000, 64), dtype=np.float32).cumsum(axis=1)
cfg = build.LeaFiConfig(backbone="dstree", leaf_capacity=64, n_global=120,
                        n_local=24, t_filter_over_t_series=10.0,
                        train=filter_training.TrainConfig(epochs=20))
lfi = build.build_leafi(S, cfg)
Q = znormalize(S[rng.integers(0, len(S), 16)]
               + 0.3 * rng.standard_normal((16, 64)).astype(np.float32))

if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5 wants explicit axis types
    mesh = jax.make_mesh((2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
else:
    mesh = jax.make_mesh((2, 2), ("data", "model"))
sharded = distributed.shard_leafi(lfi, n_shards=2, quality_target=0.99)
run, *_ = distributed.make_distributed_search(mesh, sharded)
with mesh:
    nn, searched = run(jnp.asarray(Q))

ref = lfi.search(Q, quality_target=0.99)
ref_exact = lfi.search_exact(Q)
nn = np.asarray(nn)
# distributed result must be >= exact NN and match the single-device LeaFi
# search up to pruning-path differences; exactness: recall vs exact
recall = (nn <= ref_exact.dists[:, 0] * (1 + 1e-5) + 1e-6).mean()
assert recall >= 0.9, recall
assert (nn >= ref_exact.dists[:, 0] - 1e-4).all()
print("DIST_OK recall", recall, "searched", np.asarray(searched).mean())
"""


def test_distributed_search_matches(tmp_path):
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, timeout=600)
    assert "DIST_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
