"""End-to-end behaviour of the paper's system (Alg. 1 + Alg. 2).

Builds a LeaFi-enhanced index on a RandWalk collection (the paper's
synthetic protocol), then checks the paper's headline behaviours at test
scale: exactness with filters off, recall at the quality target with
filters on, pruning-ratio improvement, and the build-report accounting.
"""
import numpy as np
import pytest

from repro.core import build, filter_training
from repro.core.summaries import znormalize
from repro.data.series import make_query_set


@pytest.fixture(scope="module")
def leafi_index():
    rng = np.random.default_rng(11)
    S = rng.standard_normal((8000, 96), dtype=np.float32).cumsum(axis=1)
    cfg = build.LeaFiConfig(
        backbone="dstree", leaf_capacity=96, n_global=240, n_local=60,
        t_filter_over_t_series=20.0,
        train=filter_training.TrainConfig(epochs=60, batch=64))
    return S, build.build_leafi(S, cfg)


@pytest.fixture(scope="module")
def test_queries(leafi_index):
    S, _ = leafi_index
    return make_query_set(S, 48, noise=0.2, seed=23)


def test_build_report_accounting(leafi_index):
    _, lfi = leafi_index
    r = lfi.build_report
    assert r["n_filters"] > 0
    assert r["n_filters"] <= r["n_leaves"]
    for key in ("t_index_build", "t_collect", "t_train", "t_calibrate"):
        assert r[key] > 0


def test_exact_mode_is_exact(leafi_index, test_queries):
    S, lfi = leafi_index
    res = lfi.search_exact(test_queries)
    d = np.sqrt(((test_queries[:, None] - znormalize(S)[None]) ** 2).sum(-1))
    np.testing.assert_allclose(res.dists[:, 0], d.min(1), rtol=1e-4)


def test_leafi_meets_quality_target(leafi_index, test_queries):
    _, lfi = leafi_index
    exact = lfi.search_exact(test_queries)
    res = lfi.search(test_queries, quality_target=0.99)
    recall = float((res.dists[:, 0] <= exact.dists[:, 0] * (1 + 1e-5) + 1e-6)
                   .mean())
    assert recall >= 0.9, recall
    # filters must prune at least as much as the summarization-only search
    assert res.pruning_ratio.mean() >= exact.pruning_ratio.mean() - 1e-9


def test_lower_quality_target_prunes_more(leafi_index, test_queries):
    _, lfi = leafi_index
    hi = lfi.search(test_queries, quality_target=0.999)
    lo = lfi.search(test_queries, quality_target=0.5)
    assert lo.searched.mean() <= hi.searched.mean() + 1e-9


def test_per_query_targets_are_independent(leafi_index, test_queries):
    """The paper's key UX claim: quality target chosen at query time."""
    _, lfi = leafi_index
    a = lfi.search(test_queries[:4], quality_target=0.95)
    b = lfi.search(test_queries[:4], quality_target=0.99)
    assert a.dists.shape == b.dists.shape


def test_index_checkpoint_roundtrip(leafi_index, tmp_path):
    _, lfi = leafi_index
    from repro.checkpoint import save_pytree, load_pytree
    tree = {"filters": lfi.filter_params,
            "leaf_start": lfi.index.leaf_start,
            "leaf_size": lfi.index.leaf_size}
    save_pytree(str(tmp_path / "lfi"), tree)
    restored, _ = load_pytree(str(tmp_path / "lfi"), like=tree)
    np.testing.assert_array_equal(
        np.asarray(restored["filters"]["w1"]),
        np.asarray(lfi.filter_params["w1"]))
