"""Leaf-node selection: greedy rule (Alg. 3) vs the exact knapsack (Eq. 1)."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import selection


def test_threshold_matches_paper_formula():
    # paper §5.3.3: t_F/t_S ≈ 279 on Deep, a = 2 ⇒ th = 558
    assert selection.size_threshold(279.0, 1.0, a=2.0) == 558.0


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 60),
       cap=st.integers(0, 30))
def test_greedy_is_optimal_for_uniform_weights(seed, n, cap):
    """Under the paper's assumption (uniform p_lb, p_F, w), value is monotone
    in leaf size, so greedy == exact knapsack value."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 2000, n)
    t_f, t_s, a = 30.0, 1.0, 2.0
    th = selection.size_threshold(t_f, t_s, a)
    values = selection.expected_benefit(sizes, p_lb=0.5, p_f=1 / a,
                                        t_series=t_s, t_filter=t_f)
    greedy = selection.greedy_select(sizes, th, max_filters=cap)
    exact = selection.knapsack_select(values, np.ones(n, np.int64), cap)
    v_greedy = values[greedy].clip(0).sum()
    v_exact = values[exact].clip(0).sum()
    assert np.isclose(v_greedy, v_exact), (v_greedy, v_exact)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_knapsack_respects_capacity_and_beats_greedy_generally(seed):
    rng = np.random.default_rng(seed)
    n = 25
    values = rng.uniform(-1, 10, n)
    weights = rng.integers(1, 8, n)
    cap = 20
    picked = selection.knapsack_select(values, weights, cap)
    assert weights[picked].sum() <= cap
    assert (values[picked] > 0).all()
    # exact DP ≥ value-greedy-by-density heuristic
    order = np.argsort(-values / weights)
    w, v_greedy = 0, 0.0
    for i in order:
        if values[i] > 0 and w + weights[i] <= cap:
            w += weights[i]
            v_greedy += values[i]
    assert values[picked].sum() >= v_greedy - 1e-9


def test_negative_benefit_leaves_are_never_selected():
    sizes = np.asarray([10, 100, 1000])
    th = selection.size_threshold(60.0, 1.0, a=2.0)   # th = 120
    got = selection.greedy_select(sizes, th)
    assert list(got) == [2]
