"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real single CPU device; only launch/dryrun.py (its own
process) forces 512 host devices."""
import numpy as np
import pytest

# Lint-rule fixture trees under tests/lint_fixtures/ are linter *inputs*, not
# test modules — keep pytest from importing them (the LF002 fixture ships its
# own tests/test_kernels.py which would shadow-collide with the real one).
collect_ignore = ["lint_fixtures"]


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Drop XLA compilation caches after each test module.

    The suite jit-compiles hundreds of programs in one process; letting
    them accumulate has crashed the CPU backend's compiler late in the run
    (segfault inside ``backend_compile`` around the ~215th test, not
    reproducible for any module in isolation).  Per-module recompilation
    costs a few seconds total and keeps the long run bounded.
    """
    yield
    import jax
    jax.clear_caches()


@pytest.fixture(scope="session")
def randwalk_small():
    rng = np.random.default_rng(7)
    return rng.standard_normal((4000, 96), dtype=np.float32).cumsum(axis=1)


@pytest.fixture(scope="session")
def queries_small(randwalk_small):
    from repro.data.series import make_query_set
    return make_query_set(randwalk_small, 32, noise=0.2, seed=3)
