"""DTW + LB_Keogh invariants (paper §3: LeaFi is metric-agnostic)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import dtw


def dtw_oracle(q, x, band):
    """Literal O(m²) DP in numpy."""
    m = len(q)
    D = np.full((m + 1, m + 1), np.inf)
    D[0, 0] = 0.0
    for i in range(1, m + 1):
        lo, hi = max(1, i - band), min(m, i + band)
        for j in range(lo, hi + 1):
            c = (q[i - 1] - x[j - 1]) ** 2
            D[i, j] = c + min(D[i - 1, j - 1], D[i - 1, j], D[i, j - 1])
    return np.sqrt(D[m, m])


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.sampled_from([8, 16, 33]),
       band=st.sampled_from([2, 4, 8]))
def test_dtw_matches_oracle(seed, m, band):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal(m).astype(np.float32)
    x = rng.standard_normal(m).astype(np.float32)
    got = float(dtw.dtw(jnp.asarray(q), jnp.asarray(x), band=band))
    want = dtw_oracle(q, x, band)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), band=st.sampled_from([2, 6]))
def test_lb_keogh_lower_bounds_dtw_and_dtw_bounds_euclidean(seed, band):
    rng = np.random.default_rng(seed)
    m = 24
    q = rng.standard_normal(m).astype(np.float32)
    x = rng.standard_normal(m).astype(np.float32)
    lb = float(dtw.lb_keogh(jnp.asarray(q), jnp.asarray(x), band=band))
    d = float(dtw.dtw(jnp.asarray(q), jnp.asarray(x), band=band))
    eu = float(np.sqrt(((q - x) ** 2).sum()))
    assert lb <= d + 1e-4, (lb, d)
    assert d <= eu + 1e-4, (d, eu)          # band-DTW ≤ identity alignment


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_leaf_envelope_bound_underestimates_member_dtw(seed):
    """Node-level LB_Keogh ≤ min DTW to any member: the Alg. 2 invariant
    for a DTW-backed index."""
    rng = np.random.default_rng(seed)
    m, n_members, band = 16, 6, 3
    members = rng.standard_normal((n_members, m)).astype(np.float32)
    q = rng.standard_normal(m).astype(np.float32)
    # leaf envelope: pointwise min/max of member envelopes
    los, his = [], []
    for s in members:
        L, U = dtw.keogh_envelope(jnp.asarray(s), band)
        los.append(np.asarray(L))
        his.append(np.asarray(U))
    env_lo = np.min(los, axis=0)[None, :]
    env_hi = np.max(his, axis=0)[None, :]
    lb = float(dtw.lb_keogh_leaves(jnp.asarray(q), jnp.asarray(env_lo),
                                   jnp.asarray(env_hi))[0])
    true = min(float(dtw.dtw(jnp.asarray(q), jnp.asarray(s), band=band))
               for s in members)
    assert lb <= true + 1e-4, (lb, true)
