"""Dry-run smoke: lower+compile representative cells in a subprocess with
512 placeholder devices (the deliverable-(e) mechanics, smoke-sized mesh
checks are in the full sweep under experiments/dryrun)."""
import json
import subprocess
import sys

import pytest

CELLS = [("glm4-9b", "train_4k"), ("rwkv6-1.6b", "long_500k"),
         ("mixtral-8x7b", "decode_32k")]


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", CELLS)
def test_cell_compiles_single_pod(arch, shape, tmp_path):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "OK " in r.stdout, r.stdout[-1500:] + r.stderr[-1500:]
    rec = json.load(open(next(tmp_path.glob("*.json"))))
    assert rec["status"] == "ok"
    assert rec["roofline"]["flops_per_device"] > 0
    assert rec["memory"]["total_hbm_bytes"] > 0
