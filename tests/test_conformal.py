"""Conformal auto-tuners: simulation exactness, spline monotonicity, recall
monotonicity in the offset."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import conformal


def _numpy_sim(d_lb, d_pred, offsets, d_L):
    """Literal Alg. 2 replay in python — oracle for the jitted simulator."""
    Q, L = d_lb.shape
    order = np.argsort(d_lb, axis=1)
    bsf = np.full(Q, np.inf, np.float32)
    searched = np.zeros(Q, np.int64)
    for qi in range(Q):
        for leaf in order[qi]:
            if d_lb[qi, leaf] > bsf[qi]:
                continue
            if d_pred[qi, leaf] - offsets[leaf] > bsf[qi]:
                continue
            searched[qi] += 1
            bsf[qi] = min(bsf[qi], d_L[qi, leaf])
    return bsf, searched


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), Q=st.integers(1, 8),
       L=st.integers(2, 30))
def test_simulator_matches_sequential_oracle(seed, Q, L):
    rng = np.random.default_rng(seed)
    d_L = rng.uniform(1, 20, (Q, L)).astype(np.float32)
    d_lb = (d_L * rng.uniform(0.2, 1.0, (Q, L))).astype(np.float32)
    d_pred = (d_L + rng.normal(0, 1, (Q, L))).astype(np.float32)
    offsets = rng.uniform(0, 2, L).astype(np.float32)
    bsf, searched = conformal.simulate_search(
        jnp.asarray(d_lb), jnp.asarray(d_pred), jnp.asarray(offsets),
        jnp.asarray(d_L))
    want_bsf, want_searched = _numpy_sim(d_lb, d_pred, offsets, d_L)
    np.testing.assert_allclose(np.asarray(bsf), want_bsf, rtol=1e-6)
    assert (np.asarray(searched) == want_searched).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_recall_monotone_in_offset(seed):
    """Bigger conformal offsets ⇒ less filter pruning ⇒ recall can only rise."""
    rng = np.random.default_rng(seed)
    Q, L = 16, 40
    d_L = rng.uniform(1, 20, (Q, L)).astype(np.float32)
    d_lb = (d_L * rng.uniform(0.2, 1.0, (Q, L))).astype(np.float32)
    d_pred = (d_L + rng.normal(0, 2, (Q, L))).astype(np.float32)
    d_nn = d_L.min(1)
    recalls = []
    for off in [0.0, 1.0, 3.0, 10.0, 100.0]:
        bsf, _ = conformal.simulate_search(
            jnp.asarray(d_lb), jnp.asarray(d_pred),
            jnp.full((L,), off, jnp.float32), jnp.asarray(d_L))
        recalls.append(float(conformal.recall_at_1(
            bsf, jnp.asarray(d_nn)).mean()))
    assert all(a <= b + 1e-9 for a, b in zip(recalls, recalls[1:])), recalls
    assert recalls[-1] == 1.0         # huge offsets disable filter pruning


def test_fit_autotuners_end_to_end():
    rng = np.random.default_rng(0)
    C, L = 120, 50
    leaf_ids = np.arange(0, L, 2)
    d_L = rng.uniform(1, 20, (C, L)).astype(np.float32)
    d_lb = (d_L * rng.uniform(0.2, 0.9, (C, L))).astype(np.float32)
    d_pred = np.full((C, L), -np.inf, np.float32)
    d_pred[:, leaf_ids] = d_L[:, leaf_ids] + rng.normal(
        0, 1.5, (C, len(leaf_ids)))
    tuner, report = conformal.fit_autotuners(d_lb, d_pred, d_L, leaf_ids)
    # spline output must be monotone in the target
    offs = [tuner.offsets(t).mean() for t in (0.5, 0.9, 0.99, 0.999)]
    assert all(a <= b + 1e-6 for a, b in zip(offs, offs[1:])), offs
    # asking for more than ever achieved → most conservative offsets
    top = tuner.offsets(1.1)
    np.testing.assert_allclose(top, tuner.max_offset)


def test_offsets_batched_matches_scalar_loop():
    """The (B,) target form is the scalar spline evaluation, row for row —
    bitwise, since both route through one vectorized implementation."""
    rng = np.random.default_rng(1)
    C, L = 100, 40
    leaf_ids = np.arange(0, L, 2)
    d_L = rng.uniform(1, 20, (C, L)).astype(np.float32)
    d_lb = (d_L * rng.uniform(0.2, 0.9, (C, L))).astype(np.float32)
    d_pred = np.full((C, L), -np.inf, np.float32)
    d_pred[:, leaf_ids] = d_L[:, leaf_ids] + rng.normal(
        0, 1.5, (C, len(leaf_ids)))
    tuner, _ = conformal.fit_autotuners(d_lb, d_pred, d_L, leaf_ids)
    # interior, below-lowest-knot, above-highest-knot, and knot-exact targets
    targets = np.concatenate([np.linspace(0.0, 1.2, 25),
                              tuner.knots_q[:3].astype(np.float64)])
    batched = tuner.offsets(targets)
    assert batched.shape == (len(targets), len(leaf_ids))
    for i, t in enumerate(targets):
        np.testing.assert_array_equal(batched[i], tuner.offsets(float(t)))
    # scatter_offsets: (B, L) rows pin against the scalar loop too
    rows = conformal.scatter_offsets(tuner, leaf_ids, L, targets)
    assert rows.shape == (len(targets), L)
    for i, t in enumerate(targets):
        np.testing.assert_array_equal(
            rows[i], conformal.scatter_offsets(tuner, leaf_ids, L, float(t)))
    # degenerate forms keep their contracts
    assert conformal.scatter_offsets(None, leaf_ids, L, targets).shape \
        == (len(targets), L)
    assert (conformal.scatter_offsets(None, leaf_ids, L, targets) == 0).all()
    assert conformal.scatter_offsets(tuner, leaf_ids, L, None).shape == (L,)


def test_steffen_spline_is_monotone_and_interpolating():
    x = np.array([0.0, 0.3, 0.7, 0.9, 1.0])
    y = np.array([[0.0, 1.0, 1.5, 4.0, 4.5]])
    slopes = conformal._steffen_slopes(x, y)
    tuner = conformal.AutoTuner(knots_q=x, knots_o=y.astype(np.float32),
                                slopes=slopes.astype(np.float32),
                                max_offset=y[:, -1].astype(np.float32))
    # interpolates the knots
    for xi, yi in zip(x[:-1], y[0][:-1]):
        assert abs(tuner.offsets(float(xi))[0] - yi) < 1e-5
    # monotone between knots
    qs = np.linspace(0, 1, 101)
    vals = np.array([tuner.offsets(float(q))[0] for q in qs])
    assert (np.diff(vals) >= -1e-6).all()
