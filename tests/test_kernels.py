"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.box_lb import ops as box_ops, ref as box_ref
from repro.kernels.filter_mlp import ops as mlp_ops, ref as mlp_ref
from repro.kernels.l2_scan import ops as l2_ops, ref as l2_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("Q,B,m", [(1, 1, 8), (3, 17, 96), (16, 300, 128),
                                   (130, 64, 256), (5, 1000, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_scan_matches_oracle(Q, B, m, dtype):
    q = jnp.asarray(RNG.standard_normal((Q, m)), dtype)
    s = jnp.asarray(RNG.standard_normal((B, m)), dtype)
    got = l2_ops.pairwise_l2(q, s, interpret=True)
    want = l2_ref.pairwise_l2(q.astype(jnp.float32), s.astype(jnp.float32))
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("F,Nq,R,m", [(1, 1, 1, 8), (3, 5, 17, 96),
                                      (4, 130, 40, 128), (2, 9, 300, 33)])
def test_slab_l2_kernel_matches_oracle(F, Nq, R, m):
    """The batched leaf-slab kernel (leading parallel F grid axis) against
    the matmul oracle it shares its algebra with — the TPU production path
    for the build side's per-leaf query batches."""
    q = jnp.asarray(RNG.standard_normal((F, Nq, m)), jnp.float32)
    s = jnp.asarray(RNG.standard_normal((F, R, m)), jnp.float32)
    got = l2_ops.slab_l2(q, s, "pairwise", interpret=True)
    want = l2_ops.slab_l2(q, s, "matmul")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=5e-4)


def test_slab_gather_and_masked_min():
    """gather_leaf_slabs + slab_masked_min against a per-leaf loop."""
    series = jnp.asarray(RNG.standard_normal((80, 32)), jnp.float32)
    starts = jnp.asarray([0, 20, 45]); sizes = jnp.asarray([20, 25, 11])
    max_leaf = 30
    # one leaf id past the end (== L) must come back all-invalid
    slabs, rows, valid = l2_ops.gather_leaf_slabs(
        series, starts, sizes, jnp.asarray([0, 1, 2, 3]), max_leaf)
    assert list(np.asarray(valid).sum(1)) == [20, 25, 11, 0]
    q = jnp.asarray(RNG.standard_normal((4, 7, 32)), jnp.float32)
    d = l2_ops.slab_l2(q, slabs, "direct")
    dmin, amin = l2_ops.slab_masked_min(d, valid)
    for f, (s0, z) in enumerate([(0, 20), (20, 25), (45, 11)]):
        want = np.sqrt((((np.asarray(q[f])[:, None, :]
                          - np.asarray(series[s0:s0 + z])[None]) ** 2)
                        .sum(-1)))
        np.testing.assert_allclose(np.asarray(dmin[f]), want.min(1),
                                   rtol=1e-5, atol=1e-4)
    assert np.isinf(np.asarray(dmin[3])).all()


def test_l2_scan_masked_min():
    q = jnp.asarray(RNG.standard_normal((4, 64)), jnp.float32)
    slab = jnp.asarray(RNG.standard_normal((50, 64)), jnp.float32)
    valid = jnp.arange(50) < 37
    dmin, amin = l2_ops.masked_min_l2(q, slab, valid, interpret=True)
    want = np.asarray(l2_ref.pairwise_l2(q, slab))[:, :37]
    np.testing.assert_allclose(np.asarray(dmin), want.min(1), rtol=1e-5,
                               atol=1e-4)
    assert (np.asarray(amin) == want.argmin(1)).all()


@pytest.mark.parametrize("F,Q,m,h", [(1, 1, 8, 8), (5, 7, 96, 96),
                                     (13, 140, 64, 128), (3, 32, 256, 17)])
def test_filter_mlp_matches_oracle(F, Q, m, h):
    w1 = jnp.asarray(RNG.standard_normal((F, m, h)) * 0.1, jnp.float32)
    b1 = jnp.asarray(RNG.standard_normal((F, h)) * 0.1, jnp.float32)
    w2 = jnp.asarray(RNG.standard_normal((F, h)) * 0.1, jnp.float32)
    b2 = jnp.asarray(RNG.standard_normal((F,)), jnp.float32)
    q = jnp.asarray(RNG.standard_normal((Q, m)), jnp.float32)
    got = mlp_ops.filter_predict(w1, b1, w2, b2, q, interpret=True)
    want = mlp_ref.filter_predict(w1, b1, w2, b2, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def _mlp_stack(F, m, h, scale=0.1):
    return (jnp.asarray(RNG.standard_normal((F, m, h)) * scale, jnp.float32),
            jnp.asarray(RNG.standard_normal((F, h)) * scale, jnp.float32),
            jnp.asarray(RNG.standard_normal((F, h)) * scale, jnp.float32),
            jnp.asarray(RNG.standard_normal((F,)), jnp.float32),
            jnp.asarray(RNG.standard_normal((F,)), jnp.float32),       # y_mean
            jnp.asarray(np.abs(RNG.standard_normal((F,))) + 0.5,
                        jnp.float32),                                  # y_std
            jnp.asarray(np.abs(RNG.standard_normal((F,))), jnp.float32))


@pytest.mark.parametrize("F,Q,m,h", [(1, 1, 8, 8), (5, 7, 96, 96),
                                     (13, 140, 64, 128), (16, 128, 128, 128),
                                     (3, 32, 256, 17)])
def test_fused_filter_mlp_matches_oracle(F, Q, m, h):
    """The filter-block megakernel (grouped matmul + in-kernel epilogue)
    against the unfused oracle composition, with and without offsets."""
    w1, b1, w2, b2, ym, ys, off = _mlp_stack(F, m, h)
    q = jnp.asarray(RNG.standard_normal((Q, m)), jnp.float32)
    got = mlp_ops.filter_predict_fused(w1, b1, w2, b2, ym, ys, q, off,
                                       interpret=True)
    want = mlp_ref.filter_predict_destd(w1, b1, w2, b2, ym, ys, q, off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    got = mlp_ops.filter_predict_fused(w1, b1, w2, b2, ym, ys, q,
                                       interpret=True)
    want = mlp_ref.filter_predict_destd(w1, b1, w2, b2, ym, ys, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("weight_dtype", ["bfloat16", "int8"])
def test_fused_filter_mlp_quantized_matches_dequantized_oracle(weight_dtype):
    """bf16/int8 fused variants vs the oracle on *dequantized* weights —
    in-kernel scale folding must equal dequantize-then-multiply."""
    from repro.core import filters
    F, Q, m, h = 13, 36, 64, 96
    w1, b1, w2, b2, ym, ys, off = _mlp_stack(F, m, h)
    p = filters.quantize_mlp(
        {"w1": w1, "b1": b1, "w2": w2, "b2": b2, "y_mean": ym, "y_std": ys},
        weight_dtype)
    s1, s2 = p.get("w1_scale"), p.get("w2_scale")
    q = jnp.asarray(RNG.standard_normal((Q, m)), jnp.float32)
    got = mlp_ops.filter_predict_fused(p["w1"], b1, p["w2"], b2, ym, ys, q,
                                       off, s1, s2, interpret=True)
    want = mlp_ref.filter_predict_destd(p["w1"], b1, p["w2"], b2, ym, ys, q,
                                        off, s1, s2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fused_epilogue_bitwise_vs_unfused_composition():
    """The in-kernel epilogue (z·y_std + y_mean − off) must be *bitwise*
    equal to composing a neutral-epilogue kernel run (y_mean=0, y_std=1,
    off=0 — exact identities) with the same ops applied outside.  The
    outside composition is jitted so both sides see XLA's mul+add (FMA)
    contraction; eager ops round the intermediate and differ by an ulp."""
    import jax
    F, Q, m, h = 13, 140, 64, 128
    w1, b1, w2, b2, ym, ys, off = _mlp_stack(F, m, h, scale=0.3)
    q = jnp.asarray(RNG.standard_normal((Q, m)), jnp.float32)
    zero = jnp.zeros((F,), jnp.float32)
    one = jnp.ones((F,), jnp.float32)
    raw = mlp_ops.filter_predict_fused(w1, b1, w2, b2, zero, one, q,
                                       interpret=True)
    manual = jax.jit(
        lambda z, s, u, o: z * s[:, None] + u[:, None] - o[:, None])(
        raw, ys, ym, off)
    fused = mlp_ops.filter_predict_fused(w1, b1, w2, b2, ym, ys, q, off,
                                         interpret=True)
    assert (np.asarray(manual) == np.asarray(fused)).all()


@pytest.mark.parametrize("Q,L,d", [(1, 1, 4), (9, 200, 16), (150, 37, 8)])
def test_box_lb_matches_oracle(Q, L, d):
    q = jnp.asarray(RNG.standard_normal((Q, d)), jnp.float32)
    centers = RNG.standard_normal((L, d))
    width = np.abs(RNG.standard_normal((L, d)))
    lo = jnp.asarray(centers - width, jnp.float32)
    hi = jnp.asarray(centers + width, jnp.float32)
    # open boxes on some edges (±inf) as produced by SAX extremes
    lo = lo.at[0].set(-jnp.inf)
    hi = hi.at[-1].set(jnp.inf)
    got = box_ops.box_lb(q, lo, hi, interpret=True)
    want = box_ref.box_lb(q, lo, hi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gathered_leaf_l2_impls_agree_and_leaf_topk():
    """The compact engine's candidate primitives: both gathered-distance
    impls (and the backend default) agree, and leaf_topk returns each
    leaf's k smallest with their row ids."""
    N, C, R, m, k = 3, 4, 10, 16, 3
    q = jnp.asarray(RNG.standard_normal((N, m)), jnp.float32)
    slabs = jnp.asarray(RNG.standard_normal((N, C, R, m)), jnp.float32)
    d_direct = l2_ops.gathered_leaf_l2(q, slabs, "direct")
    d_matmul = l2_ops.gathered_leaf_l2(q, slabs, "matmul")
    np.testing.assert_allclose(np.asarray(d_direct), np.asarray(d_matmul),
                               rtol=1e-4, atol=1e-4)
    assert l2_ops.default_gathered_impl() in ("direct", "matmul")
    d_default = l2_ops.gathered_leaf_l2(q, slabs)        # backend default
    assert d_default.shape == (N, C, R)
    rows = jnp.broadcast_to(jnp.arange(C * R).reshape(1, C, R), (N, C, R))
    vals, ids = l2_ops.leaf_topk(d_direct, rows, k)
    dd = np.asarray(d_direct)
    np.testing.assert_allclose(np.asarray(vals),
                               np.sort(dd, axis=-1)[..., :k],
                               rtol=0, atol=0)
    np.testing.assert_array_equal(
        np.asarray(ids),
        np.asarray(rows)[np.arange(N)[:, None, None],
                         np.arange(C)[None, :, None],
                         np.argsort(dd, axis=-1)[..., :k]])


def test_shared_slab_l2_impls_agree():
    """All three shared-slab impls (the union candidate pass / build sweep)
    agree; the backend default is one of them."""
    Q, C, R, m = 5, 3, 12, 16
    q = jnp.asarray(RNG.standard_normal((Q, m)), jnp.float32)
    slabs = jnp.asarray(RNG.standard_normal((C, R, m)), jnp.float32)
    d_direct = l2_ops.shared_slab_l2(q, slabs, "direct")
    d_matmul = l2_ops.shared_slab_l2(q, slabs, "matmul")
    d_pair = l2_ops.shared_slab_l2(q, slabs, "pairwise", interpret=True)
    np.testing.assert_allclose(np.asarray(d_matmul), np.asarray(d_direct),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(d_pair), np.asarray(d_direct),
                               rtol=1e-4, atol=1e-4)
    assert l2_ops.default_slab_impl() in ("pairwise", "matmul")


def test_pack_fused_layout_roundtrip():
    """pack_fused's grouped layout invariant: lane j of group g holds
    filter g·bf + j//h' — unpacking recovers the original weights."""
    F, m, h, bf = 6, 16, 8, 4
    w1, b1, w2, b2, ym, ys, off = _mlp_stack(F, m, h)
    g = mlp_ops.pack_fused(w1, b1, w2, b2, ym, ys, off, bf=bf)
    G = -(-F // bf)
    mp = g["w1g"].shape[1]
    hp = g["w1g"].shape[2] // bf
    w1g = np.asarray(g["w1g"]).reshape(G, mp, bf, hp).transpose(0, 2, 1, 3)
    b1g = np.asarray(g["b1g"]).reshape(G, bf, hp)
    for f in range(F):
        np.testing.assert_array_equal(w1g[f // bf, f % bf, :m, :h],
                                      np.asarray(w1[f]))
        np.testing.assert_array_equal(b1g[f // bf, f % bf, :h],
                                      np.asarray(b1[f]))


def test_reference_aliases_are_the_oracles():
    """Each kernel package re-exports its oracle under ``reference`` —
    benchmarks and parity harnesses rely on the alias staying wired."""
    assert l2_ops.reference is l2_ref.pairwise_l2
    assert box_ops.reference is box_ref.box_lb
    assert mlp_ops.reference is mlp_ref.filter_predict
    assert mlp_ops.fused_reference is mlp_ref.filter_predict_destd


def test_kernel_paths_agree_with_bound_oracles(randwalk_small):
    """sax_lb / eapca_lb kernel wrappers == core.bounds jnp forms."""
    from repro.core import bounds, summaries, tree
    S = randwalk_small[:1500]
    q = jnp.asarray(summaries.znormalize(S[:9] + 0.5))
    idx_i = tree.build_isax(S, leaf_capacity=64)
    idx_d = tree.build_dstree(S, leaf_capacity=64)
    edges = jnp.asarray(idx_i.payload["sax_edges"])
    qp = summaries.paa(q, edges.shape[1])
    got = box_ops.sax_lb(qp, edges, length=S.shape[1], interpret=True)
    want = bounds.sax_lower_bound(qp, edges, S.shape[1])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)
    boxes = jnp.asarray(idx_d.payload["eapca_box"])
    seg = jnp.asarray(idx_d.payload["seg_len"])
    qs = summaries.segment_stats(q, boxes.shape[1])
    got = box_ops.eapca_lb(qs, boxes, seg, interpret=True)
    want = bounds.eapca_lower_bound(qs, boxes, seg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)
