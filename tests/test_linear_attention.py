"""Chunked linear attention vs the literal per-step recurrence oracle.

Locks semantics before §Perf optimizations: any chunking/factorization
change must keep these green.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import linear_attention as la


def _oracle(q, k, v, ld, state0, bonus, include_current):
    """Direct recurrence, per (batch, head)."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    state = state0.copy()
    out = np.zeros((B, S, H, dv), np.float32)
    for b in range(B):
        for h in range(H):
            St = state[b, h].copy()
            for t in range(S):
                kv = np.outer(k[b, t, h], v[b, t, h])
                if include_current:
                    St = np.exp(ld[b, t, h])[:, None] * St + kv
                    out[b, t, h] = q[b, t, h] @ St
                else:
                    out[b, t, h] = q[b, t, h] @ St
                    if bonus is not None:
                        out[b, t, h] += (q[b, t, h] * bonus[h] * k[b, t, h]
                                         ).sum() * v[b, t, h]
                    St = np.exp(ld[b, t, h])[:, None] * St + kv
            state[b, h] = St
    return out, state


@pytest.mark.parametrize("include_current,with_bonus",
                         [(False, True), (False, False), (True, False)])
@pytest.mark.parametrize("S,chunk", [(16, 4), (20, 8), (7, 8), (64, 16)])
def test_chunked_matches_recurrence(include_current, with_bonus, S, chunk):
    rng = np.random.default_rng(0)
    B, H, dk, dv = 2, 3, 8, 5
    q = rng.standard_normal((B, S, H, dk)).astype(np.float32)
    k = rng.standard_normal((B, S, H, dk)).astype(np.float32)
    v = rng.standard_normal((B, S, H, dv)).astype(np.float32)
    ld = -np.exp(rng.normal(-1.5, 1.0, (B, S, H, dk))).astype(np.float32)
    state0 = rng.standard_normal((B, H, dk, dv)).astype(np.float32) * 0.1
    bonus = (rng.standard_normal((H, dk)).astype(np.float32)
             if with_bonus else None)

    got, got_state = la.chunked_linear_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(ld),
        jnp.asarray(state0),
        bonus=None if bonus is None else jnp.asarray(bonus),
        include_current=include_current, chunk=chunk)
    want, want_state = _oracle(q, k, v, ld, state0, bonus, include_current)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got_state), want_state,
                               rtol=2e-4, atol=2e-4)


def test_extreme_decay_stays_finite():
    """Fast decay (ld very negative) must not produce inf/nan — the reason
    the implementation avoids the naive exp(+cum) factorization."""
    rng = np.random.default_rng(1)
    B, S, H, dk, dv = 1, 32, 2, 4, 4
    q = rng.standard_normal((B, S, H, dk)).astype(np.float32)
    k = rng.standard_normal((B, S, H, dk)).astype(np.float32)
    v = rng.standard_normal((B, S, H, dv)).astype(np.float32)
    ld = np.full((B, S, H, dk), -20.0, np.float32)     # decay ≈ 2e-9/step
    out, state = la.chunked_linear_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(ld),
        include_current=True, chunk=8)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(np.asarray(state)).all()


def test_step_matches_chunked():
    rng = np.random.default_rng(2)
    B, H, dk, dv = 2, 3, 8, 5
    S = 10
    q = rng.standard_normal((B, S, H, dk)).astype(np.float32)
    k = rng.standard_normal((B, S, H, dk)).astype(np.float32)
    v = rng.standard_normal((B, S, H, dv)).astype(np.float32)
    ld = -np.exp(rng.normal(-1.5, 1.0, (B, S, H, dk))).astype(np.float32)
    full, full_state = la.chunked_linear_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(ld),
        include_current=True, chunk=4)
    state = jnp.zeros((B, H, dk, dv))
    outs = []
    for t in range(S):
        o, state = la.linear_attention_step(
            jnp.asarray(q[:, t]), jnp.asarray(k[:, t]), jnp.asarray(v[:, t]),
            jnp.asarray(ld[:, t]), state, include_current=True)
        outs.append(o)
    np.testing.assert_allclose(np.stack([np.asarray(o) for o in outs], 1),
                               np.asarray(full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(full_state),
                               rtol=2e-4, atol=2e-4)
