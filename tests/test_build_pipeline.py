"""Build-pipeline guarantees of the leaf-slab batch layer.

Two contracts the engine-backed build path must keep:

* *collection parity* — the batched training-data collection
  (``collect_training_data``) matches the seed per-leaf reference path on
  both backbones: RNG-derived artifacts (queries) bitwise, distance targets
  to float tolerance (they share the matmul decomposition; bitwise on CPU).
* *determinism* — ``build_leafi`` is a pure function of (series, config,
  key): building twice yields identical filters and tuner tables.  The seed
  per-leaf path owed its determinism to Python iteration order; the batched
  path must not regress it.
"""
import numpy as np
import jax
import pytest

from repro.core import build, filter_training, tree


@pytest.fixture(scope="module", params=["dstree", "isax"])
def index_small(request, randwalk_small):
    if request.param == "dstree":
        return tree.build_dstree(randwalk_small[:2500], leaf_capacity=64)
    return tree.build_isax(randwalk_small[:2500], leaf_capacity=64)


def _filtered_leaves(index, min_size=16, max_n=16):
    sizes = np.asarray(index.leaf_size)
    return np.arange(index.n_leaves)[sizes >= min_size][:max_n]


def test_collection_matches_reference(index_small):
    leaf_ids = _filtered_leaves(index_small)
    key = jax.random.PRNGKey(11)
    got = filter_training.collect_training_data(
        index_small, leaf_ids, n_global=48, n_local=12, key=key)
    want = filter_training._reference_collect_training_data(
        index_small, leaf_ids, n_global=48, n_local=12, key=key)
    # RNG-derived artifacts are bitwise (same key schedule, same host math)
    np.testing.assert_array_equal(got.global_queries, want.global_queries)
    np.testing.assert_array_equal(got.local_queries, want.local_queries)
    np.testing.assert_array_equal(got.leaf_ids, want.leaf_ids)
    np.testing.assert_array_equal(got.global_d_lb, want.global_d_lb)
    # distance targets share the matmul decomposition → float tolerance
    np.testing.assert_allclose(got.global_d_L, want.global_d_L,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got.local_d_L, want.local_d_L,
                               rtol=1e-4, atol=1e-4)


def test_local_queries_bitwise_and_loop_free(index_small):
    """The vmapped sampler must reproduce the sequential key schedule."""
    leaf_ids = _filtered_leaves(index_small, max_n=9)
    key = jax.random.PRNGKey(3)
    got = filter_training.make_local_queries(index_small, leaf_ids, 7, key)
    want = filter_training._reference_local_queries(
        index_small, leaf_ids, 7, key)
    np.testing.assert_array_equal(got, want)
    assert got.shape == (len(leaf_ids), 7, index_small.length)


def _build_twice(series, cfg):
    a = build.build_leafi(series, cfg, key=jax.random.PRNGKey(cfg.seed))
    b = build.build_leafi(series, cfg, key=jax.random.PRNGKey(cfg.seed))
    return a, b


@pytest.mark.parametrize("backbone", ["dstree", "isax"])
def test_build_is_deterministic(randwalk_small, backbone):
    cfg = build.LeaFiConfig(
        backbone=backbone, leaf_capacity=64, n_global=60, n_local=12,
        t_filter_over_t_series=10.0,
        train=filter_training.TrainConfig(epochs=4))
    a, b = _build_twice(randwalk_small[:1500], cfg)
    np.testing.assert_array_equal(a.leaf_ids, b.leaf_ids)
    assert a.filter_params is not None, "config must select some filters"
    for name in a.filter_params:
        np.testing.assert_array_equal(
            np.asarray(a.filter_params[name]),
            np.asarray(b.filter_params[name]), err_msg=name)
    np.testing.assert_array_equal(a.tuner.knots_q, b.tuner.knots_q)
    np.testing.assert_array_equal(a.tuner.knots_o, b.tuner.knots_o)
    np.testing.assert_array_equal(a.tuner.slopes, b.tuner.slopes)
    np.testing.assert_array_equal(a.tuner.max_offset, b.tuner.max_offset)


def test_build_dist_impl_plumbs_through(randwalk_small):
    """collect_training_data accepts an explicit slab impl; 'direct' and
    'matmul' targets agree to float tolerance."""
    index = tree.build_dstree(randwalk_small[:1500], leaf_capacity=64)
    leaf_ids = _filtered_leaves(index, max_n=6)
    key = jax.random.PRNGKey(0)
    a = filter_training.collect_training_data(
        index, leaf_ids, 24, 8, key, dist_impl="direct")
    b = filter_training.collect_training_data(
        index, leaf_ids, 24, 8, key, dist_impl="matmul")
    np.testing.assert_allclose(a.global_d_L, b.global_d_L,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(a.local_d_L, b.local_d_L,
                               rtol=1e-4, atol=1e-4)


def test_training_data_split_unchanged_by_calibration(randwalk_small):
    """build_leafi's calibration split must leave TrainingData fields
    consistent (regression guard for the engine-backed calibration)."""
    cfg = build.LeaFiConfig(
        backbone="dstree", leaf_capacity=64, n_global=60, n_local=12,
        t_filter_over_t_series=10.0,
        train=filter_training.TrainConfig(epochs=3))
    lfi = build.build_leafi(randwalk_small[:1500], cfg)
    assert lfi.tuner is not None
    assert lfi.build_report["t_collect"] > 0
    assert lfi.build_report["t_calibrate"] > 0
    # tuner knots are sorted qualities in [0, 1]
    q = lfi.tuner.knots_q
    assert (np.diff(q) > 0).all() and q[0] >= 0.0 and q[-1] <= 1.0 + 1e-6
