"""HLO stats parser: trip counts, flops, collective detection."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import hlo_stats


def test_scan_flops_count_trip_multiplied():
    W = jnp.zeros((256, 256), jnp.float32)

    def f_scan(x):
        def body(c, _):
            return c @ W, None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out

    def f_unroll(x):
        for _ in range(8):
            x = x @ W
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    expect = 2 * 256 ** 3 * 8
    for f in (f_scan, f_unroll):
        st = hlo_stats(jax.jit(f).lower(x).compile().as_text())
        assert st.flops == expect, (f.__name__, st.flops, expect)


def test_nested_scan_flops():
    W = jnp.zeros((128, 128), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ W, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    st = hlo_stats(jax.jit(f).lower(x).compile().as_text())
    assert st.flops == 2 * 128 ** 3 * 12


def test_f32_projection_halves_bytes():
    W = jnp.zeros((512, 512), jnp.float32)
    f = lambda x: x @ W                                 # noqa: E731
    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    hlo = jax.jit(f).lower(x).compile().as_text()
    raw = hlo_stats(hlo).hbm_bytes
    proj = hlo_stats(hlo, f32_as_bf16=True).hbm_bytes
    assert abs(proj * 2 - raw) / raw < 1e-6


@pytest.mark.skipif(jax.device_count() != 1, reason="needs subprocess devices")
def test_collectives_detected_in_sharded_program():
    """Run in a subprocess with 8 host devices: a psum must show up as an
    all-reduce with correct byte attribution."""
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import sys
sys.path.insert(0, "src")
from repro.analysis import hlo_stats
if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5 wants explicit axis types
    mesh = jax.make_mesh((8,), ("d",),
                         axis_types=(jax.sharding.AxisType.Auto,))
else:
    mesh = jax.make_mesh((8,), ("d",))
x = jax.ShapeDtypeStruct((1024, 512), jnp.float32)
w = jax.ShapeDtypeStruct((512, 256), jnp.float32)
f = lambda x, w: x @ w
c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "d")),
                             NamedSharding(mesh, P("d", None))),
            out_shardings=NamedSharding(mesh, P())).lower(x, w).compile()
st = hlo_stats(c.as_text())
assert st.bytes_by_kind.get("all-reduce", 0) > 0 or \
       st.bytes_by_kind.get("reduce-scatter", 0) > 0, st.bytes_by_kind
# contraction sharded 8-ways: per-device flops = total/8
assert abs(st.flops - 2*1024*512*256/8) / (2*1024*512*256/8) < 1e-6, st.flops
print("SUBPROCESS_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=240)
    assert "SUBPROCESS_OK" in r.stdout, r.stdout + r.stderr
