"""HLO stats parser: trip counts, flops, collective detection."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import hlo_stats


def test_scan_flops_count_trip_multiplied():
    W = jnp.zeros((256, 256), jnp.float32)

    def f_scan(x):
        def body(c, _):
            return c @ W, None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out

    def f_unroll(x):
        for _ in range(8):
            x = x @ W
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    expect = 2 * 256 ** 3 * 8
    for f in (f_scan, f_unroll):
        st = hlo_stats(jax.jit(f).lower(x).compile().as_text())
        assert st.flops == expect, (f.__name__, st.flops, expect)


def test_nested_scan_flops():
    W = jnp.zeros((128, 128), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ W, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    st = hlo_stats(jax.jit(f).lower(x).compile().as_text())
    assert st.flops == 2 * 128 ** 3 * 12


def test_f32_projection_halves_bytes():
    W = jnp.zeros((512, 512), jnp.float32)
    f = lambda x: x @ W                                 # noqa: E731
    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    hlo = jax.jit(f).lower(x).compile().as_text()
    raw = hlo_stats(hlo).hbm_bytes
    proj = hlo_stats(hlo, f32_as_bf16=True).hbm_bytes
    assert abs(proj * 2 - raw) / raw < 1e-6


@pytest.mark.skipif(jax.device_count() != 1, reason="needs subprocess devices")
def test_collectives_detected_in_sharded_program():
    """Run in a subprocess with 8 host devices: a psum must show up as an
    all-reduce with correct byte attribution."""
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import sys
sys.path.insert(0, "src")
from repro.analysis import hlo_stats
if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5 wants explicit axis types
    mesh = jax.make_mesh((8,), ("d",),
                         axis_types=(jax.sharding.AxisType.Auto,))
else:
    mesh = jax.make_mesh((8,), ("d",))
x = jax.ShapeDtypeStruct((1024, 512), jnp.float32)
w = jax.ShapeDtypeStruct((512, 256), jnp.float32)
f = lambda x, w: x @ w
c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "d")),
                             NamedSharding(mesh, P("d", None))),
            out_shardings=NamedSharding(mesh, P())).lower(x, w).compile()
st = hlo_stats(c.as_text())
assert st.bytes_by_kind.get("all-reduce", 0) > 0 or \
       st.bytes_by_kind.get("reduce-scatter", 0) > 0, st.bytes_by_kind
# contraction sharded 8-ways: per-device flops = total/8
assert abs(st.flops - 2*1024*512*256/8) / (2*1024*512*256/8) < 1e-6, st.flops
print("SUBPROCESS_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=240)
    assert "SUBPROCESS_OK" in r.stdout, r.stdout + r.stderr


def test_filter_mlp_roofline_structure():
    """Analytic filter-kernel bound: fused cuts the query re-stream bf×,
    drops the epilogue passes, and quantization cuts the dominant weight
    stream — all visible in the three-term model."""
    from repro.analysis.roofline import filter_mlp_roofline
    F, Q, m, h, bf = 1024, 128, 128, 128, 8
    per = filter_mlp_roofline(F, Q, m, h, variant="per_filter")
    fus = filter_mlp_roofline(F, Q, m, h, variant="fused", bf=bf)
    # single-chip kernel: no collective term; both memory-bound at this shape
    assert per.link_bytes_per_device == 0 and fus.link_bytes_per_device == 0
    assert per.dominant == "memory" and fus.dominant == "memory"
    # fused strictly cheaper on bytes, despite the group-sum flops overhead
    assert fus.hbm_bytes_per_device < per.hbm_bytes_per_device
    assert fus.flops_per_device > per.flops_per_device
    assert fus.bound_time < per.bound_time
    # exact traffic deltas: bf× query re-stream cut + 3 epilogue passes
    q_delta = (F - F // bf) * Q * m * 4
    epi = 3 * 2 * F * Q * 4
    assert per.hbm_bytes_per_device - fus.hbm_bytes_per_device == \
        q_delta + epi
    # quantization cuts exactly the w1/w2 element stream (biases/stats stay
    # f32; int8 adds two f32 scales per filter); the shared query stream
    # dilutes the whole-kernel ratio below the raw 4x/2x element cut
    f32 = filter_mlp_roofline(F, Q, m, h, variant="fused")
    i8 = filter_mlp_roofline(F, Q, m, h, variant="fused",
                             weight_dtype="int8")
    bf16 = filter_mlp_roofline(F, Q, m, h, variant="fused",
                               weight_dtype="bfloat16")
    n_w = m * h + h
    assert f32.hbm_bytes_per_device - i8.hbm_bytes_per_device == \
        F * (3 * n_w - 2 * 4)
    assert f32.hbm_bytes_per_device - bf16.hbm_bytes_per_device == F * 2 * n_w
    assert f32.hbm_bytes_per_device / i8.hbm_bytes_per_device > 2.5
    assert f32.hbm_bytes_per_device / bf16.hbm_bytes_per_device > 1.5
    with pytest.raises(ValueError):
        filter_mlp_roofline(8, 8, 8, variant="nope")
