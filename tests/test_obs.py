"""Observability subsystem (`repro.obs`): registry instruments, span
recording, Chrome trace export, the recall-drift hook, and the serve-level
trace-determinism pin.

The determinism contract under test: with an injected ``service_time``,
every registry instrument not declared ``wall=True`` and every non-``ts``/
``dur`` field of the exported Chrome trace is bitwise-reproducible across
two seeded serving runs — wall-clock may appear *only* in the snapshot's
``"wall"`` subtree and in the trace's ``ts``/``dur`` fields.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro import obs
from repro.core import build, filter_training
from repro.launch.serve import _print_serve_report
from repro.obs import export
from repro.obs.metrics import MetricsRegistry, RecallDriftMonitor
from repro.obs.spans import SpanRecorder
from repro.serving import (MicroBatcher, ServingSession, Telemetry,
                          poisson_trace)


# ---------------------------------------------------------------------------
# metrics registry: counters / gauges / histograms
# ---------------------------------------------------------------------------

def test_counter_labels_and_monotonicity():
    r = MetricsRegistry()
    c = r.counter("reqs", help="requests")
    c.inc()
    c.inc(2.0)
    c.inc(5, target="0.9")
    assert c.value() == 3.0
    assert c.value(target="0.9") == 5.0
    assert c.value(target="0.99") == 0.0
    with pytest.raises(ValueError, match="negative"):
        c.inc(-1)


def test_gauge_last_write_wins():
    r = MetricsRegistry()
    g = r.gauge("depth")
    assert g.value(default=-1.0) == -1.0
    g.set(3)
    g.set(7, lane="a")
    g.set(4)
    assert g.value() == 4.0
    assert g.value(lane="a") == 7.0


def test_histogram_lifetime_vs_window():
    r = MetricsRegistry()
    h = r.histogram("lat", window=4)
    h.extend([1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    assert h.count() == 6                       # lifetime survives overflow
    assert h.window_values() == [3.0, 4.0, 5.0, 6.0]
    p = h.percentiles((50,))
    assert p["p50"] == pytest.approx(4.5)
    h.reset_window()
    assert h.window_values() == []
    assert h.count() == 6                       # lifetime survives the flush
    assert np.isnan(h.percentiles((50,))["p50"])   # empty window: NaN, no raise


def test_registry_idempotent_creation_and_kind_mismatch():
    r = MetricsRegistry()
    a = r.counter("x")
    assert r.counter("x") is a                  # second creation: same object
    with pytest.raises(ValueError, match="already registered"):
        r.gauge("x")


def test_snapshot_segregates_wall_instruments():
    r = MetricsRegistry()
    r.counter("n").inc(3)
    r.histogram("virt", window=8).observe(1.0)
    r.histogram("wallclock_s", window=8, wall=True).observe(0.125)
    snap = r.snapshot()
    assert snap["counters"]["n"] == 3.0
    assert snap["histograms"]["virt"]["count"] == 1
    assert "wallclock_s" not in snap["histograms"]
    assert snap["wall"]["histograms"]["wallclock_s"]["count"] == 1
    json.dumps(snap)                            # snapshot is JSON-clean


def test_delta_reports_counter_movement():
    r = MetricsRegistry()
    c = r.counter("n")
    c.inc(2)
    prev = r.snapshot()
    c.inc(3, target="0.9")
    d = r.delta(prev)
    assert d == {'n{target=0.9}': 3.0}


def test_jsonl_and_prometheus_export(tmp_path):
    r = MetricsRegistry()
    r.counter("serve_requests_total").inc(5)
    r.histogram("serve_latency_s", window=8).extend([0.1, 0.2, 0.3])
    r.histogram("empty_h", window=8)            # registered, never observed
    jl = tmp_path / "m.jsonl"
    export.write_metrics(jl, r)
    rows = [json.loads(line) for line in jl.read_text().splitlines()]
    by_name = {row["name"]: row for row in rows}
    assert by_name["serve_requests_total"]["value"] == 5.0
    assert by_name["serve_latency_s"]["count"] == 3
    assert "empty_h" not in by_name             # no series yet → no row
    prom = tmp_path / "m.prom"
    export.write_metrics(prom, r)
    text = prom.read_text()
    assert "# TYPE serve_requests_total counter" in text
    assert "serve_requests_total 5.0" in text
    assert 'serve_latency_s{quantile="0.5"}' in text
    assert "serve_latency_s_count 3" in text


# ---------------------------------------------------------------------------
# recall-drift monitor (ROADMAP item 1's recalibration hook)
# ---------------------------------------------------------------------------

def test_recall_drift_flag_needs_min_samples_then_fires_and_clears():
    r = MetricsRegistry()
    mon = RecallDriftMonitor(r, window=16, min_samples=8)
    for _ in range(7):
        mon.observe(0.95, False)
    assert mon.drifting() == {0.95: False}      # below min_samples: no flag
    mon.observe(0.95, False)
    assert mon.drifting() == {0.95: True}
    assert mon.any_drifting()
    assert r.gauge("serve_recall_drift").value(target="0.95") == 1.0
    assert r.gauge("serve_recall_windowed").value(target="0.95") == 0.0
    for _ in range(16):                         # window fills with hits
        mon.observe(0.95, True)
    assert mon.drifting() == {0.95: False}
    assert r.gauge("serve_recall_drift").value(target="0.95") == 0.0
    assert mon.windowed_recall()[0.95] == 1.0


def test_telemetry_surfaces_drift_in_summary():
    tel = Telemetry(drift_window=16, drift_min_samples=4)
    for _ in range(6):
        tel.observe_recall(0.9, False)
    assert tel.recall_drifting() == {0.9: True}
    s = tel.summary()
    assert s["recall_drifting"] == {0.9: True}
    assert s["recall_windowed"][0.9] == 0.0
    assert s["recall_by_target"][0.9]["n"] == 6


# ---------------------------------------------------------------------------
# telemetry facade: registry-backed, NaN-safe when empty
# ---------------------------------------------------------------------------

def test_fresh_telemetry_is_nan_safe_everywhere():
    tel = Telemetry()
    assert np.isnan(tel.latency_percentiles()["p50"])
    assert np.isnan(tel.pruning_ratio())
    s = tel.summary()
    assert s["n_requests"] == 0 and s["n_batches"] == 0
    assert np.isnan(s["p99"])
    assert "phases" not in s                    # no wall-clock seen yet
    assert "recall_drifting" not in s
    assert not tel.latencies and len(tel.queue_wait) == 0


def test_telemetry_windows_are_registry_instruments():
    tel = Telemetry(window=8)
    tel.record_latency(0.25)
    tel.survivors.extend([2, 3, 4])             # pre-registry deque surface
    tel.record_phases(queue_wait=[0.001, 0.002], form_s=0.01, exec_s=0.02)
    snap = tel.snapshot()
    assert snap["histograms"]["serve_latency_s"]["count"] == 1
    assert snap["histograms"]["serve_survivor_leaves"]["sum"] == 9.0
    assert snap["histograms"]["serve_queue_wait_s"]["count"] == 2
    # host wall-clock phases live under the maskable "wall" subtree only
    assert "serve_form_s" not in snap["histograms"]
    assert snap["wall"]["histograms"]["serve_form_s"]["count"] == 1
    assert snap["wall"]["histograms"]["serve_exec_s"]["count"] == 1
    assert list(tel.survivors) == [2.0, 3.0, 4.0]
    tel.flush_windows()
    assert len(tel.latencies) == 0
    assert tel.snapshot()["histograms"]["serve_latency_s"]["count"] == 1


# ---------------------------------------------------------------------------
# span recording
# ---------------------------------------------------------------------------

def test_recording_captures_nesting_and_restores_previous_recorder():
    before = obs.get_recorder()
    with obs.recording() as rec:
        assert obs.get_recorder() is rec
        with obs.span("outer", cat="t", a=1):
            with obs.span("inner", cat="t"):
                pass
    assert obs.get_recorder() is before
    inner, outer = rec.spans()                  # append order: close order
    assert (inner.name, inner.depth) == ("inner", 1)
    assert (outer.name, outer.depth) == ("outer", 0)
    assert outer.args == {"a": 1}
    assert inner.lane == outer.lane == 0        # dense lanes, not thread ids
    assert outer.dur >= inner.dur >= 0.0


def test_recorder_is_bounded_and_drains():
    rec = SpanRecorder(maxlen=4)
    for i in range(10):
        with rec.span(f"s{i}"):
            pass
    got = rec.drain()
    assert [s.name for s in got] == ["s6", "s7", "s8", "s9"]
    assert rec.spans() == []


def test_disabled_recorder_records_nothing():
    rec = SpanRecorder(enabled=False)
    with rec.span("x"):
        pass
    assert rec.spans() == []


# ---------------------------------------------------------------------------
# chrome trace export
# ---------------------------------------------------------------------------

def _demo_batch_log():
    return [
        # serial run_trace entry: no t_disp → one combined execute slice
        {"bucket": 4, "n_valid": 3, "k": 1, "service": 0.01,
         "rids": [0, 1, 2], "wall": 0.02},
        # pipelined entry: dispatch / in-flight / harvest lanes
        {"bucket": 8, "n_valid": 8, "k": 1, "service": 0.01,
         "rids": list(range(3, 11)), "t_disp": 10.0, "dispatch_s": 0.001,
         "t_done": 10.5, "harvest_s": 0.002},
    ]


def test_chrome_trace_lane_layout():
    with obs.recording() as rec:
        with obs.span("build.train", cat="build", n_filters=3):
            pass
    trace = export.chrome_trace(spans=rec.drain(),
                                batch_log=_demo_batch_log())
    evs = trace["traceEvents"]
    lanes = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert lanes == {"serve/dispatch", "serve/in-flight", "serve/harvest",
                     "spans/lane0"}
    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    serial = xs["batch[4x k=1]"]
    assert serial["tid"] == 1 and serial["ts"] == 0.0
    assert serial["dur"] == pytest.approx(0.02 * 1e6)
    assert serial["args"]["n_requests"] == 3
    assert xs["dispatch batch[8x k=1]"]["tid"] == 1
    assert xs["in-flight batch[8x k=1]"]["tid"] == 2
    assert xs["harvest batch[8x k=1]"]["tid"] == 3
    span_ev = xs["build.train"]
    assert span_ev["tid"] == 10 and span_ev["args"] == {"n_filters": 3,
                                                        "depth": 0}


def test_mask_wallclock_zeroes_only_ts_dur():
    trace = export.chrome_trace(batch_log=_demo_batch_log())
    masked = export.mask_wallclock(trace)
    for e in masked["traceEvents"]:
        if e["ph"] == "X":
            assert e["ts"] == 0.0 and e["dur"] == 0.0
    # non-wall-clock fields survive untouched; the input is not mutated
    assert ([(e["name"], e.get("args")) for e in masked["traceEvents"]]
            == [(e["name"], e.get("args")) for e in trace["traceEvents"]])
    assert any(e.get("dur", 0.0) > 0.0 for e in trace["traceEvents"])


def test_write_chrome_trace_roundtrips(tmp_path):
    path = tmp_path / "trace.json"
    trace = export.write_chrome_trace(path, batch_log=_demo_batch_log())
    loaded = json.loads(path.read_text())
    assert loaded == json.loads(json.dumps(trace))
    assert loaded["displayTimeUnit"] == "ms"


# ---------------------------------------------------------------------------
# cascade-trace host helpers (device-side semantics: tests/test_engine.py)
# ---------------------------------------------------------------------------

def test_cascade_trace_host_helpers():
    z = obs.zero_trace(3)
    assert all(np.asarray(f).shape == (3,) for f in z)
    t = obs.CascadeTrace(*(np.full((3,), i, np.int32)
                           for i in range(len(z._fields))))
    both = obs.combine(t, t)
    assert np.array_equal(np.asarray(both.pruned_filter),
                          np.asarray(t.pruned_filter) * 2)
    sel = obs.select(np.asarray([True, False, True]), t, z)
    assert np.asarray(sel.survivors).tolist() == [4, 0, 4]
    d = obs.to_numpy(t)
    assert set(d) == set(t._fields)
    assert d["distances"].dtype == np.int64
    # residual: n_leaves = Σpruned + survivors + probed ⇒ zero
    n_leaves = int(0 + 1 + 2 + 3 + 4)
    assert np.asarray(obs.accounting_residual(t, n_leaves)).tolist() \
        == [0, 0, 0]


# ---------------------------------------------------------------------------
# serve-level determinism + zero-request regression (needs a built index)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lfi_obs(randwalk_small):
    cfg = build.LeaFiConfig(backbone="dstree", leaf_capacity=64,
                            n_global=120, n_local=24,
                            t_filter_over_t_series=10.0,
                            train=filter_training.TrainConfig(epochs=20))
    return build.build_leafi(randwalk_small[:2000], cfg)


def _serve_once(lfi, trace, oracle):
    tel = Telemetry(drift_window=32, drift_min_samples=8)
    session = ServingSession(lfi, telemetry=tel)
    with obs.recording() as rec:
        report = session.serve(
            trace, batcher=MicroBatcher(max_batch=8, max_wait=0.004),
            recall_oracle=oracle, service_time=lambda b: 0.002)
    chrome = export.mask_wallclock(export.chrome_trace(
        spans=rec.drain(), batch_log=report["batches"]))
    return report, tel.snapshot(), chrome


def test_serve_observability_is_deterministic_modulo_wallclock(
        lfi_obs, queries_small):
    trace = poisson_trace(queries_small, rate=500.0, n_requests=48,
                          targets=(0.9, 0.99), seed=5)
    session = ServingSession(lfi_obs)
    exact = session.search_exact(queries_small)
    oracle = {r.rid: float(np.asarray(exact.dists)[r.pool_row, 0])
              for r in trace}
    rep1, snap1, chrome1 = _serve_once(lfi_obs, trace, oracle)
    rep2, snap2, chrome2 = _serve_once(lfi_obs, trace, oracle)
    assert rep1["n_requests"] == 48

    # wall-clock leaked somewhere it shouldn't ⇒ these dumps differ
    def masked(snap):
        s = dict(snap)
        wall = s.pop("wall")
        return s, wall
    s1, wall1 = masked(snap1)
    s2, _ = masked(snap2)
    assert json.dumps(s1, sort_keys=True) == json.dumps(s2, sort_keys=True)
    assert json.dumps(chrome1, sort_keys=True) \
        == json.dumps(chrome2, sort_keys=True)

    # ... and the run did populate every layer being compared
    assert s1["counters"]["serve_requests_total"] == 48.0
    assert s1["histograms"]["serve_latency_s"]["count"] == 48
    assert wall1["histograms"]["serve_form_s"]["count"] == rep1["n_batches"]
    assert any(k.startswith("serve_recall_windowed") for k in s1["gauges"])
    spans_seen = {e["name"] for e in chrome1["traceEvents"]
                  if e["ph"] == "X"}
    assert "serve.dispatch" in spans_seen and "serve.harvest" in spans_seen


def test_zero_request_serve_report_is_nan_safe(lfi_obs, capsys):
    session = ServingSession(lfi_obs)
    report = session.serve([], service_time=lambda b: 0.001)
    assert report["n_requests"] == 0
    assert "throughput_qps" not in report
    assert np.isnan(report["p50"])
    _print_serve_report(report)                 # must not raise (regression)
    out = capsys.readouterr().out
    assert "0 requests" in out and "no completions" in out
    assert session.telemetry.summary()["n_requests"] == 0
