"""Observability subsystem (`repro.obs`): registry instruments, span
recording, Chrome trace export, the recall-drift hook, and the serve-level
trace-determinism pin.

The determinism contract under test: with an injected ``service_time``,
every registry instrument not declared ``wall=True`` and every non-``ts``/
``dur`` field of the exported Chrome trace is bitwise-reproducible across
two seeded serving runs — wall-clock may appear *only* in the snapshot's
``"wall"`` subtree and in the trace's ``ts``/``dur`` fields.
"""
from __future__ import annotations

import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro import obs
from repro.core import bounds, build, engine, filter_training, tree
from repro.data.series import make_query_set
from repro.launch.serve import _print_serve_report
from repro.obs import audit as obs_audit
from repro.obs import explain as obs_explain
from repro.obs import export
from repro.obs.health import LeafHealthBoard
from repro.obs.metrics import MetricsRegistry, RecallDriftMonitor
from repro.obs.spans import SpanRecorder
from repro.serving import (BsfCache, MicroBatcher, ServingSession,
                          Telemetry, poisson_trace)
from repro.serving.shadow import explain_query, leaf_of_ids, sample_mask


# ---------------------------------------------------------------------------
# metrics registry: counters / gauges / histograms
# ---------------------------------------------------------------------------

def test_counter_labels_and_monotonicity():
    r = MetricsRegistry()
    c = r.counter("reqs", help="requests")
    c.inc()
    c.inc(2.0)
    c.inc(5, target="0.9")
    assert c.value() == 3.0
    assert c.value(target="0.9") == 5.0
    assert c.value(target="0.99") == 0.0
    with pytest.raises(ValueError, match="negative"):
        c.inc(-1)


def test_gauge_last_write_wins():
    r = MetricsRegistry()
    g = r.gauge("depth")
    assert g.value(default=-1.0) == -1.0
    g.set(3)
    g.set(7, lane="a")
    g.set(4)
    assert g.value() == 4.0
    assert g.value(lane="a") == 7.0


def test_histogram_lifetime_vs_window():
    r = MetricsRegistry()
    h = r.histogram("lat", window=4)
    h.extend([1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    assert h.count() == 6                       # lifetime survives overflow
    assert h.window_values() == [3.0, 4.0, 5.0, 6.0]
    p = h.percentiles((50,))
    assert p["p50"] == pytest.approx(4.5)
    h.reset_window()
    assert h.window_values() == []
    assert h.count() == 6                       # lifetime survives the flush
    assert np.isnan(h.percentiles((50,))["p50"])   # empty window: NaN, no raise


def test_registry_idempotent_creation_and_kind_mismatch():
    r = MetricsRegistry()
    a = r.counter("x")
    assert r.counter("x") is a                  # second creation: same object
    with pytest.raises(ValueError, match="already registered"):
        r.gauge("x")


def test_snapshot_segregates_wall_instruments():
    r = MetricsRegistry()
    r.counter("n").inc(3)
    r.histogram("virt", window=8).observe(1.0)
    r.histogram("wallclock_s", window=8, wall=True).observe(0.125)
    snap = r.snapshot()
    assert snap["counters"]["n"] == 3.0
    assert snap["histograms"]["virt"]["count"] == 1
    assert "wallclock_s" not in snap["histograms"]
    assert snap["wall"]["histograms"]["wallclock_s"]["count"] == 1
    json.dumps(snap)                            # snapshot is JSON-clean


def test_delta_reports_counter_movement():
    r = MetricsRegistry()
    c = r.counter("n")
    c.inc(2)
    prev = r.snapshot()
    c.inc(3, target="0.9")
    d = r.delta(prev)
    assert d == {'n{target=0.9}': 3.0}


def test_jsonl_and_prometheus_export(tmp_path):
    r = MetricsRegistry()
    r.counter("serve_requests_total").inc(5)
    r.histogram("serve_latency_s", window=8).extend([0.1, 0.2, 0.3])
    r.histogram("empty_h", window=8)            # registered, never observed
    jl = tmp_path / "m.jsonl"
    export.write_metrics(jl, r)
    rows = [json.loads(line) for line in jl.read_text().splitlines()]
    by_name = {row["name"]: row for row in rows}
    assert by_name["serve_requests_total"]["value"] == 5.0
    assert by_name["serve_latency_s"]["count"] == 3
    assert "empty_h" not in by_name             # no series yet → no row
    prom = tmp_path / "m.prom"
    export.write_metrics(prom, r)
    text = prom.read_text()
    assert "# TYPE serve_requests_total counter" in text
    assert "serve_requests_total 5.0" in text
    assert 'serve_latency_s{quantile="0.5"}' in text
    assert "serve_latency_s_count 3" in text


def test_prometheus_escapes_pathological_label_values(tmp_path):
    """Prometheus 0.0.4 label-value escaping: backslash, quote and newline
    must come out as \\\\, \\" and \\n — a raw newline would split the
    exposition line and corrupt the whole scrape."""
    r = MetricsRegistry()
    evil = 'a\\b"c\nd'
    r.counter("evil_total").inc(1, path=evil)
    prom = tmp_path / "m.prom"
    export.write_metrics(prom, r)
    text = prom.read_text()
    assert 'evil_total{path="a\\\\b\\"c\\nd"} 1.0' in text
    # the value never splits its exposition line
    metric_lines = [ln for ln in text.splitlines()
                    if ln.startswith("evil_total{")]
    assert len(metric_lines) == 1
    assert metric_lines[0].endswith(" 1.0")


# ---------------------------------------------------------------------------
# recall-drift monitor (ROADMAP item 1's recalibration hook)
# ---------------------------------------------------------------------------

def test_recall_drift_flag_needs_min_samples_then_fires_and_clears():
    r = MetricsRegistry()
    mon = RecallDriftMonitor(r, window=16, min_samples=8)
    for _ in range(7):
        mon.observe(0.95, False)
    assert mon.drifting() == {0.95: False}      # below min_samples: no flag
    mon.observe(0.95, False)
    assert mon.drifting() == {0.95: True}
    assert mon.any_drifting()
    assert r.gauge("serve_recall_drift").value(target="0.95") == 1.0
    assert r.gauge("serve_recall_windowed").value(target="0.95") == 0.0
    for _ in range(16):                         # window fills with hits
        mon.observe(0.95, True)
    assert mon.drifting() == {0.95: False}
    assert r.gauge("serve_recall_drift").value(target="0.95") == 0.0
    assert mon.windowed_recall()[0.95] == 1.0


def test_telemetry_surfaces_drift_in_summary():
    tel = Telemetry(drift_window=16, drift_min_samples=4)
    for _ in range(6):
        tel.observe_recall(0.9, False)
    assert tel.recall_drifting() == {0.9: True}
    s = tel.summary()
    assert s["recall_drifting"] == {0.9: True}
    assert s["recall_windowed"][0.9] == 0.0
    assert s["recall_by_target"][0.9]["n"] == 6


# ---------------------------------------------------------------------------
# telemetry facade: registry-backed, NaN-safe when empty
# ---------------------------------------------------------------------------

def test_fresh_telemetry_is_nan_safe_everywhere():
    tel = Telemetry()
    assert np.isnan(tel.latency_percentiles()["p50"])
    assert np.isnan(tel.pruning_ratio())
    s = tel.summary()
    assert s["n_requests"] == 0 and s["n_batches"] == 0
    assert np.isnan(s["p99"])
    assert "phases" not in s                    # no wall-clock seen yet
    assert "recall_drifting" not in s
    assert not tel.latencies and len(tel.queue_wait) == 0


def test_telemetry_windows_are_registry_instruments():
    tel = Telemetry(window=8)
    tel.record_latency(0.25)
    tel.survivors.extend([2, 3, 4])             # pre-registry deque surface
    tel.record_phases(queue_wait=[0.001, 0.002], form_s=0.01, exec_s=0.02)
    snap = tel.snapshot()
    assert snap["histograms"]["serve_latency_s"]["count"] == 1
    assert snap["histograms"]["serve_survivor_leaves"]["sum"] == 9.0
    assert snap["histograms"]["serve_queue_wait_s"]["count"] == 2
    # host wall-clock phases live under the maskable "wall" subtree only
    assert "serve_form_s" not in snap["histograms"]
    assert snap["wall"]["histograms"]["serve_form_s"]["count"] == 1
    assert snap["wall"]["histograms"]["serve_exec_s"]["count"] == 1
    assert list(tel.survivors) == [2.0, 3.0, 4.0]
    tel.flush_windows()
    assert len(tel.latencies) == 0
    assert tel.snapshot()["histograms"]["serve_latency_s"]["count"] == 1


# ---------------------------------------------------------------------------
# span recording
# ---------------------------------------------------------------------------

def test_recording_captures_nesting_and_restores_previous_recorder():
    before = obs.get_recorder()
    with obs.recording() as rec:
        assert obs.get_recorder() is rec
        with obs.span("outer", cat="t", a=1):
            with obs.span("inner", cat="t"):
                pass
    assert obs.get_recorder() is before
    inner, outer = rec.spans()                  # append order: close order
    assert (inner.name, inner.depth) == ("inner", 1)
    assert (outer.name, outer.depth) == ("outer", 0)
    assert outer.args == {"a": 1}
    assert inner.lane == outer.lane == 0        # dense lanes, not thread ids
    assert outer.dur >= inner.dur >= 0.0


def test_recorder_is_bounded_and_drains():
    rec = SpanRecorder(maxlen=4)
    for i in range(10):
        with rec.span(f"s{i}"):
            pass
    got = rec.drain()
    assert [s.name for s in got] == ["s6", "s7", "s8", "s9"]
    assert rec.spans() == []


def test_disabled_recorder_records_nothing():
    rec = SpanRecorder(enabled=False)
    with rec.span("x"):
        pass
    assert rec.spans() == []


# ---------------------------------------------------------------------------
# chrome trace export
# ---------------------------------------------------------------------------

def _demo_batch_log():
    return [
        # serial run_trace entry: no t_disp → one combined execute slice
        {"bucket": 4, "n_valid": 3, "k": 1, "service": 0.01,
         "rids": [0, 1, 2], "wall": 0.02},
        # pipelined entry: dispatch / in-flight / harvest lanes
        {"bucket": 8, "n_valid": 8, "k": 1, "service": 0.01,
         "rids": list(range(3, 11)), "t_disp": 10.0, "dispatch_s": 0.001,
         "t_done": 10.5, "harvest_s": 0.002},
    ]


def test_chrome_trace_lane_layout():
    with obs.recording() as rec:
        with obs.span("build.train", cat="build", n_filters=3):
            pass
    trace = export.chrome_trace(spans=rec.drain(),
                                batch_log=_demo_batch_log())
    evs = trace["traceEvents"]
    lanes = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert lanes == {"serve/dispatch", "serve/in-flight", "serve/harvest",
                     "spans/lane0"}
    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    serial = xs["batch[4x k=1]"]
    assert serial["tid"] == 1 and serial["ts"] == 0.0
    assert serial["dur"] == pytest.approx(0.02 * 1e6)
    assert serial["args"]["n_requests"] == 3
    assert xs["dispatch batch[8x k=1]"]["tid"] == 1
    assert xs["in-flight batch[8x k=1]"]["tid"] == 2
    assert xs["harvest batch[8x k=1]"]["tid"] == 3
    span_ev = xs["build.train"]
    assert span_ev["tid"] == 10 and span_ev["args"] == {"n_filters": 3,
                                                        "depth": 0}


def test_mask_wallclock_zeroes_only_ts_dur():
    trace = export.chrome_trace(batch_log=_demo_batch_log())
    masked = export.mask_wallclock(trace)
    for e in masked["traceEvents"]:
        if e["ph"] == "X":
            assert e["ts"] == 0.0 and e["dur"] == 0.0
    # non-wall-clock fields survive untouched; the input is not mutated
    assert ([(e["name"], e.get("args")) for e in masked["traceEvents"]]
            == [(e["name"], e.get("args")) for e in trace["traceEvents"]])
    assert any(e.get("dur", 0.0) > 0.0 for e in trace["traceEvents"])


def test_write_chrome_trace_roundtrips(tmp_path):
    path = tmp_path / "trace.json"
    trace = export.write_chrome_trace(path, batch_log=_demo_batch_log())
    loaded = json.loads(path.read_text())
    assert loaded == json.loads(json.dumps(trace))
    assert loaded["displayTimeUnit"] == "ms"


# ---------------------------------------------------------------------------
# cascade-trace host helpers (device-side semantics: tests/test_engine.py)
# ---------------------------------------------------------------------------

def test_cascade_trace_host_helpers():
    z = obs.zero_trace(3)
    assert all(np.asarray(f).shape == (3,) for f in z)
    t = obs.CascadeTrace(*(np.full((3,), i, np.int32)
                           for i in range(len(z._fields))))
    both = obs.combine(t, t)
    assert np.array_equal(np.asarray(both.pruned_filter),
                          np.asarray(t.pruned_filter) * 2)
    sel = obs.select(np.asarray([True, False, True]), t, z)
    assert np.asarray(sel.survivors).tolist() == [4, 0, 4]
    d = obs.to_numpy(t)
    assert set(d) == set(t._fields)
    assert d["distances"].dtype == np.int64
    # residual: n_leaves = Σpruned + survivors + probed ⇒ zero
    n_leaves = int(0 + 1 + 2 + 3 + 4)
    assert np.asarray(obs.accounting_residual(t, n_leaves)).tolist() \
        == [0, 0, 0]


# ---------------------------------------------------------------------------
# serve-level determinism + zero-request regression (needs a built index)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lfi_obs(randwalk_small):
    cfg = build.LeaFiConfig(backbone="dstree", leaf_capacity=64,
                            n_global=120, n_local=24,
                            t_filter_over_t_series=10.0,
                            train=filter_training.TrainConfig(epochs=20))
    return build.build_leafi(randwalk_small[:2000], cfg)


def _serve_once(lfi, trace, oracle):
    tel = Telemetry(drift_window=32, drift_min_samples=8)
    session = ServingSession(lfi, telemetry=tel)
    with obs.recording() as rec:
        report = session.serve(
            trace, batcher=MicroBatcher(max_batch=8, max_wait=0.004),
            recall_oracle=oracle, service_time=lambda b: 0.002)
    chrome = export.mask_wallclock(export.chrome_trace(
        spans=rec.drain(), batch_log=report["batches"]))
    return report, tel.snapshot(), chrome


def test_serve_observability_is_deterministic_modulo_wallclock(
        lfi_obs, queries_small):
    trace = poisson_trace(queries_small, rate=500.0, n_requests=48,
                          targets=(0.9, 0.99), seed=5)
    session = ServingSession(lfi_obs)
    exact = session.search_exact(queries_small)
    oracle = {r.rid: float(np.asarray(exact.dists)[r.pool_row, 0])
              for r in trace}
    rep1, snap1, chrome1 = _serve_once(lfi_obs, trace, oracle)
    rep2, snap2, chrome2 = _serve_once(lfi_obs, trace, oracle)
    assert rep1["n_requests"] == 48

    # wall-clock leaked somewhere it shouldn't ⇒ these dumps differ
    def masked(snap):
        s = dict(snap)
        wall = s.pop("wall")
        return s, wall
    s1, wall1 = masked(snap1)
    s2, _ = masked(snap2)
    assert json.dumps(s1, sort_keys=True) == json.dumps(s2, sort_keys=True)
    assert json.dumps(chrome1, sort_keys=True) \
        == json.dumps(chrome2, sort_keys=True)

    # ... and the run did populate every layer being compared
    assert s1["counters"]["serve_requests_total"] == 48.0
    assert s1["histograms"]["serve_latency_s"]["count"] == 48
    assert wall1["histograms"]["serve_form_s"]["count"] == rep1["n_batches"]
    assert any(k.startswith("serve_recall_windowed") for k in s1["gauges"])
    spans_seen = {e["name"] for e in chrome1["traceEvents"]
                  if e["ph"] == "X"}
    assert "serve.dispatch" in spans_seen and "serve.harvest" in spans_seen


def test_zero_request_serve_report_is_nan_safe(lfi_obs, capsys):
    session = ServingSession(lfi_obs)
    report = session.serve([], service_time=lambda b: 0.001)
    assert report["n_requests"] == 0
    assert "throughput_qps" not in report
    assert np.isnan(report["p50"])
    _print_serve_report(report)                 # must not raise (regression)
    out = capsys.readouterr().out
    assert "0 requests" in out and "no completions" in out
    assert session.telemetry.summary()["n_requests"] == 0


# ---------------------------------------------------------------------------
# per-leaf audit: engine-level pins (both backbones x both strategies)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=["dstree", "isax"])
def obs_index(request, randwalk_small):
    builder = (tree.build_dstree if request.param == "dstree"
               else tree.build_isax)
    return builder(randwalk_small, 64)


def _cascade(index, q, d_lb, d_F, k, strategy, **kw):
    return engine.run_cascade(
        jnp.asarray(index.series), jnp.asarray(index.leaf_start),
        jnp.asarray(index.leaf_size), q, d_lb, d_F,
        k=k, max_leaf=index.max_leaf_size, strategy=strategy, **kw)


def _synthetic_predictions(d_lb, seed=0):
    """Deterministic noisy per-leaf NN 'predictions' → real filter pruning
    (same construction tests/test_engine.py prunes with)."""
    lb = np.asarray(d_lb)
    rng = np.random.default_rng(seed)
    noise = rng.standard_normal(lb.shape).astype(np.float32)
    return jnp.asarray(lb * (1.4 + 0.4 * noise) + 2.0)


@pytest.mark.parametrize("strategy", ["scan", "compact"])
def test_audit_results_bitwise_and_per_leaf_identity(
        obs_index, queries_small, strategy):
    """audit=True returns bitwise-identical answers and counters, and the
    per-leaf accounting identity partitions the query batch exactly."""
    q = jnp.asarray(queries_small)
    n_queries = q.shape[0]
    d_lb = bounds.lower_bounds(obs_index, q)
    d_F = _synthetic_predictions(d_lb)
    for k in (1, 5):
        a = _cascade(obs_index, q, d_lb, d_F, k, strategy)
        b = _cascade(obs_index, q, d_lb, d_F, k, strategy, audit=True)
        np.testing.assert_array_equal(np.asarray(a.topk_d),
                                      np.asarray(b.topk_d))
        np.testing.assert_array_equal(np.asarray(a.topk_i),
                                      np.asarray(b.topk_i))
        np.testing.assert_array_equal(np.asarray(a.n_searched),
                                      np.asarray(b.n_searched))
        fa = b.audit
        assert not np.asarray(obs_audit.accounting_residual_leaf(
            fa, n_queries)).any()
        fa_np = obs_audit.to_numpy(fa)
        # the synthetic cascade is active and audited as such
        assert fa_np["pruned_filter"].sum() > 0
        assert fa_np["kept"].sum() > 0
        # residual bookkeeping: histogram mass == observations, violations
        # are a subset, scored >= kept (union co-residents score for free)
        np.testing.assert_array_equal(fa_np["resid_buckets"].sum(-1),
                                      fa_np["resid_count"])
        assert (fa_np["violations"] <= fa_np["resid_count"]).all()
        assert (fa_np["scored"] >= fa_np["kept"]).all()
        assert (fa_np["resid_count"] <= fa_np["scored"]).all()
        # resid_min is +inf exactly where nothing was observed
        unobserved = fa_np["resid_count"] == 0
        assert np.isinf(fa_np["resid_min"][unobserved]).all()
        assert np.isfinite(fa_np["resid_min"][~unobserved]).all()


@pytest.mark.parametrize("strategy", ["scan", "compact"])
def test_trace_attributes_warm_start_seed_prunes(
        obs_index, queries_small, strategy):
    """BsfCache-seeded bsf_ub: answers stay bitwise (exact mode) and the
    accounting identity still partitions the leaf set exactly — on both
    strategies.  The attribution itself is strategy-shaped: the scan visits
    leaves in ascending-lb order, so by the time any leaf has lb > ub every
    leaf holding a true top-k member (lb ≤ d_k ≤ ub) is already scanned and
    the converged bsf dominates any *valid* bound — seed-only prunes are
    impossible there (pinned at exactly zero).  The compact strategy
    attributes at the mask stage against the probe seed bsf0, which a warm
    bound undercuts whenever the probe leaf is not the k-NN leaf — so its
    pruned_seed is live (pinned > 0)."""
    q = jnp.asarray(queries_small)
    L = obs_index.n_leaves
    d_lb = bounds.lower_bounds(obs_index, q)
    d_F = jnp.full(d_lb.shape, -jnp.inf)
    cold = _cascade(obs_index, q, d_lb, d_F, 1, strategy, trace=True)
    # no warm bound → nothing can be seed-attributed
    assert np.asarray(cold.trace.pruned_seed).sum() == 0
    assert not np.asarray(obs.accounting_residual(cold.trace, L)).any()

    cache = BsfCache()
    cache.update(queries_small, np.asarray(cold.topk_d)[:, 0], k=1)
    ub = cache.seed(queries_small, k=1)
    assert ub is not None and np.isfinite(ub).all()
    warm = _cascade(obs_index, q, d_lb, d_F, 1, strategy, trace=True,
                    bsf_ub=jnp.asarray(ub))
    # prune-only contract: bitwise answers, never more leaves searched
    np.testing.assert_array_equal(np.asarray(cold.topk_d),
                                  np.asarray(warm.topk_d))
    np.testing.assert_array_equal(np.asarray(cold.topk_i),
                                  np.asarray(warm.topk_i))
    assert (np.asarray(warm.n_searched)
            <= np.asarray(cold.n_searched)).all()
    seed_prunes = np.asarray(warm.trace.pruned_seed).sum()
    if strategy == "scan":
        assert seed_prunes == 0         # ascending-lb order: see docstring
    else:
        assert seed_prunes > 0          # probe bsf0 undercut by the bound
    assert not np.asarray(obs.accounting_residual(warm.trace, L)).any()
    # per-leaf audit agrees with the per-query trace on the attribution
    audited = _cascade(obs_index, q, d_lb, d_F, 1, strategy, audit=True,
                       bsf_ub=jnp.asarray(ub))
    fa_np = obs_audit.to_numpy(audited.audit)
    assert fa_np["pruned_seed"].sum() == seed_prunes
    assert not np.asarray(obs_audit.accounting_residual_leaf(
        audited.audit, q.shape[0])).any()


@settings(max_examples=8)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
       backbone=st.sampled_from(["dstree", "isax"]),
       strategy=st.sampled_from(["scan", "compact"]))
def test_accounting_residual_zero_property(seed, backbone, strategy):
    """Property: the trace accounting residual is zero per query and the
    audit identity is zero per leaf, across random leaf layouts, random
    filter planes and random (valid) warm-start bounds, both backbones."""
    rng = np.random.default_rng(seed)
    S = rng.standard_normal((512, 32), dtype=np.float32).cumsum(axis=1)
    cap = int(8 + (seed % 5) * 12)              # leaf layout varies w/ seed
    builder = tree.build_dstree if backbone == "dstree" else tree.build_isax
    index = builder(S, cap)
    queries = make_query_set(S, 4, noise=0.3, seed=seed % 997)
    q = jnp.asarray(queries)
    d_lb = bounds.lower_bounds(index, q)
    no_f = jnp.full(d_lb.shape, -jnp.inf)
    keep = jnp.asarray(rng.random(d_lb.shape) < 0.5)
    d_F = jnp.where(keep, no_f, _synthetic_predictions(d_lb, seed=seed))
    # a valid prune-only bound: the exact nn, inflated
    exact = _cascade(index, q, d_lb, no_f, 1, strategy)
    ub = np.asarray(exact.topk_d)[:, 0] * (1 + 1e-6) + 1e-6
    res = _cascade(index, q, d_lb, d_F, 1, strategy, trace=True,
                   audit=True, bsf_ub=jnp.asarray(ub))
    assert not np.asarray(
        obs.accounting_residual(res.trace, index.n_leaves)).any()
    assert not np.asarray(
        obs_audit.accounting_residual_leaf(res.audit, 4)).any()


# ---------------------------------------------------------------------------
# shadow sampler: pure helpers
# ---------------------------------------------------------------------------

def test_sample_mask_is_deterministic_and_batching_invariant():
    rids = np.arange(1000)
    whole = sample_mask(rids, 0.25, seed=3)
    split = np.concatenate([sample_mask(rids[:137], 0.25, seed=3),
                            sample_mask(rids[137:], 0.25, seed=3)])
    np.testing.assert_array_equal(whole, split)   # batching-invariant
    np.testing.assert_array_equal(whole, sample_mask(rids, 0.25, seed=3))
    assert 0.15 < whole.mean() < 0.35             # roughly the asked rate
    assert not sample_mask(rids, 0.0, seed=3).any()
    assert sample_mask(rids, 1.0, seed=3).all()
    # the seed offsets the hash, so a distant seed shadows a different set
    assert (whole != sample_mask(rids, 0.25, seed=1 << 31)).any()


def test_leaf_of_ids_names_the_holding_leaf(obs_index):
    rng = np.random.default_rng(0)
    order = np.asarray(obs_index.order)
    ids = rng.integers(0, order.shape[0], 64)
    leaves = leaf_of_ids(obs_index, ids)
    starts = np.asarray(obs_index.leaf_start)
    sizes = np.asarray(obs_index.leaf_size)
    assert ((0 <= leaves) & (leaves < obs_index.n_leaves)).all()
    for i, leaf in zip(ids, leaves):
        members = order[starts[leaf]: starts[leaf] + sizes[leaf]]
        assert i in members, (i, leaf)


# ---------------------------------------------------------------------------
# leaf-health scoreboard (unit level; serve-level wiring below)
# ---------------------------------------------------------------------------

def _audit_dict(L, **cols):
    base = {k: np.zeros(L, np.int64)
            for k in ("violations", "resid_count", "scored", "kept",
                      "pruned_box", "pruned_seed", "pruned_filter",
                      "rows_saved")}
    base["resid_sum"] = np.zeros(L, np.float64)
    base["resid_min"] = np.full(L, np.inf)
    for k, v in cols.items():
        base[k] = np.asarray(v)
    return base


def test_health_board_flags_reasons_and_severity_order():
    r = MetricsRegistry()
    board = LeafHealthBoard(window=4, registry=r, min_resid_count=8,
                            violation_rate_threshold=0.05,
                            resid_min_threshold=-0.5)
    # leaf 1: high violation rate; leaf 2: one deep violation (too few
    # observations for the rate flag); leaves 0/3 healthy
    board.record_audit(_audit_dict(
        4, violations=[0, 3, 1, 0], resid_count=[9, 10, 2, 9],
        resid_min=[0.2, -0.05, -1.0, 0.3]), n_queries=16)
    # shadow truth: two filter-attributed misses at leaf 3, one box-
    # attributed miss at leaf 0 (float-tie noise → must NOT flag)
    board.record_shadow([{"leaf": 3, "bound": "filter"},
                         {"leaf": 3, "bound": "filter"},
                         {"leaf": 0, "bound": "box"}], n_queries=8)
    reps = board.filters_needing_attention()
    # ground truth outranks rates; higher rate outranks lower
    assert [rep.leaf for rep in reps] == [3, 2, 1]
    by_leaf = {rep.leaf: rep for rep in reps}
    assert by_leaf[3].reasons == ["shadow-miss"]
    assert by_leaf[3].shadow_misses == 2
    assert by_leaf[2].reasons == ["deep-violation"]
    assert by_leaf[1].reasons == ["violation-rate"]
    assert by_leaf[1].violation_rate == pytest.approx(0.3)
    assert board.filters_needing_attention(limit=1)[0].leaf == 3
    # registry surface: lifetime counters + windowed flag gauge
    assert r.counter("health_violations_total").value() == 4.0
    assert r.counter("health_shadow_misses_total").value(bound="filter") \
        == 2.0
    assert r.gauge("health_flagged_leaves").value() == 3.0
    json.dumps(board.snapshot())                # JSON-clean
    board.reset()                               # post-recalibration flush
    assert board.filters_needing_attention() == []
    assert r.gauge("health_flagged_leaves").value() == 0.0


def test_health_board_rejects_mismatched_leaf_count():
    board = LeafHealthBoard()
    board.record_audit(_audit_dict(4), n_queries=2)
    with pytest.raises(ValueError, match="leaves"):
        board.record_audit(_audit_dict(5), n_queries=2)


# ---------------------------------------------------------------------------
# serve-level: shadow recall vs calibration, injected staleness, explain
# ---------------------------------------------------------------------------

def _serve_shadowed(lfi, queries, n_requests=64, target=0.95, rate=1.0):
    trace = poisson_trace(queries, rate=500.0, n_requests=n_requests,
                          targets=(target,), ks=(1,), seed=11)
    session = ServingSession(lfi, audit=True, shadow_rate=rate,
                             shadow_seed=7)
    report = session.serve(
        trace, batcher=MicroBatcher(max_batch=8, max_wait=0.004),
        service_time=lambda b: 0.002)
    return session, report


def test_shadow_recall_agrees_with_calibration_estimate(
        lfi_obs, queries_small):
    """Acceptance pin: shadow-sampled *true* recall agrees with the
    calibration-split estimate within the binomial CI (+ slack for the
    finite calibration split itself)."""
    target = 0.95
    session, report = _serve_shadowed(lfi_obs, queries_small,
                                      target=target)
    sh = report["shadow"]
    assert sh["n_shadowed"] == 64               # rate=1.0 shadows everything
    calib = min(target,
                float(lfi_obs.build_report.get("calib_best_quality", 1.0)))
    ci = 1.96 * np.sqrt(calib * (1.0 - calib) / sh["n_shadowed"])
    assert abs(sh["recall_mean"] - calib) <= ci + 0.05, (sh["recall_mean"],
                                                         calib, ci)
    for m in sh["misses"]:                      # every miss fully attributed
        assert m["bound"] in ("box", "seed", "filter", "timing")
        assert 0 <= m["leaf"] < lfi_obs.index.n_leaves
        assert "rid" in m and "d_F" in m
    # the audit stream reached the health board alongside the shadow stream
    assert session.telemetry.health.n_leaves == lfi_obs.index.n_leaves
    assert session.shadow.summary()["n_shadowed"] == 64


def test_injected_stale_filter_is_flagged_with_correct_leaf(
        lfi_obs, queries_small):
    """Acceptance pin: perturbing one leaf's conformal offset (smaller
    offset → larger adjusted prediction → over-pruning) must surface that
    exact leaf at the top of filters_needing_attention()."""
    exact = lfi_obs.search_exact(queries_small, k=1)
    nn_leaves = leaf_of_ids(lfi_obs.index, np.asarray(exact.ids)[:, 0])
    filtered = set(int(leaf) for leaf in lfi_obs.leaf_ids)
    cand = np.asarray([leaf for leaf in nn_leaves if int(leaf) in filtered])
    assert cand.size, "no filtered leaf holds a pool query's true NN"
    target_leaf = int(np.bincount(cand).argmax())
    f_idx = int(np.nonzero(
        np.asarray(lfi_obs.leaf_ids) == target_leaf)[0][0])

    tuner = lfi_obs.tuner
    knots_o = np.asarray(tuner.knots_o).copy()
    max_off = np.asarray(tuner.max_offset).copy()
    knots_o[f_idx] -= 1e3                       # d_F = pred − offset → huge
    max_off[f_idx] -= 1e3
    stale = dataclasses.replace(
        lfi_obs, tuner=dataclasses.replace(
            tuner, knots_o=knots_o.astype(np.float32),
            max_offset=max_off.astype(np.float32)))

    session, report = _serve_shadowed(stale, queries_small)
    flagged = session.telemetry.filters_needing_attention()
    assert flagged, "stale filter went unflagged"
    top = flagged[0]
    assert top.leaf == target_leaf              # the *correct* leaf id
    assert "shadow-miss" in top.reasons
    assert top.shadow_misses >= 1
    # every one of those misses is shadow-confirmed against exact truth and
    # attributed to the filter bound at the injected leaf
    guilty = [m for m in report["shadow"]["misses"]
              if m["leaf"] == target_leaf]
    assert guilty and all(m["bound"] == "filter" for m in guilty)
    # the summary surfaces the same list (the recalibration trigger)
    summary = session.telemetry.summary()
    assert summary["filters_needing_attention"][0]["leaf"] == target_leaf

    # control: the unperturbed index never accumulates that many confirmed
    # filter misses at the injected leaf
    clean_session, clean_report = _serve_shadowed(lfi_obs, queries_small)
    clean_guilty = [m for m in clean_report["shadow"]["misses"]
                    if m["leaf"] == target_leaf and m["bound"] == "filter"]
    assert len(clean_guilty) < len(guilty)


def test_explain_query_gathers_and_renders(lfi_obs, queries_small):
    session = ServingSession(lfi_obs, audit=True)
    ctx = explain_query(session, queries_small[0], target=0.95, k=3, rid=7)
    assert ctx["rid"] == 7 and ctx["k"] == 3
    assert len(ctx["served"]["dists"]) == 3
    cas = ctx["cascade"]
    assert cas["n_leaves"] == lfi_obs.index.n_leaves
    assert 0 < cas["searched"] <= cas["n_leaves"]
    # single-query audit planes render as per-leaf verdicts, closest first
    assert ctx["leaves"]
    assert {row["verdict"] for row in ctx["leaves"]} \
        <= {"kept", "box", "seed", "filter"}
    assert any(row["verdict"] == "kept" for row in ctx["leaves"])
    lbs = [row["d_lb"] for row in ctx["leaves"]]
    assert lbs == sorted(lbs)
    assert 0.0 <= ctx["shadow"]["recall"] <= 1.0
    text = obs_explain.render_text(ctx)
    assert "explain rid=7 k=3" in text
    assert "served kNN" in text and "cascade:" in text
    assert "shadow truth" in text
    json.loads(obs_explain.render_json(ctx))    # valid JSON round-trip


# ---------------------------------------------------------------------------
# bench smoke (slow): the audit-overhead pin's code path cannot rot
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_obs_bench_trace_audit_smoke():
    from benchmarks.obs_bench import bench_trace_audit
    rows, payload = bench_trace_audit(n=3000, m=64, leaf_capacity=64,
                                      n_queries=8, k=3, repeat=2)
    assert "max_compact_audit_overhead_pct" in payload
    assert len(payload["levels"]) == 4
    assert any("obs/max_compact_audit_overhead" in row for row in rows)
