"""Property tests (hypothesis): the lower-bound invariant.

For any index and any query:  lb(q, leaf) ≤ min_{s ∈ leaf} ||q − s||.
This is the correctness foundation of the whole pruning cascade — if it
holds, exact search can never lose the true nearest neighbor.
"""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import bounds, summaries, tree


def _check_lb(index, queries):
    lb = np.asarray(bounds.lower_bounds(index, jnp.asarray(queries)))
    series = np.asarray(index.series)
    for li in range(index.n_leaves):
        s = int(index.leaf_start[li])
        z = int(index.leaf_size[li])
        d = np.sqrt(((queries[:, None, :] - series[None, s:s + z]) ** 2)
                    .sum(-1)).min(1)
        assert (lb[:, li] <= d + 1e-3).all(), \
            f"LB violated at leaf {li}: {lb[:, li]} > {d}"


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.integers(80, 400),
       m=st.sampled_from([16, 40, 64]),
       cap=st.sampled_from([16, 50]),
       backbone=st.sampled_from(["dstree", "isax"]))
def test_lower_bound_never_exceeds_true_distance(seed, n, m, cap, backbone):
    rng = np.random.default_rng(seed)
    S = rng.standard_normal((n, m), dtype=np.float32).cumsum(axis=1)
    if backbone == "dstree":
        idx = tree.build_dstree(S, leaf_capacity=cap, n_segments=4)
    else:
        idx = tree.build_isax(S, leaf_capacity=cap, word_len=4)
    q = summaries.znormalize(
        S[rng.integers(0, n, 8)]
        + rng.standard_normal((8, m), dtype=np.float32))
    _check_lb(idx, q)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_eapca_bound_math(seed):
    """Direct check of the segment inequality used by the DSTree bound."""
    rng = np.random.default_rng(seed)
    q = rng.standard_normal(32).astype(np.float32)
    x = rng.standard_normal(32).astype(np.float32)
    true = np.sqrt(((q - x) ** 2).sum())
    qs = np.asarray(summaries.segment_stats(jnp.asarray(q)[None], 4))[0]
    xs = np.asarray(summaries.segment_stats(jnp.asarray(x)[None], 4))[0]
    seg_len = np.full(4, 8.0, np.float32)
    lb2 = (seg_len * ((qs[:, 0] - xs[:, 0]) ** 2
                      + (qs[:, 1] - xs[:, 1]) ** 2)).sum()
    assert np.sqrt(lb2) <= true + 1e-4


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.integers(1, 8))
def test_sax_symbol_edges_contain_value(seed, bits):
    """A PAA value always lies inside its own SAX symbol's box."""
    rng = np.random.default_rng(seed)
    vals = rng.standard_normal((64,)).astype(np.float32) * 2
    sym = np.asarray(summaries.sax_from_paa(jnp.asarray(vals), bits))
    edges = summaries.sax_symbol_edges(sym[None], np.full((1, 64), bits))
    lo, hi = edges[0, :, 0], edges[0, :, 1]
    assert (vals >= lo - 1e-6).all() and (vals <= hi + 1e-6).all()
