"""Optimizer, schedules, data pipeline, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, int8_compress, int8_decompress)


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = adamw_init(params)
    target = jnp.asarray([1.0, 2.0, -1.0])

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: ((p["w"] - target) ** 2).sum())(params)
        return adamw_update(params, grads, state, cfg)

    for _ in range(300):
        params, state, gnorm = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    grads = {"w": jnp.full(4, 1e6)}
    _, _, gnorm = adamw_update(params, grads, state, cfg)
    assert float(gnorm) > 1e5         # reported norm is pre-clip


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, 1000, 100)) < 0.02
    assert abs(float(cosine_schedule(100, 1000, 100)) - 1.0) < 1e-6
    assert float(cosine_schedule(1000, 1000, 100)) <= 0.11


def test_token_pipeline_deterministic_and_disjoint():
    cfg = TokenPipelineConfig(vocab_size=1000, seq_len=64, global_batch=8)
    pipe = TokenPipeline(cfg)
    a = pipe.host_batch(step=3, shard=0, n_shards=4)
    b = pipe.host_batch(step=3, shard=0, n_shards=4)
    assert (a["tokens"] == b["tokens"]).all()          # deterministic
    c = pipe.host_batch(step=3, shard=1, n_shards=4)
    assert not (a["tokens"] == c["tokens"]).all()      # shards differ
    d = pipe.host_batch(step=4, shard=0, n_shards=4)
    assert not (a["tokens"] == d["tokens"]).all()      # steps differ
    # labels are next-token shifted views of the same stream
    assert a["tokens"].shape == (2, 64)
    assert (a["tokens"] < 1000).all() and (a["tokens"] >= 0).all()


def test_token_pipeline_zipf_skew():
    cfg = TokenPipelineConfig(vocab_size=5000, seq_len=256, global_batch=16)
    pipe = TokenPipeline(cfg)
    t = pipe.host_batch(0)["tokens"].ravel()
    # low ids should dominate under a zipfian marginal
    assert (t < 50).mean() > 0.3


def test_int8_compression_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((333, 170)), jnp.float32) * 3
    q, scale = int8_compress(x)
    y = int8_decompress(q, scale, x.shape)
    err = float(jnp.abs(y - x).max() / jnp.abs(x).max())
    assert err < 0.02                 # 1/127 block quantization
    assert q.dtype == jnp.int8
