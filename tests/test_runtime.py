"""Fault tolerance drill: heartbeat → straggler → elastic re-mesh."""
from repro.runtime import (ElasticMeshManager, HeartbeatRegistry,
                           StragglerDetector)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_detects_silence():
    clock = FakeClock()
    reg = HeartbeatRegistry(range(4), timeout_s=10, clock=clock)
    clock.t = 5
    for h in (0, 1, 2):
        reg.beat(h)
    clock.t = 12
    assert reg.dead_hosts() == [3]
    assert reg.live_hosts() == [0, 1, 2]


def test_straggler_quarantine_after_patience():
    det = StragglerDetector(range(8), patience=3, k_sigma=3.0)
    for step in range(6):
        for h in range(8):
            det.observe(h, 1.0 if h != 5 else 9.0)
        bad = det.check()
    assert bad == [5]


def test_straggler_recovers_on_good_steps():
    det = StragglerDetector(range(4), patience=3)
    for h in range(4):
        det.observe(h, 1.0)
    # one slow round: a strike, but no quarantine
    for h in range(4):
        det.observe(h, 5.0 if h == 2 else 1.0)
    assert det.check() == []
    # many good rounds: EWMA decays back, strikes reset, never quarantined
    for _ in range(20):
        for h in range(4):
            det.observe(h, 1.0)
        assert det.check() == []


def test_elastic_plan_shrinks_data_axis():
    # 16×16 = 256 devices = 64 hosts of 4 devices
    mgr = ElasticMeshManager(data=16, model=16, pods=1, devices_per_host=4)
    full = mgr.plan(list(range(64)))
    assert (full.data, full.model, full.pods) == (16, 16, 1)
    # lose 8 hosts → 56 live → data shrinks to 8 (largest pow2 fitting)
    plan = mgr.plan(list(range(56)))
    assert plan.model == 16                 # TP width is structural
    assert plan.data * plan.model <= 56 * 4
    assert plan.data in (8, 16) and plan.data * 16 <= 224


def test_elastic_drops_whole_pod():
    mgr = ElasticMeshManager(data=16, model=16, pods=2, devices_per_host=4)
    # 128 hosts total, one pod entirely unreachable
    plan = mgr.plan(list(range(64)))
    assert plan.pods == 1
    assert plan.dropped_hosts == list(range(64, 128))


def test_end_to_end_failure_drill(tmp_path):
    """Kill a host → registry notices → plan shrinks → resume from ckpt."""
    import jax.numpy as jnp
    from repro.checkpoint import CheckpointManager

    clock = FakeClock()
    reg = HeartbeatRegistry(range(8), timeout_s=5, clock=clock)
    mgr = ElasticMeshManager(data=4, model=2, pods=1, devices_per_host=1)
    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    state = {"w": jnp.ones(4), "step": jnp.int32(100)}
    ckpt.save(100, state)

    clock.t = 3
    for h in range(7):
        reg.beat(h)                        # host 7 dies silently
    clock.t = 7                            # 7−3 = 4 ≤ 5 alive; 7−0 = 7 dead
    dead = reg.dead_hosts()
    assert dead == [7]
    plan = mgr.plan(reg.live_hosts(), total_hosts=8)
    assert plan.data * plan.model <= len(reg.live_hosts())
    restored, meta = ckpt.restore(like=state)
    assert meta["step"] == 100             # resume point
