"""Tests for the invariant linter (`repro.analysis.lint`).

Each rule is exercised against small fixture files under
``tests/lint_fixtures/`` — a positive fixture that must trip the rule and a
negative fixture encoding the blessed idiom that must stay clean.  The
pragma machinery, JSON output, CLI entry point, and exit-code contract are
covered here too, plus a meta-test that the real source tree lints clean
with every suppression carrying a reason.
"""
from __future__ import annotations

import json
import pathlib

import pytest

from repro.analysis.lint import RULES, run_lint
from repro.analysis.lint.__main__ import main

FIXTURES = pathlib.Path(__file__).parent / "lint_fixtures"
REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _lint_fixture(name):
    report = run_lint([str(FIXTURES / name)], root=str(FIXTURES))
    assert not report.errors, report.errors
    return report


def _by_rule(report, rule):
    return [f for f in report.findings if f.rule == rule]


# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------

def test_all_five_rules_registered():
    assert set(RULES) == {"LF001", "LF002", "LF003", "LF004", "LF005"}
    for r in RULES.values():
        assert r.title and r.doc


# ---------------------------------------------------------------------------
# LF001 — dynamic shapes / host syncs in jit-reachable code
# ---------------------------------------------------------------------------

def test_lf001_positive_fixture_trips():
    report = _lint_fixture("lf001_pos.py")
    findings = _by_rule(report, "LF001")
    # nonzero, bool-mask subscript, .item(), int(tracer) inside the jit fn,
    # and jnp.unique in the helper reached from the jitted caller.
    assert len(findings) == 5, [f.render() for f in findings]
    texts = " ".join(f.message for f in findings)
    assert "nonzero" in texts
    assert "unique" in texts
    assert len({f.line for f in findings}) == 5


def test_lf001_negative_fixture_clean():
    report = _lint_fixture("lf001_neg.py")
    assert _by_rule(report, "LF001") == []


# ---------------------------------------------------------------------------
# LF002 — kernel ops exports must be referenced from the parity tests
# ---------------------------------------------------------------------------

def test_lf002_uncovered_export_trips():
    root = FIXTURES / "lf002_repo"
    report = run_lint([str(root / "src")], root=str(root))
    assert not report.errors, report.errors
    findings = _by_rule(report, "LF002")
    assert len(findings) == 1, [f.render() for f in findings]
    assert "`uncovered_op`" in findings[0].message
    assert "_private_helper" not in findings[0].message
    assert "`covered_op`" not in findings[0].message


# ---------------------------------------------------------------------------
# LF003 — reads after buffer donation
# ---------------------------------------------------------------------------

def test_lf003_read_after_donation_trips():
    report = _lint_fixture("lf003_pos.py")
    findings = _by_rule(report, "LF003")
    assert len(findings) == 1, [f.render() for f in findings]
    assert "`state`" in findings[0].message


def test_lf003_rebind_idiom_clean():
    report = _lint_fixture("lf003_neg.py")
    assert _by_rule(report, "LF003") == []


# ---------------------------------------------------------------------------
# LF004 — recompile hazards at jitted call sites
# ---------------------------------------------------------------------------

def test_lf004_loop_var_and_unhashable_trip():
    report = _lint_fixture("lf004_pos.py")
    findings = _by_rule(report, "LF004")
    assert len(findings) == 2, [f.render() for f in findings]
    texts = " ".join(f.message for f in findings)
    assert "loop variable" in texts
    assert "unhashable" in texts


def test_lf004_hoisted_static_clean():
    report = _lint_fixture("lf004_neg.py")
    assert _by_rule(report, "LF004") == []


# ---------------------------------------------------------------------------
# LF005 — benchmark suites need artifacts + Makefile targets
# ---------------------------------------------------------------------------

def test_lf005_missing_artifact_and_target_trip():
    root = FIXTURES / "lf005_repo"
    report = run_lint([str(root / "benchmarks")], root=str(root))
    assert not report.errors, report.errors
    findings = _by_rule(report, "LF005")
    assert len(findings) == 2, [f.render() for f in findings]
    texts = " ".join(f.message for f in findings)
    assert "`noartifact`" in texts
    assert "`notarget`" in texts
    assert "`good`" not in texts


# ---------------------------------------------------------------------------
# pragmas
# ---------------------------------------------------------------------------

def test_pragma_with_reason_suppresses():
    report = _lint_fixture("pragma_ok.py")
    assert report.findings == [], [f.render() for f in report.findings]
    assert len(report.suppressed) == 1
    entry = report.suppressed[0]
    assert entry["finding"].rule == "LF001"
    assert entry["reason"] == "fixture-documented exception"


def test_reasonless_and_unknown_pragmas_rejected():
    report = _lint_fixture("pragma_bad.py")
    # Neither pragma suppresses its LF001 finding; both also raise LF000.
    assert report.suppressed == []
    lf000 = _by_rule(report, "LF000")
    lf001 = _by_rule(report, "LF001")
    assert len(lf000) == 2, [f.render() for f in lf000]
    assert len(lf001) == 2, [f.render() for f in lf001]
    texts = " ".join(f.message for f in lf000)
    assert "without a reason" in texts
    assert "LF999" in texts


# ---------------------------------------------------------------------------
# CLI: JSON output and exit codes
# ---------------------------------------------------------------------------

def test_cli_json_findings_exit_1(capsys):
    rc = main([str(FIXTURES / "lf001_pos.py"), "--root", str(FIXTURES),
               "--format", "json"])
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["errors"] == []
    assert payload["exit_code"] == 1
    assert {f["rule"] for f in payload["findings"]} == {"LF001"}
    sample = payload["findings"][0]
    assert {"rule", "path", "line", "message"} <= set(sample)


def test_cli_clean_exit_0(capsys):
    rc = main([str(FIXTURES / "lf001_neg.py"), "--root", str(FIXTURES),
               "--format", "json"])
    assert rc == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["findings"] == []


def test_cli_suppressed_only_exit_0(capsys):
    rc = main([str(FIXTURES / "pragma_ok.py"), "--root", str(FIXTURES)])
    assert rc == 0
    assert "1 suppressed" in capsys.readouterr().out


def test_cli_json_reports_suppressions_with_reasons(capsys):
    rc = main([str(FIXTURES / "pragma_ok.py"), "--root", str(FIXTURES),
               "--format", "json"])
    assert rc == 0
    payload = json.loads(capsys.readouterr().out)
    assert len(payload["suppressed"]) == 1
    assert payload["suppressed"][0]["reason"] == "fixture-documented exception"


def test_cli_unparseable_file_exit_2(tmp_path, capsys):
    bad = tmp_path / "broken.py"
    bad.write_text("def nope(:\n")
    rc = main([str(bad), "--root", str(tmp_path), "--format", "json"])
    assert rc == 2
    payload = json.loads(capsys.readouterr().out)
    assert payload["errors"]


def test_cli_unknown_rule_exit_2(capsys):
    rc = main([str(FIXTURES / "lf001_neg.py"), "--root", str(FIXTURES),
               "--rules", "LF042"])
    assert rc == 2
    assert "unknown rule" in capsys.readouterr().out


def test_cli_rule_filter(capsys):
    rc = main([str(FIXTURES / "lf001_pos.py"), "--root", str(FIXTURES),
               "--rules", "lf003", "--format", "json"])
    assert rc == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["findings"] == []
    assert payload["rules"] == ["LF003"]


def test_cli_list_rules(capsys):
    rc = main(["--list-rules"])
    assert rc == 0
    out = capsys.readouterr().out
    for rid in sorted(RULES):
        assert rid in out


# ---------------------------------------------------------------------------
# the real tree must lint clean with every suppression reasoned
# ---------------------------------------------------------------------------

def test_source_tree_lints_clean():
    report = run_lint([str(REPO_ROOT / "src")], root=str(REPO_ROOT))
    assert not report.errors, report.errors
    assert report.findings == [], [f.render() for f in report.findings]
    for entry in report.suppressed:
        assert entry["reason"], f"reasonless pragma: {entry['finding'].render()}"


def test_obs_package_lints_clean():
    """The observability package is jit-adjacent (CascadeTrace threads
    through the engine's compiled programs) and must land LF001-clean with
    zero suppressions — no host syncs hiding behind a pragma."""
    report = run_lint([str(REPO_ROOT / "src" / "repro" / "obs")],
                      root=str(REPO_ROOT))
    assert not report.errors, report.errors
    assert report.findings == [], [f.render() for f in report.findings]
    assert report.suppressed == []
    assert report.files >= 5      # __init__, trace, metrics, spans, export


@pytest.mark.parametrize("rule", sorted(RULES))
def test_every_rule_has_a_failing_fixture(rule):
    """Acceptance guard: each rule demonstrably fires on some fixture."""
    if rule in ("LF002", "LF005"):
        sub = "lf002_repo" if rule == "LF002" else "lf005_repo"
        root = FIXTURES / sub
        scan = root / ("src" if rule == "LF002" else "benchmarks")
        report = run_lint([str(scan)], root=str(root))
    else:
        report = _lint_fixture(f"{rule.lower()}_pos.py")
    assert _by_rule(report, rule), f"{rule} fired nowhere"
