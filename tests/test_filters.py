"""Filter backbones, weight quantization, and the quantized-recall pin.

Covers the parts of :mod:`repro.core.filters` the kernel tests don't: the
CNN/RNN ablation backbones (shape + dispatch through ``filters.APPLY`` and
``search.predictions_for_all_leaves``), the bf16/int8 weight compression
round-trip, the per-filter byte accounting, and the end-to-end guarantee
that quantizing a built index's filters *with conformal recalibration*
(:func:`repro.core.build.requantize_leafi`) holds recall on the calibration
split for both backbones.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build, conformal, filter_training, filters, search, tree

RNG = np.random.default_rng(11)


# ---------------------------------------------------------------------------
# CNN / RNN backbones: shapes, determinism, dispatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ftype", ["mlp", "cnn", "rnn"])
def test_backbone_apply_shapes(ftype):
    F, Q, m = 3, 5, 32
    params = filters.INIT[ftype](jax.random.PRNGKey(0), F, m)
    q = jnp.asarray(RNG.standard_normal((Q, m)), jnp.float32)
    out = filters.APPLY[ftype](params, q)
    assert out.shape == (F, Q)
    assert np.isfinite(np.asarray(out)).all()
    # uniform dispatch signature: use_kernel accepted by every backbone
    out2 = filters.APPLY[ftype](params, q, use_kernel=False)
    assert out2.shape == (F, Q)


def test_apply_cnn_rnn_destandardize():
    """y_mean/y_std stats must rescale CNN/RNN outputs like the MLP's."""
    F, Q, m = 2, 4, 16
    for ftype in ("cnn", "rnn"):
        params = filters.INIT[ftype](jax.random.PRNGKey(1), F, m)
        q = jnp.asarray(RNG.standard_normal((Q, m)), jnp.float32)
        base = np.asarray(filters.APPLY[ftype](params, q))
        params2 = dict(params)
        params2["y_mean"] = jnp.full((F,), 3.0)
        params2["y_std"] = jnp.full((F,), 2.0)
        scaled = np.asarray(filters.APPLY[ftype](params2, q))
        np.testing.assert_allclose(scaled, base * 2.0 + 3.0,
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("ftype", ["cnn", "rnn"])
def test_predictions_dispatch_reaches_ablation_backbones(ftype, randwalk_small):
    """search.predictions_for_all_leaves must route through filters.APPLY —
    the Table 1 ablation variants are reachable from search, offsets and
    the −inf no-filter convention included."""
    index = tree.build_dstree(randwalk_small[:600], leaf_capacity=64,
                              n_segments=8)
    L = index.n_leaves
    leaf_ids = np.arange(min(3, L))
    params = filters.INIT[ftype](jax.random.PRNGKey(2), len(leaf_ids),
                                 index.length)
    q = jnp.asarray(RNG.standard_normal((4, index.length)), jnp.float32)
    off = np.abs(RNG.standard_normal(len(leaf_ids))).astype(np.float32)
    got = np.asarray(search.predictions_for_all_leaves(
        index, params, leaf_ids, q, off, filter_type=ftype))
    assert got.shape == (4, L)
    want = np.asarray(filters.APPLY[ftype](params, q)) - off[:, None]
    np.testing.assert_allclose(got[:, leaf_ids], want.T, rtol=1e-5, atol=1e-5)
    unfiltered = np.setdiff1d(np.arange(L), leaf_ids)
    assert np.isneginf(got[:, unfiltered]).all()


def test_build_rejects_non_mlp_training():
    cfg = build.LeaFiConfig(filter_type="cnn")
    with pytest.raises(NotImplementedError):
        build.build_leafi(np.zeros((64, 16), np.float32), cfg)


# ---------------------------------------------------------------------------
# quantization round-trip + byte accounting
# ---------------------------------------------------------------------------


def _stack(F=6, m=48, h=32):
    return {
        "w1": jnp.asarray(RNG.standard_normal((F, m, h)) * 0.2, jnp.float32),
        "b1": jnp.asarray(RNG.standard_normal((F, h)) * 0.1, jnp.float32),
        "w2": jnp.asarray(RNG.standard_normal((F, h)) * 0.2, jnp.float32),
        "b2": jnp.asarray(RNG.standard_normal((F,)), jnp.float32),
        "y_mean": jnp.asarray(RNG.standard_normal((F,)), jnp.float32),
        "y_std": jnp.ones((F,), jnp.float32),
    }


def test_quantize_mlp_roundtrip_error_bound():
    p = _stack()
    q8 = filters.quantize_mlp(p, "int8")
    assert q8["w1"].dtype == jnp.int8 and q8["w2"].dtype == jnp.int8
    assert filters.mlp_weight_dtype(q8) == "int8"
    w1f, w2f = np.asarray(q8["w1"], np.float32), np.asarray(q8["w2"],
                                                            np.float32)
    w1d = w1f * np.asarray(q8["w1_scale"])[:, None, None]
    # symmetric max-abs/127: per-element error ≤ scale/2 by construction
    assert (np.abs(w1d - np.asarray(p["w1"]))
            <= np.asarray(q8["w1_scale"])[:, None, None] * 0.5 + 1e-7).all()
    assert (np.abs(w2f * np.asarray(q8["w2_scale"])[:, None]
                   - np.asarray(p["w2"]))
            <= np.asarray(q8["w2_scale"])[:, None] * 0.5 + 1e-7).all()
    # bf16: payload halves, float32 restores exactly the bf16 rounding
    qb = filters.quantize_mlp(p, "bfloat16")
    assert qb["w1"].dtype == jnp.bfloat16
    assert filters.mlp_weight_dtype(qb) == "bfloat16"
    back = filters.quantize_mlp(qb, "float32")
    assert back["w1"].dtype == jnp.float32
    assert "w1_scale" not in back
    np.testing.assert_array_equal(
        np.asarray(back["w1"]), np.asarray(qb["w1"], np.float32))
    # float32 is a no-op passthrough (and strips stale scales)
    p32 = filters.quantize_mlp(q8, "float32")
    assert p32["w1"].dtype == jnp.float32 and "w1_scale" not in p32


def test_mlp_param_bytes_table():
    m, h = 96, 64
    n_w = m * h + h                       # w1 + w2 elements
    n_f32 = h + 1 + 2                     # b1 + b2 + y_mean/y_std
    assert filters.mlp_param_bytes(m, h) == 4 * n_w + 4 * n_f32
    assert filters.mlp_param_bytes(m, h, "bfloat16") == 2 * n_w + 4 * n_f32
    assert filters.mlp_param_bytes(m, h, "int8") == (
        n_w + 4 * (n_f32 + 2))            # + two f32 scales
    # hidden defaults to length
    assert filters.mlp_param_bytes(m) == filters.mlp_param_bytes(m, m)
    # actual footprint of a quantized stack matches the accounting
    F = 5
    q8 = filters.quantize_mlp(_stack(F, m, h), "int8")
    nbytes = sum(np.asarray(v).nbytes for v in q8.values())
    assert nbytes == F * filters.mlp_param_bytes(m, h, "int8")


def test_apply_mlp_offset_matches_composition():
    p = _stack()
    q = jnp.asarray(RNG.standard_normal((9, 48)), jnp.float32)
    off = jnp.asarray(np.abs(RNG.standard_normal(6)), jnp.float32)
    for params in (p, filters.quantize_mlp(p, "int8")):
        want = np.asarray(filters.apply_mlp(params, q)) \
            - np.asarray(off)[:, None]
        got = np.asarray(filters.apply_mlp_offset(params, q, off))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# quantized recall on the calibration split (the end-to-end guarantee)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module", params=["dstree", "isax"])
def built_index(request, randwalk_small):
    cfg = build.LeaFiConfig(
        backbone=request.param, leaf_capacity=64, n_global=200, n_local=50,
        t_filter_over_t_series=10.0,
        train=filter_training.TrainConfig(epochs=40))
    return build.build_leafi(randwalk_small, cfg)


@pytest.mark.parametrize("weight_dtype", ["bfloat16", "int8"])
def test_quantized_recall_on_calibration_split(built_index, weight_dtype):
    """Quantize → recalibrate (requantize_leafi refits the auto-tuners on
    the quantized predictions) must hold recall@1 ≥ 0.99 at a 0.99 quality
    target on the calibration split, for both backbones × both dtypes."""
    lfi = built_index
    assert lfi.filter_params is not None and lfi.calib is not None
    lq = build.requantize_leafi(lfi, weight_dtype)
    assert filters.mlp_weight_dtype(lq.filter_params) == weight_dtype
    q = lq.calib.queries
    exact = lq.search_exact(q)
    res = lq.search(q, quality_target=0.99)
    recall = float(np.mean(np.asarray(conformal.recall_at_1(
        jnp.asarray(res.dists[:, 0]), jnp.asarray(exact.dists[:, 0])))))
    assert recall >= 0.99, (
        f"{lfi.config.backbone}/{weight_dtype}: calib recall {recall}")
    # and the filters still actually prune
    assert float(res.pruned_filter.mean()) > 0
