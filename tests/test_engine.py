"""Engine exactness parity: strategy="compact" vs strategy="scan" vs brute
force.

The compact path replays the sequential cascade over per-leaf top-k
summaries, so it must reproduce the scan path's top-k ids/dists AND its
pruning counters bitwise — including under active (lossy) filter pruning,
where the decisions depend on the evolving best-so-far.  These tests pin
that contract across backbones, k, filter regimes, and the adversarial
all-leaves-survive case.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import bounds, build, engine, filter_training, search, tree


@pytest.fixture(scope="module", params=["dstree", "isax"])
def index_small(request, randwalk_small):
    if request.param == "dstree":
        return tree.build_dstree(randwalk_small[:2000], leaf_capacity=64)
    return tree.build_isax(randwalk_small[:2000], leaf_capacity=64)


def _run(index, queries, d_lb, d_F, k, strategy):
    return engine.run_cascade(
        jnp.asarray(index.series), jnp.asarray(index.leaf_start),
        jnp.asarray(index.leaf_size), queries, d_lb, d_F,
        k=k, max_leaf=index.max_leaf_size, strategy=strategy)


def _assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a.topk_d), np.asarray(b.topk_d))
    np.testing.assert_array_equal(np.asarray(a.topk_i), np.asarray(b.topk_i))
    np.testing.assert_array_equal(np.asarray(a.n_searched),
                                  np.asarray(b.n_searched))
    np.testing.assert_array_equal(np.asarray(a.n_pruned_lb),
                                  np.asarray(b.n_pruned_lb))
    np.testing.assert_array_equal(np.asarray(a.n_pruned_filter),
                                  np.asarray(b.n_pruned_filter))


def _synthetic_predictions(d_lb, seed=0):
    """Deterministic noisy per-leaf NN 'predictions' → real filter pruning."""
    lb = np.asarray(d_lb)
    rng = np.random.default_rng(seed)
    noise = rng.standard_normal(lb.shape).astype(np.float32)
    return jnp.asarray(lb * (1.4 + 0.4 * noise) + 2.0)


@pytest.mark.parametrize("k", [1, 10])
def test_compact_matches_scan_bitwise_exact(index_small, queries_small, k):
    q = jnp.asarray(queries_small)
    d_lb = bounds.lower_bounds(index_small, q)
    d_F = jnp.full(d_lb.shape, -jnp.inf)
    a = _run(index_small, q, d_lb, d_F, k, "scan")
    b = _run(index_small, q, d_lb, d_F, k, "compact")
    _assert_bitwise(a, b)
    # compact must not have paid for more leaves than exist, nor fewer than
    # it reports as scanned
    assert (np.asarray(b.n_computed) <= index_small.n_leaves).all()
    assert (np.asarray(b.n_computed) >= np.asarray(b.n_searched)).all()


@pytest.mark.parametrize("k", [1, 10])
def test_compact_matches_scan_bitwise_with_filter_pruning(
        index_small, queries_small, k):
    q = jnp.asarray(queries_small)
    d_lb = bounds.lower_bounds(index_small, q)
    d_F = _synthetic_predictions(d_lb)
    a = _run(index_small, q, d_lb, d_F, k, "scan")
    b = _run(index_small, q, d_lb, d_F, k, "compact")
    assert np.asarray(a.n_pruned_filter).sum() > 0   # the cascade is active
    _assert_bitwise(a, b)


def test_all_leaves_survive_adversarial(index_small, queries_small):
    """Zero lower bounds + no filters: nothing prunes, the compact path must
    degrade to the full-width bucket (empty-pruning path) and stay exact."""
    q = jnp.asarray(queries_small)
    d_lb = jnp.zeros((q.shape[0], index_small.n_leaves), jnp.float32)
    d_F = jnp.full(d_lb.shape, -jnp.inf)
    a = _run(index_small, q, d_lb, d_F, 3, "scan")
    b = _run(index_small, q, d_lb, d_F, 3, "compact")
    _assert_bitwise(a, b)
    assert (np.asarray(b.n_computed) == index_small.n_leaves).all()
    assert (np.asarray(b.n_searched) == index_small.n_leaves).all()


def test_k_larger_than_leaf_capacity(index_small, queries_small):
    q = jnp.asarray(queries_small[:8])
    d_lb = bounds.lower_bounds(index_small, q)
    d_F = _synthetic_predictions(d_lb, seed=3)
    k = index_small.max_leaf_size + 17
    a = _run(index_small, q, d_lb, d_F, k, "scan")
    b = _run(index_small, q, d_lb, d_F, k, "compact")
    _assert_bitwise(a, b)


def _brute_force(index, queries, k):
    S = np.asarray(index.series[: index.n_series])
    d = np.sqrt(((queries[:, None, :] - S[None]) ** 2).sum(-1))
    rows = np.argsort(d, axis=1)[:, :k]
    return np.take_along_axis(d, rows, 1), np.asarray(index.order)[rows]


@pytest.mark.parametrize("strategy", ["scan", "compact"])
def test_exact_search_equals_brute_force(index_small, queries_small,
                                         strategy):
    res = search.search_batched(index_small, queries_small, k=5,
                                use_filters=False, strategy=strategy)
    want_d, want_i = _brute_force(index_small, queries_small, k=5)
    np.testing.assert_allclose(res.dists, want_d, rtol=1e-4)
    assert (np.sort(res.ids, 1) == np.sort(want_i, 1)).all()
    want_computed = (index_small.n_leaves if strategy == "scan"
                     else res.searched)
    assert (res.computed >= want_computed).all()


def test_leafi_end_to_end_strategies_agree(randwalk_small):
    """Built index with trained filters + conformal offsets: both engine
    strategies return identical results through the public search API."""
    cfg = build.LeaFiConfig(backbone="dstree", leaf_capacity=64,
                            n_global=60, n_local=16,
                            t_filter_over_t_series=10.0,
                            train=filter_training.TrainConfig(epochs=5))
    lfi = build.build_leafi(randwalk_small[:1500], cfg)
    rng = np.random.default_rng(11)
    q = (randwalk_small[rng.integers(0, 1500, 16)]
         + 0.25 * rng.standard_normal((16, randwalk_small.shape[1]))
         .astype(np.float32))
    for k in (1, 10):
        a = lfi.search(q, k=k, quality_target=0.99, strategy="scan")
        b = lfi.search(q, k=k, quality_target=0.99, strategy="compact")
        np.testing.assert_array_equal(a.dists, b.dists)
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.searched, b.searched)
        np.testing.assert_array_equal(a.pruned_lb, b.pruned_lb)
        np.testing.assert_array_equal(a.pruned_filter, b.pruned_filter)


@pytest.mark.parametrize("dist_impl", ["matmul", "pairwise"])
def test_lossy_impls_close_to_direct(index_small, queries_small, dist_impl):
    """The MXU distance impls (matmul decomposition; the union-slab pairwise
    kernel path) are numerically different from the scan path but must agree
    to float tolerance and make identical id choices on well-separated
    data."""
    q = jnp.asarray(queries_small[:8])
    d_lb = bounds.lower_bounds(index_small, q)
    d_F = jnp.full(d_lb.shape, -jnp.inf)
    a = _run(index_small, q, d_lb, d_F, 5, "scan")
    b = engine.run_cascade(
        jnp.asarray(index_small.series), jnp.asarray(index_small.leaf_start),
        jnp.asarray(index_small.leaf_size), q, d_lb, d_F,
        k=5, max_leaf=index_small.max_leaf_size, strategy="compact",
        dist_impl=dist_impl)
    np.testing.assert_allclose(np.asarray(a.topk_d), np.asarray(b.topk_d),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(a.topk_i), np.asarray(b.topk_i))


@pytest.mark.parametrize("k", [1, 10])
def test_pairwise_impl_with_filter_pruning(index_small, queries_small, k):
    """Union-slab pairwise candidates under an active filter cascade: the
    non-survivor leaves that ride along in the shared slab must never leak
    into results or counters (float-tolerance engine parity)."""
    q = jnp.asarray(queries_small)
    d_lb = bounds.lower_bounds(index_small, q)
    d_F = _synthetic_predictions(d_lb)
    a = _run(index_small, q, d_lb, d_F, k, "scan")
    b = engine.run_cascade(
        jnp.asarray(index_small.series), jnp.asarray(index_small.leaf_start),
        jnp.asarray(index_small.leaf_size), q, d_lb, d_F,
        k=k, max_leaf=index_small.max_leaf_size, strategy="compact",
        dist_impl="pairwise")
    assert np.asarray(a.n_pruned_filter).sum() > 0
    np.testing.assert_allclose(np.asarray(a.topk_d), np.asarray(b.topk_d),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(a.topk_i), np.asarray(b.topk_i))
    np.testing.assert_array_equal(np.asarray(a.n_searched),
                                  np.asarray(b.n_searched))
    np.testing.assert_array_equal(np.asarray(a.n_pruned_lb),
                                  np.asarray(b.n_pruned_lb))
    np.testing.assert_array_equal(np.asarray(a.n_pruned_filter),
                                  np.asarray(b.n_pruned_filter))


# ---------------------------------------------------------------------------
# shard_map-safe pieces: probe + fixed-width compact cascade (1-NN forms)
# ---------------------------------------------------------------------------


def _bsf_args(index):
    return (jnp.asarray(index.series), jnp.asarray(index.leaf_start),
            jnp.asarray(index.leaf_size))


def test_probe_best_leaf_skips_empty_leaves(index_small, queries_small):
    q = jnp.asarray(queries_small[:8])
    series, starts, sizes = _bsf_args(index_small)
    ml = index_small.max_leaf_size
    lb = bounds.lower_bounds(index_small, q)
    want = engine.probe_best_leaf(series, starts, sizes, lb, q, ml)
    # append an empty (shard-padding) leaf advertising an unbeatable lb of 0,
    # exactly what the pre-fix distributed body produced: the probe must
    # tie-break away from it instead of returning +inf
    starts2 = jnp.concatenate([starts, jnp.zeros((1,), starts.dtype)])
    sizes2 = jnp.concatenate([sizes, jnp.zeros((1,), sizes.dtype)])
    lb2 = jnp.concatenate([lb, jnp.zeros((q.shape[0], 1))], axis=1)
    got = engine.probe_best_leaf(series, starts2, sizes2, lb2, q, ml)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("cap", [None, 1, 4])
def test_compact_bsf_cascade_matches_masked_scan(index_small, queries_small,
                                                 cap):
    """Fixed-width compaction == masked scan, bitwise, at any capacity
    (cap=1 forces the overflow→scan fallback for nearly every query)."""
    q = jnp.asarray(queries_small)
    series, starts, sizes = _bsf_args(index_small)
    ml = index_small.max_leaf_size
    d_lb = bounds.lower_bounds(index_small, q)
    d_F = _synthetic_predictions(d_lb)
    bsf0 = engine.probe_best_leaf(series, starts, sizes, d_lb, q, ml)
    a = engine.masked_bsf_scan(series, starts, sizes, d_lb, d_F, q, ml, bsf0)
    b = engine.compact_bsf_cascade(series, starts, sizes, d_lb, d_F, q, ml,
                                   bsf0, max_survivors=cap)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_compact_bsf_cascade_all_survive(index_small, queries_small):
    """Zero lower bounds + no filters: every leaf survives; small caps must
    overflow into the exact scan fallback, a full cap must not overflow."""
    q = jnp.asarray(queries_small[:8])
    series, starts, sizes = _bsf_args(index_small)
    ml = index_small.max_leaf_size
    L = index_small.n_leaves
    d_lb = jnp.zeros((q.shape[0], L), jnp.float32)
    d_F = jnp.full(d_lb.shape, -jnp.inf)
    bsf0 = engine.probe_best_leaf(series, starts, sizes, d_lb, q, ml)
    a = engine.masked_bsf_scan(series, starts, sizes, d_lb, d_F, q, ml, bsf0)
    for cap in (engine.default_max_survivors(L), L):
        b = engine.compact_bsf_cascade(series, starts, sizes, d_lb, d_F, q,
                                       ml, bsf0, max_survivors=cap)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
        assert (np.asarray(b[1]) == L).all()


def test_compact_bsf_cascade_padding_leaves(index_small, queries_small):
    """Shard-padding leaf slots (size 0) with adversarial raw lb 0: both
    1-NN forms must prune them; an all-padding shard returns the seed."""
    q = jnp.asarray(queries_small[:8])
    series, starts, sizes = _bsf_args(index_small)
    ml = index_small.max_leaf_size
    extra = 5
    starts2 = jnp.concatenate([starts, jnp.zeros((extra,), starts.dtype)])
    sizes2 = jnp.concatenate([sizes, jnp.zeros((extra,), sizes.dtype)])
    d_lb = bounds.lower_bounds(index_small, q)
    d_lb2 = jnp.concatenate(
        [d_lb, jnp.zeros((q.shape[0], extra))], axis=1)
    d_F2 = _synthetic_predictions(d_lb2)
    bsf0 = engine.probe_best_leaf(series, starts2, sizes2, d_lb2, q, ml)
    assert np.isfinite(np.asarray(bsf0)).all()
    a = engine.masked_bsf_scan(series, starts2, sizes2, d_lb2, d_F2, q, ml,
                               bsf0)
    b = engine.compact_bsf_cascade(series, starts2, sizes2, d_lb2, d_F2, q,
                                   ml, bsf0)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))

    # all-padding: nothing to scan, bsf stays at the (+inf) seed, n_s == 0
    allpad = jnp.zeros_like(sizes2)
    bsf0p = engine.probe_best_leaf(series, starts2, allpad, d_lb2, q, ml)
    c = engine.compact_bsf_cascade(series, starts2, allpad, d_lb2, d_F2, q,
                                   ml, bsf0p)
    d = engine.masked_bsf_scan(series, starts2, allpad, d_lb2, d_F2, q, ml,
                               bsf0p)
    np.testing.assert_array_equal(np.asarray(c[0]), np.asarray(d[0]))
    assert (np.asarray(c[1]) == 0).all() and (np.asarray(d[1]) == 0).all()


def test_pairwise_impl_all_leaves_survive(index_small, queries_small):
    """Adversarial empty-pruning case on the union path: the shared slab is
    the whole index; results must still match scan."""
    q = jnp.asarray(queries_small[:8])
    d_lb = jnp.zeros((q.shape[0], index_small.n_leaves), jnp.float32)
    d_F = jnp.full(d_lb.shape, -jnp.inf)
    a = _run(index_small, q, d_lb, d_F, 3, "scan")
    b = engine.run_cascade(
        jnp.asarray(index_small.series), jnp.asarray(index_small.leaf_start),
        jnp.asarray(index_small.leaf_size), q, d_lb, d_F,
        k=3, max_leaf=index_small.max_leaf_size, strategy="compact",
        dist_impl="pairwise")
    np.testing.assert_allclose(np.asarray(a.topk_d), np.asarray(b.topk_d),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(a.topk_i), np.asarray(b.topk_i))
    assert (np.asarray(b.n_searched) == index_small.n_leaves).all()


# ---------------------------------------------------------------------------
# cascade trace (repro.obs): trace=True must be result-invisible and must
# account for every leaf slot, per query, on every strategy
# ---------------------------------------------------------------------------


def _assert_trace_accounts(trace, n_leaves):
    assert trace is not None
    res = obs.accounting_residual(trace, n_leaves)
    np.testing.assert_array_equal(np.asarray(res),
                                  np.zeros(res.shape, np.int64))
    for field in trace:
        assert (np.asarray(field) >= 0).all()


@pytest.mark.parametrize("strategy", ["scan", "compact"])
def test_trace_is_bitwise_invisible(index_small, queries_small, strategy):
    """Both backbones (fixture) x both strategies: the traced program must
    return bitwise-identical results and counters to the untraced one, and
    its per-query attribution must partition the leaf set exactly:
    pruned_box + pruned_seed + pruned_filter == L - survivors - probed."""
    q = jnp.asarray(queries_small)
    d_lb = bounds.lower_bounds(index_small, q)
    d_F = _synthetic_predictions(d_lb)
    for k in (1, 5):
        a = _run(index_small, q, d_lb, d_F, k, strategy)
        b = engine.run_cascade(
            jnp.asarray(index_small.series),
            jnp.asarray(index_small.leaf_start),
            jnp.asarray(index_small.leaf_size), q, d_lb, d_F,
            k=k, max_leaf=index_small.max_leaf_size, strategy=strategy,
            trace=True)
        _assert_bitwise(a, b)
        assert a.trace is None
        _assert_trace_accounts(b.trace, index_small.n_leaves)
        # an active filter cascade must be visible in the attribution
        assert np.asarray(b.trace.pruned_filter).sum() > 0


def test_trace_through_search_batched(index_small, queries_small):
    """Public API: search_batched(trace=True) materializes the numpy dict
    and stays bitwise-identical to the untraced call."""
    a = search.search_batched(index_small, queries_small, k=3,
                              use_filters=False, strategy="compact")
    b = search.search_batched(index_small, queries_small, k=3,
                              use_filters=False, strategy="compact",
                              trace=True)
    np.testing.assert_array_equal(a.dists, b.dists)
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(a.searched, b.searched)
    assert a.trace is None and isinstance(b.trace, dict)
    total = (b.trace["pruned_box"] + b.trace["pruned_seed"]
             + b.trace["pruned_filter"] + b.trace["survivors"]
             + b.trace["probed"])
    np.testing.assert_array_equal(
        total, np.full(len(queries_small), index_small.n_leaves))


@pytest.mark.parametrize("cap", [None, 1])
def test_compact_bsf_cascade_trace_parity(index_small, queries_small, cap):
    """The 1-NN fixed-width form: traced == untraced bitwise at any
    capacity; cap=1 forces the overflow->scan fallback, which the trace
    must flag while keeping the leaf accounting exact."""
    q = jnp.asarray(queries_small)
    series, starts, sizes = _bsf_args(index_small)
    ml = index_small.max_leaf_size
    d_lb = bounds.lower_bounds(index_small, q)
    d_F = _synthetic_predictions(d_lb)
    bsf0 = engine.probe_best_leaf(series, starts, sizes, d_lb, q, ml)
    a = engine.compact_bsf_cascade(series, starts, sizes, d_lb, d_F, q, ml,
                                   bsf0, max_survivors=cap)
    b = engine.compact_bsf_cascade(series, starts, sizes, d_lb, d_F, q, ml,
                                   bsf0, max_survivors=cap, trace=True)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    _assert_trace_accounts(b[2], index_small.n_leaves)
    if cap == 1:
        assert np.asarray(b[2].overflow).sum() > 0


def test_masked_bsf_scan_trace_parity(index_small, queries_small):
    q = jnp.asarray(queries_small)
    series, starts, sizes = _bsf_args(index_small)
    ml = index_small.max_leaf_size
    d_lb = bounds.lower_bounds(index_small, q)
    d_F = _synthetic_predictions(d_lb)
    bsf0 = engine.probe_best_leaf(series, starts, sizes, d_lb, q, ml)
    a = engine.masked_bsf_scan(series, starts, sizes, d_lb, d_F, q, ml, bsf0)
    b = engine.masked_bsf_scan(series, starts, sizes, d_lb, d_F, q, ml, bsf0,
                               trace=True)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    n_box, n_seed, n_pf, n_rows = b[2]
    total = (np.asarray(n_box) + np.asarray(n_seed) + np.asarray(n_pf)
             + np.asarray(a[1]))
    np.testing.assert_array_equal(
        total, np.full(q.shape[0], index_small.n_leaves))
