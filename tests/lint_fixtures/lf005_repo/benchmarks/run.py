"""LF005 fixture suite registry: one healthy suite, two broken ones."""

SUITES = {
    "good": (None, "experiments/good_bench.json"),
    "noartifact": (None, "experiments/missing_bench.json"),
    "notarget": (None, "experiments/notarget_bench.json"),
}
