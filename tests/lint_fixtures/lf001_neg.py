"""LF001 negative fixture: static-shape idioms and host-only code."""
import jax
import jax.numpy as jnp


@jax.jit
def good_static(x):
    n = int(x.shape[0])                  # shape-derived: exempt
    mask = x > 0
    return jnp.where(mask, x, 0.0).sum() + n


def host_only(x):
    # not jit-reachable: dynamic shapes are fine on the host side
    return jnp.nonzero(x > 0)[0]
