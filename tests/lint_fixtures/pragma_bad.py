"""Pragma fixture: reasonless and unknown-rule pragmas suppress nothing."""
import jax
import jax.numpy as jnp


@jax.jit
def unexplained(x):
    return jnp.unique(x)  # leafi: ignore[LF001]


@jax.jit
def unknown_rule(x):
    return jnp.nonzero(x)  # leafi: ignore[LF999]: not a registered rule
