"""LF004 negative fixture: hoisted static arg — one program, many calls."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("k",))
def topk(x, k):
    return jax.lax.top_k(x, k)[0]


def drive(xs):
    k = 4                                # hoisted: a single compiled program
    return [topk(x, k) for x in xs]
