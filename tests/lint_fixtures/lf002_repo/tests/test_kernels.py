"""LF002 fixture test file: references covered_op only."""
from repro.kernels.demo.ops import covered_op


def test_covered():
    assert covered_op(1) == 1
