"""LF002 fixture kernel: one covered export, one uncovered, one private."""


def covered_op(x):
    return x


def uncovered_op(x):
    return x


def _private_helper(x):
    return x
