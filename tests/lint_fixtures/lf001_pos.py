"""LF001 positive fixture: dynamic-shape / host-sync ops in traced code."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def bad_dynamic(x):
    idx = jnp.nonzero(x > 0)[0]          # finding: dynamic output shape
    y = x[x > 0]                         # finding: boolean-mask indexing
    s = x.sum().item()                   # finding: host sync
    n = int(x.sum())                     # finding: concretizes a tracer
    return idx, y, s, n


def helper(x):
    return jnp.unique(x)                 # finding: reachable from a jit root


@functools.partial(jax.jit, static_argnames=())
def calls_helper(x):
    return helper(x)
