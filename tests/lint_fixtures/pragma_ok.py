"""Pragma fixture: a reasoned ignore suppresses the finding."""
import jax
import jax.numpy as jnp


@jax.jit
def tolerated(x):
    return jnp.unique(x)  # leafi: ignore[LF001]: fixture-documented exception
