"""LF003 negative fixture: the rebind idiom — donation then reassignment."""
import jax


def loop(fn, state, batches):
    step = jax.jit(fn, donate_argnums=(0,))
    for batch in batches:
        state = step(state, batch)       # rebind clears the taint
    return state
