"""LF004 positive fixture: loop-varying and unhashable static args."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k",))
def topk(x, k):
    return jax.lax.top_k(x, k)[0]


def drive():
    out = []
    for n in range(4):
        out.append(topk(jnp.ones(8), n))     # finding: re-traces per n
    out.append(topk(jnp.ones(8), k=[1, 2]))  # finding: unhashable static
    return out
