"""LF003 positive fixture: a donated buffer read after the donating call."""
import jax


def loop(fn, state, batch):
    step = jax.jit(fn, donate_argnums=(0,))
    out = step(state, batch)
    return state.sum() + out             # finding: state was donated above
