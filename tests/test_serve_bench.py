"""Smoke for the serving benchmark's --quick mode (make bench-serve-quick).

Runs the CI-sized pipeline sweep end-to-end in a subprocess on a shrunken
setup (BENCH_N/BENCH_CACHE env) and checks the emitted payload has the
depth × strategy cells with finite headline numbers.
"""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_serve_bench_quick_smoke(tmp_path):
    out = tmp_path / "serve_bench_quick.json"
    env = dict(os.environ, PYTHONPATH="src", BENCH_N="4000",
               BENCH_CACHE=str(tmp_path / "cache"))
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.serve_bench", "--quick",
         "--requests", "64", "--batch", "8", "--out", str(out)],
        capture_output=True, text=True, timeout=1800, env=env)
    assert out.exists(), r.stdout[-2000:] + r.stderr[-4000:]
    payload = json.load(open(out))
    assert payload["quick"] is True
    cells = payload["pipeline"]
    for strategy in ("scan", "compact"):
        assert cells[f"single/{strategy}/schedule_identical"] is True
        for name in ("serial", "pipe1"):
            cell = cells[f"single/{strategy}/{name}"]
            assert cell["capacity_qps"] > 0
            assert cell["p99_sat_over_sustained"] > 0
            for pct in cell["saturated_latency_ms"].values():
                assert pct >= 0
