"""Runtime-sanitizer smoke tests (``REPRO_CHECKIFY=1``).

The engine's padded-slab layout silently clamps out-of-bounds gathers, so a
corrupted ``leaf_start`` returns plausible garbage instead of crashing.
These tests pin the sanitizer contract on both backbones and both cascade
strategies:

* clean inputs run bitwise-identically with the sanitizer on;
* a corrupted ``leaf_start`` raises ``checkify.JaxRuntimeError`` under
  ``REPRO_CHECKIFY=1`` (scan AND compact);
* without the env var the same corruption is silent — which is exactly why
  the sanitizer exists.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import checkify

from repro import sanitize
from repro.core import bounds, engine, tree


@pytest.fixture(scope="module", params=["dstree", "isax"])
def index_small(request, randwalk_small):
    if request.param == "dstree":
        return tree.build_dstree(randwalk_small[:2000], leaf_capacity=64)
    return tree.build_isax(randwalk_small[:2000], leaf_capacity=64)


def _run(index, queries, d_lb, d_F, k, strategy, leaf_start=None):
    if leaf_start is None:
        leaf_start = jnp.asarray(index.leaf_start)
    return engine.run_cascade(
        jnp.asarray(index.series), leaf_start,
        jnp.asarray(index.leaf_size), queries, d_lb, d_F,
        k=k, max_leaf=index.max_leaf_size, strategy=strategy)


def _inputs(index, queries_small, n_queries=8):
    q = jnp.asarray(queries_small[:n_queries])
    d_lb = bounds.lower_bounds(index, q)
    d_F = jnp.full(d_lb.shape, -jnp.inf)
    return q, d_lb, d_F


def _corrupt(index):
    """A leaf_start aiming one leaf's slab far past the series rows."""
    start = jnp.asarray(index.leaf_start)
    return start.at[index.n_leaves // 2].set(index.series.shape[0] + 1000)


def test_enabled_flag(monkeypatch):
    monkeypatch.delenv("REPRO_CHECKIFY", raising=False)
    assert not sanitize.enabled()
    monkeypatch.setenv("REPRO_CHECKIFY", "0")
    assert not sanitize.enabled()
    monkeypatch.setenv("REPRO_CHECKIFY", "1")
    assert sanitize.enabled()


@pytest.mark.parametrize("strategy", ["scan", "compact"])
def test_clean_run_matches_uninstrumented(index_small, queries_small,
                                          strategy, monkeypatch):
    q, d_lb, d_F = _inputs(index_small, queries_small)
    monkeypatch.delenv("REPRO_CHECKIFY", raising=False)
    plain = _run(index_small, q, d_lb, d_F, 5, strategy)
    monkeypatch.setenv("REPRO_CHECKIFY", "1")
    checked = _run(index_small, q, d_lb, d_F, 5, strategy)
    np.testing.assert_array_equal(np.asarray(plain.topk_d),
                                  np.asarray(checked.topk_d))
    np.testing.assert_array_equal(np.asarray(plain.topk_i),
                                  np.asarray(checked.topk_i))
    np.testing.assert_array_equal(np.asarray(plain.n_searched),
                                  np.asarray(checked.n_searched))


@pytest.mark.parametrize("strategy", ["scan", "compact"])
def test_corrupted_leaf_start_caught(index_small, queries_small, strategy,
                                     monkeypatch):
    q, d_lb, d_F = _inputs(index_small, queries_small)
    monkeypatch.setenv("REPRO_CHECKIFY", "1")
    with pytest.raises(checkify.JaxRuntimeError, match="out-of-bounds"):
        _run(index_small, q, d_lb, d_F, 5, strategy,
             leaf_start=_corrupt(index_small))


@pytest.mark.parametrize("strategy", ["scan", "compact"])
def test_corruption_is_silent_without_env(index_small, queries_small,
                                          strategy, monkeypatch):
    """The motivating failure: without the sanitizer, OOB slabs clamp and the
    cascade returns finite garbage as if nothing happened."""
    q, d_lb, d_F = _inputs(index_small, queries_small)
    monkeypatch.delenv("REPRO_CHECKIFY", raising=False)
    res = _run(index_small, q, d_lb, d_F, 5, strategy,
               leaf_start=_corrupt(index_small))
    assert np.isfinite(np.asarray(res.topk_d)).all()
