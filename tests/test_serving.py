"""Serving runtime: batcher policy determinism, per-query-target parity,
per-group recall on calibration queries, checkpoint cold start, and
survivor-capacity auto-tuning.

Parity caveat: the vectorized (Q, F)-offset path and the grouped-sub-batch
fallback compile as *different XLA programs* over the same per-query
arithmetic, so prune decisions tied within an ulp of the bsf may fuse
differently — the pins below use float tolerance plus a small searched-count
slack, not bitwise equality (cf. tests/test_distributed.py).
"""
import jax
import numpy as np
import pytest

from repro.core import build, conformal, engine, filter_training, search
from repro.core.summaries import znormalize
from repro.serving import (MicroBatcher, ServingSession, Telemetry,
                           latency_percentiles, load_index, poisson_trace,
                           run_trace, save_index)


@pytest.fixture(scope="module", params=["dstree", "isax"])
def lfi(request, randwalk_small):
    cfg = build.LeaFiConfig(backbone=request.param, leaf_capacity=64,
                            n_global=200, n_local=50,
                            t_filter_over_t_series=10.0,
                            train=filter_training.TrainConfig(epochs=30))
    return build.build_leafi(randwalk_small[:2500], cfg)


@pytest.fixture(scope="module")
def mixed_queries(randwalk_small):
    rng = np.random.default_rng(11)
    q = znormalize(randwalk_small[rng.integers(0, 2500, 48)]
                   + 0.2 * rng.standard_normal((48, 96)).astype(np.float32))
    targets = np.asarray([0.7, 0.85, 0.95])[rng.integers(0, 3, 48)]
    return q, targets


# ---------------------------------------------------------------------------
# per-query quality targets: vectorized (Q, F) offsets vs grouped fallback
# ---------------------------------------------------------------------------


def _search_kw(lfi):
    return dict(filter_params=lfi.filter_params, leaf_ids=lfi.leaf_ids,
                tuner=lfi.tuner)


@pytest.mark.parametrize("strategy", ["scan", "compact"])
def test_per_query_offsets_match_grouped(lfi, mixed_queries, strategy):
    q, targets = mixed_queries
    vec = search.search_batched(lfi.index, q, k=5, quality_target=targets,
                                strategy=strategy, **_search_kw(lfi))
    grp = search.search_batched_grouped(lfi.index, q, targets, k=5,
                                        strategy=strategy, **_search_kw(lfi))
    np.testing.assert_allclose(vec.dists, grp.dists, rtol=1e-5, atol=1e-6)
    # ulp-tied prune decisions may differ across programs: tiny slack only
    assert np.abs(vec.searched - grp.searched).max() <= 2
    neq = vec.ids != grp.ids
    assert neq.mean() <= 0.02, f"{neq.sum()} id mismatches beyond ties"


def test_uniform_target_array_matches_scalar(lfi, mixed_queries):
    """A constant target array is the scalar path, batched."""
    q, _ = mixed_queries
    arr = search.search_batched(lfi.index, q, quality_target=np.full(48, 0.9),
                                **_search_kw(lfi))
    sca = search.search_batched(lfi.index, q, quality_target=0.9,
                                **_search_kw(lfi))
    np.testing.assert_allclose(arr.dists, sca.dists, rtol=1e-5, atol=1e-6)
    assert np.abs(arr.searched - sca.searched).max() <= 2


def test_target_array_length_mismatch_raises(lfi, mixed_queries):
    q, _ = mixed_queries
    with pytest.raises(ValueError, match="per-query quality_target"):
        search.search_batched(lfi.index, q, quality_target=np.full(7, 0.9),
                              **_search_kw(lfi))
    with pytest.raises(ValueError, match="scalar or a \\(Q,\\)"):
        search.search_batched(lfi.index, q,
                              quality_target=np.full((len(q), 1), 0.9),
                              **_search_kw(lfi))


def test_per_group_recall_meets_targets_on_calibration_queries(lfi):
    """Mixed targets on the build's own calibration split: each group's
    achieved recall must meet its requested target, up to the one-query
    quantization of a small group (1/n)."""
    cfg = lfi.config
    key = jax.random.PRNGKey(cfg.seed)
    kdata, _ = jax.random.split(key)
    kg, _ = jax.random.split(kdata)
    gq = filter_training.make_noisy_queries(
        np.asarray(lfi.index.series[:lfi.index.n_series]),
        cfg.n_global, kg, 0.1, 0.4)
    n_cal = max(int(cfg.n_global * cfg.calib_fraction), 8)
    calib = gq[-n_cal:]                   # the split build_leafi calibrated on
    rng = np.random.default_rng(3)
    targets = np.asarray([0.7, 0.85, 0.95])[rng.integers(0, 3, n_cal)]
    exact = lfi.search_exact(calib)
    res = lfi.search(calib, quality_target=targets)
    hit = np.asarray(conformal.recall_at_1(res.dists[:, 0],
                                           exact.dists[:, 0])) > 0
    for t in np.unique(targets):
        sel = targets == t
        recall = hit[sel].mean()
        assert recall >= t - 1.0 / sel.sum() - 1e-9, \
            f"target {t}: recall {recall:.3f} over {sel.sum()} queries"


# ---------------------------------------------------------------------------
# micro-batcher: bucket/flush policy + determinism under a seeded trace
# ---------------------------------------------------------------------------


def _toy_trace(rate, n=64, seed=5, ks=(1, 5)):
    pool = np.random.default_rng(0).standard_normal((8, 4)).astype(np.float32)
    return poisson_trace(pool, rate=rate, n_requests=n,
                         targets=(0.8, 0.9, 0.99), ks=ks, seed=seed)


def _drive(trace, max_batch, max_wait, service=1e-3):
    batcher = MicroBatcher(max_batch=max_batch, max_wait=max_wait)
    return run_trace(trace, batcher, lambda b: None,
                     service_time=lambda b: service)


def test_batcher_policy_and_completeness():
    trace = _toy_trace(rate=2000.0)       # saturating arrivals
    completions, batch_log = _drive(trace, max_batch=8, max_wait=0.01)
    assert sorted(completions) == [r.rid for r in trace]   # all served once
    arrivals = {r.rid: r.arrival for r in trace}
    ks = {r.rid: r.k for r in trace}
    for b in batch_log:
        assert b["bucket"] in (1, 2, 4, 8) and b["n_valid"] <= b["bucket"]
    # FIFO within each k-group; batches are k-homogeneous by construction
    for k in (1, 5):
        order = [rid for rid in sorted(completions,
                                       key=lambda r: completions[r]["finish"])
                 if ks[rid] == k]
        assert all(arrivals[a] <= arrivals[b] + 1e-12
                   for a, b in zip(order, order[1:]))


def test_batcher_deadline_flush_under_light_load():
    """At low rate every request flushes at its deadline, not max_batch."""
    trace = _toy_trace(rate=10.0, n=16, ks=(1,))
    service = 1e-3
    max_wait = 0.01
    completions, batch_log = _drive(trace, max_batch=8, max_wait=max_wait,
                                    service=service)
    for b in batch_log:
        assert b["n_valid"] < 8           # never a size flush at this rate
    for rid, c in completions.items():
        # a request can join an older request's batch (the deadline is the
        # *oldest* member's), so only the upper bound is per-request
        assert c["latency"] <= max_wait + 2 * service + 1e-9
    # …but each batch's oldest member did wait out the full deadline
    for finish in {c["finish"] for c in completions.values()}:
        members = [c for c in completions.values() if c["finish"] == finish]
        assert max(m["latency"] for m in members) >= max_wait - 1e-9


def test_batcher_trace_replay_is_deterministic():
    trace = _toy_trace(rate=500.0)
    a_c, a_log = _drive(trace, max_batch=8, max_wait=0.005)
    b_c, b_log = _drive(trace, max_batch=8, max_wait=0.005)
    # everything but the measured wall-clock around execute is replayable
    def strip(log):
        return [{k: v for k, v in b.items() if k != "wall"} for b in log]
    assert strip(a_log) == strip(b_log)
    assert {r: c["latency"] for r, c in a_c.items()} == \
        {r: c["latency"] for r, c in b_c.items()}
    # and the trace itself replays identically from its seed
    t2 = _toy_trace(rate=500.0)
    assert [(r.rid, r.arrival, r.k, r.quality_target) for r in t2] == \
        [(r.rid, r.arrival, r.k, r.quality_target) for r in trace]


# ---------------------------------------------------------------------------
# session: cold start round-trip + end-to-end serve loop
# ---------------------------------------------------------------------------


def test_index_checkpoint_roundtrip_search_parity(lfi, mixed_queries,
                                                  tmp_path):
    q, targets = mixed_queries
    path = str(tmp_path / "leafi_idx")
    save_index(path, lfi)
    lfi2 = load_index(path)
    assert lfi2.index.kind == lfi.index.kind
    assert lfi2.config.backbone == lfi.config.backbone
    a = lfi.search(q, k=3, quality_target=targets)
    b = lfi2.search(q, k=3, quality_target=targets)
    # identical arrays through identical programs: exact equality
    np.testing.assert_array_equal(a.dists, b.dists)
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(a.searched, b.searched)


def test_serving_session_end_to_end(lfi, mixed_queries):
    q, _ = mixed_queries
    session = ServingSession(lfi, strategy="compact")
    n = session.warmup(max_batch=4, ks=(1,), queries=q)
    assert n == 3 and session.warmup(max_batch=4, ks=(1,)) == 0  # cached
    trace = poisson_trace(q, rate=400.0, n_requests=24,
                          targets=(0.8, 0.95), ks=(1,), seed=2)
    exact = session.search_exact(np.stack([r.query for r in trace]))
    oracle = {r.rid: float(exact.dists[i, 0]) for i, r in enumerate(trace)}
    report = session.serve(
        trace, batcher=MicroBatcher(max_batch=4, max_wait=0.002),
        recall_oracle=oracle)
    assert report["n_requests"] == 24
    assert report["throughput_qps"] > 0
    assert np.isfinite(report["p99"]) and report["p50"] <= report["p99"]
    groups = report["recall_by_target"]
    assert set(groups) <= {0.8, 0.95}
    assert sum(g["n"] for g in groups.values()) == 24
    for g in groups.values():
        assert 0.0 <= g["recall"] <= 1.0


# ---------------------------------------------------------------------------
# telemetry + survivor-capacity auto-tuning
# ---------------------------------------------------------------------------


def test_latency_percentiles_helper():
    p = latency_percentiles(np.arange(1, 101))
    assert p["p50"] == pytest.approx(50.5)
    assert p["p99"] == pytest.approx(99.01)
    assert np.isnan(latency_percentiles([])["p95"])


def test_tuned_max_survivors_bounds_overflow():
    """Percentile-chosen capacity keeps the overflow-fallback frequency
    bounded on fresh traffic from the same workload (the regression the
    static P/8 default cannot promise)."""
    L = 1024
    rng = np.random.default_rng(0)
    calib = rng.lognormal(mean=3.0, sigma=0.8, size=4000).astype(int) + 1
    cap = engine.tuned_max_survivors(calib, L, pct=99.0)
    assert 1 <= cap <= 2 * L and cap & (cap - 1) == 0      # pow2, clamped
    fresh = rng.lognormal(mean=3.0, sigma=0.8, size=4000).astype(int) + 1
    assert (fresh > cap).mean() <= 0.02                    # ~1% by design
    # degenerate inputs fall back to the static default
    assert engine.tuned_max_survivors([], L) == \
        engine.default_max_survivors(L)
    # huge observed counts clamp at the leaf-slot ceiling
    assert engine.tuned_max_survivors([10 * L], L) <= \
        engine.tuned_max_survivors([L], L)


def test_telemetry_feeds_capacity_and_counters(lfi, mixed_queries):
    q, targets = mixed_queries
    session = ServingSession(lfi, strategy="compact")
    res = session.search(q, quality_targets=targets, k=1)
    tel = session.telemetry
    assert tel.n_requests == len(q)
    assert 0.0 <= tel.pruning_ratio() <= 1.0
    cap = tel.suggest_max_survivors()
    assert cap >= 1 and cap & (cap - 1) == 0
    # capacity covers ≥99% of the observed survivor counts
    surv = np.asarray(res.computed)
    assert (surv > cap).mean() <= 0.01 + 1.0 / len(surv)


def test_suggest_max_survivors_cold_start_floors_at_default():
    """A handful of easy early queries must not lock in an unstable low
    capacity: below ~100/(100−pct) observations the suggestion is floored
    at the engine's static default (regression for the cold-start bug
    where 3 lucky queries suggested capacity 4 on a 1024-leaf index)."""
    L = 1024
    tel = Telemetry()
    tel.n_leaves = L
    tel.survivors.extend([1, 2, 3])                  # cold window
    assert tel.suggest_max_survivors() >= engine.default_max_survivors(L)
    # with a full window the percentile speaks for itself again, even when
    # it sits *below* the static default
    tel2 = Telemetry()
    tel2.n_leaves = L
    tel2.survivors.extend([4] * 400)
    assert tel2.suggest_max_survivors() == \
        engine.tuned_max_survivors(np.full(400, 4), L)
    assert tel2.suggest_max_survivors() < engine.default_max_survivors(L)


# ---------------------------------------------------------------------------
# bsf warm-starting: prune-only bound semantics + the rolling cache
# ---------------------------------------------------------------------------


def test_bsf_ub_exact_mode_is_bitwise_and_prunes_no_worse(lfi, mixed_queries):
    """Exact mode (no filters): a valid prune-only upper bound never changes
    the answer — bitwise — and never scans more leaves in aggregate."""
    q, _ = mixed_queries
    for strategy in ("scan", "compact"):
        base = search.search_batched(lfi.index, q, k=3, strategy=strategy)
        ub = base.dists[:, -1] * (1 + 1e-6) + 1e-6       # ≥ true 3rd-NN dist
        seeded = search.search_batched(lfi.index, q, k=3, strategy=strategy,
                                       bsf_ub=ub)
        np.testing.assert_array_equal(seeded.dists, base.dists, strategy)
        np.testing.assert_array_equal(seeded.ids, base.ids, strategy)
        assert seeded.searched.sum() <= base.searched.sum(), strategy
        assert (seeded.computed <= base.computed).all() if strategy == \
            "compact" else True


def test_bsf_ub_filtered_mode_keeps_recall(lfi, mixed_queries):
    """With filters the seeded cascade is not bitwise (the tighter lb prune
    changes the bsf trajectory and with it the filter decisions), but the
    bound only ever enters the *lb* test — a leaf with lb > ub ≥ d_true
    holds no true NN — while the learned-filter test keeps its witnessed-bsf
    threshold, so conformal recall semantics are preserved."""
    q, targets = mixed_queries
    exact = search.search_batched(lfi.index, q, k=1)
    ub = exact.dists[:, 0] * (1 + 1e-6) + 1e-6
    base = search.search_batched(lfi.index, q, k=1, quality_target=targets,
                                 **_search_kw(lfi))
    seeded = search.search_batched(lfi.index, q, k=1, quality_target=targets,
                                   bsf_ub=ub, **_search_kw(lfi))
    hit_base = conformal.recall_at_1(base.dists[:, 0], exact.dists[:, 0])
    hit_seed = conformal.recall_at_1(seeded.dists[:, 0], exact.dists[:, 0])
    assert np.mean(hit_seed) >= np.mean(hit_base) - 0.05
    assert seeded.searched.sum() <= base.searched.sum()
    # seeded distances are still witnessed: never below the exact answer
    assert (seeded.dists[:, 0] >= exact.dists[:, 0] - 1e-4).all()


def test_bsf_cache_bounds_are_valid_and_staged_commits_lag():
    from repro.serving import BsfCache

    rng = np.random.default_rng(3)
    base = rng.standard_normal((32, 16)).astype(np.float32)
    dists = rng.uniform(1.0, 2.0, 32).astype(np.float32)
    cache = BsfCache(capacity=16)
    assert cache.seed(base, 1) is None                   # cold
    cache.update(base, dists, k=1)
    assert len(cache) == 16                              # ring capacity
    near = base[-16:] + 0.01 * rng.standard_normal((16, 16)).astype(
        np.float32)
    ub = cache.seed(near, 1)
    # triangle inequality: ub ≥ cached dist − drift, and finite
    assert np.isfinite(ub).all()
    assert (ub >= dists[-16:] - 0.2).all()
    assert cache.seed(near, 5) is None                   # per-k rings
    # staging: nothing lands until commit_through reaches the seq
    cache2 = BsfCache()
    cache2.stage(0, base[:4], dists[:4], k=1)
    cache2.stage(1, base[4:8], dists[4:8], k=1)
    assert cache2.seed(base, 1) is None
    cache2.commit_through(0)
    assert len(cache2) == 4
    cache2.commit_through(5)
    assert len(cache2) == 8
    # nonfinite kth distances (padded/failed rows) are skipped
    cache3 = BsfCache()
    cache3.update(base[:4], np.array([1.0, np.inf, np.nan, 2.0]), k=1)
    assert len(cache3) == 2
    cache3.reset()
    assert len(cache3) == 0 and cache3.seed(base, 1) is None


# ---------------------------------------------------------------------------
# pipelined serving: overlapped dispatch vs the serial loop (bitwise)
# ---------------------------------------------------------------------------


def _serve_mode(lfi, trace, *, pipeline, warm):
    session = ServingSession(lfi, strategy="compact", warm_start=warm)
    session.warmup(max_batch=8, ks=(1,),
                   queries=np.stack([r.query for r in trace[:8]]))
    report = session.serve(
        trace, batcher=MicroBatcher(max_batch=8, max_wait=0.004),
        service_time=lambda b: 1e-3 * max(b.bucket / 8, 0.25),
        pipeline=pipeline)
    return session, report


@pytest.mark.parametrize("warm", [False, True])
def test_pipelined_serve_matches_serial_bitwise(lfi, mixed_queries, warm):
    """The tentpole determinism pin: pipelined serving (overlapped dispatch,
    1 batch in flight) produces the identical batch sequence, completion
    times, and bitwise-identical per-request results as the serial loop —
    including with cross-batch bsf warm-starting (the staged-commit rule
    makes both modes observe identical cache states)."""
    q, _ = mixed_queries
    trace = poisson_trace(q, rate=900.0, n_requests=64,
                          targets=(0.8, 0.95), ks=(1,), seed=9)
    s0, r0 = _serve_mode(lfi, trace, pipeline=0, warm=warm)
    s1, r1 = _serve_mode(lfi, trace, pipeline=1, warm=warm)
    host_keys = ("wall", "dispatch_s", "harvest_s", "t_disp", "t_done")

    def strip(log):
        return [{k: v for k, v in b.items() if k not in host_keys}
                for b in log]
    assert strip(r0["batches"]) == strip(r1["batches"])
    for rid in r0["completions"]:
        c0, c1 = r0["completions"][rid], r1["completions"][rid]
        assert c0["latency"] == c1["latency"], rid
        assert c0["result"] == c1["result"], rid      # bitwise (==, no tol)
    # pipelined logs carry the overlap accounting
    assert all(b["harvest_s"] is not None for b in r1["batches"])
    assert all(b["t_done"] >= b["t_disp"] for b in r1["batches"])


def test_warm_start_serving_preserves_recall(lfi, mixed_queries):
    q, _ = mixed_queries
    trace = poisson_trace(q, rate=900.0, n_requests=48, targets=(0.95,),
                          ks=(1,), seed=4)
    cold = ServingSession(lfi, strategy="compact", warm_start=False)
    warm = ServingSession(lfi, strategy="compact", warm_start=True)
    exact = cold.search_exact(np.stack([r.query for r in trace]))
    oracle = {r.rid: float(exact.dists[i, 0]) for i, r in enumerate(trace)}
    reps = {}
    for name, s in (("cold", cold), ("warm", warm)):
        s.warmup(max_batch=8, ks=(1,), queries=q)
        reps[name] = s.serve(
            trace, batcher=MicroBatcher(max_batch=8, max_wait=0.004),
            recall_oracle=oracle,
            service_time=lambda b: 1e-3)
    rc = reps["cold"]["recall_by_target"][0.95]["recall"]
    rw = reps["warm"]["recall_by_target"][0.95]["recall"]
    assert rw >= rc - 0.05
    # warm bounds are prune-only: distances never undercut the oracle
    for rid, c in reps["warm"]["completions"].items():
        assert c["result"]["dist"] >= oracle[rid] - 1e-4


def test_phase_telemetry_lands_in_summary(lfi, mixed_queries):
    q, _ = mixed_queries
    assert "phases" not in Telemetry().summary()         # empty: no key
    trace = poisson_trace(q, rate=900.0, n_requests=24, targets=(0.9,),
                          ks=(1,), seed=6)
    session, _ = _serve_mode(lfi, trace, pipeline=1, warm=True)
    summ = session.telemetry.summary()
    phases = summ["phases"]
    assert set(phases) == {"queue_wait", "form", "execute"}
    for ph in phases.values():
        assert np.isfinite(ph["p50"]) and ph["p50"] <= ph["p99"]
    # queue waits are per-request (virtual clock), phases per batch
    assert len(session.telemetry.queue_wait) == 24
    assert len(session.telemetry.form_s) == len(session.telemetry.exec_s)


def test_run_trace_pipelined_requires_service_model():
    from repro.serving import run_trace_pipelined
    trace = _toy_trace(rate=500.0, n=8, ks=(1,))
    with pytest.raises(ValueError, match="service_time"):
        run_trace_pipelined(trace, MicroBatcher(), lambda b: b,
                            lambda h: None, service_time=None)
    with pytest.raises(ValueError, match="max_in_flight"):
        run_trace_pipelined(trace, MicroBatcher(), lambda b: b,
                            lambda h: None, service_time=lambda b: 1e-3,
                            max_in_flight=0)
