"""Hypothesis, or a minimal deterministic fallback when it isn't installed.

The property tests import ``given``/``settings``/``st`` from here.  With
hypothesis present this module is a pass-through and the full shrinking
machinery applies.  Without it, ``@given`` degrades to a fixed-seed sweep of
a handful of samples per test — far weaker than hypothesis, but it keeps the
invariants exercised on minimal environments (the tier-1 image carries no
dev extras) instead of failing collection outright.

Only the strategy combinators the suite actually uses are shimmed
(``integers``, ``sampled_from``); add more here before using new ones in
tests.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 8

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[
                rng.randrange(len(elements))])

    st = _Strategies()

    def settings(max_examples=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def wrapper():
                limit = getattr(wrapper, "_max_examples", None) \
                    or getattr(fn, "_max_examples", None) \
                    or _FALLBACK_EXAMPLES
                rng = random.Random(0)       # fixed seed: deterministic CI
                for _ in range(min(limit, _FALLBACK_EXAMPLES)):
                    fn(**{name: s.sample(rng)
                          for name, s in strategies.items()})
            # keep the test's identity but hide its parameters, or pytest
            # would try to resolve them as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco
