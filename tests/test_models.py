"""Per-arch smoke tests (deliverable f) + decode/forward equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer
from repro.optim import adamw_init


def _inputs(cfg, B, S, rng, labels=True, decode=False):
    d = {}
    if cfg.input_mode == "tokens":
        d["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    elif cfg.input_mode == "embeddings":
        d["embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    else:
        if decode:
            d["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
            d["patches"] = jnp.zeros((B, 0, cfg.d_model), jnp.float32)
        else:
            n_img = max(S // 4, 1)
            d["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab, (B, S - n_img)), jnp.int32)
            d["patches"] = jnp.asarray(
                rng.standard_normal((B, n_img, cfg.d_model)), jnp.float32)
    if labels:
        n_lbl = d["tokens"].shape[1] if "tokens" in d else S
        d["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, n_lbl)),
                                  jnp.int32)
    return d


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced same-family config: one forward + one train step on CPU,
    asserting shapes and no NaNs (assignment requirement)."""
    cfg = configs.get_smoke(arch)
    rng = np.random.default_rng(0)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    inputs = _inputs(cfg, B, S, rng)
    logits, aux = transformer.forward(cfg, params, inputs)
    n_out = inputs["tokens"].shape[1] if "tokens" in inputs else S
    exp_seq = S if cfg.input_mode != "mixed" else S
    assert logits.shape == (B, exp_seq, cfg.vocab) or \
        logits.shape == (B, n_out + S // 4, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    step = transformer.make_train_step(cfg)
    p2, o2, metrics = jax.jit(step)(params, adamw_init(params), inputs)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, p2))
    assert delta > 0


@pytest.mark.parametrize("arch", ["codeqwen1_5_7b", "glm4_9b", "rwkv6_1_6b",
                                  "mixtral_8x7b", "hymba_1_5b",
                                  "musicgen_large", "qwen2_moe_a2_7b"])
def test_decode_matches_forward(arch):
    cfg = configs.get_smoke(arch)
    if cfg.moe is not None:   # avoid train-path capacity drops in the check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = transformer.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    rng = np.random.default_rng(0)
    inputs = _inputs(cfg, B, S, rng, labels=False, decode=True)
    full, _ = transformer.forward(cfg, params, inputs)
    cache = transformer.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        di = {k: v[:, t:t + 1] if k in ("tokens", "embeds") else v
              for k, v in inputs.items()}
        lg, cache = transformer.forward_decode(cfg, params, cache, di,
                                               jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    rel = float(jnp.abs(dec - full).max()) / float(jnp.abs(full).max())
    assert rel < 3e-2, rel


def test_prefill_then_decode_continues_correctly():
    cfg = configs.get_smoke("glm4_9b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(2))
    B, S = 2, 24
    rng = np.random.default_rng(0)
    inputs = _inputs(cfg, B, S, rng, labels=False)
    full, _ = transformer.forward(cfg, params, inputs)
    prefill = transformer.make_prefill_step(cfg, cache_len=S + 8)
    logits_last, cache = prefill(params, {"tokens": inputs["tokens"][:, :-1]})
    lg, cache = transformer.forward_decode(
        cfg, params, cache, {"tokens": inputs["tokens"][:, -1:]},
        jnp.int32(S - 1))
    rel = float(jnp.abs(lg[:, 0] - full[:, -1]).max()) \
        / float(jnp.abs(full).max())
    assert rel < 3e-2, rel


def test_swa_ring_cache_bounds_memory():
    """Mixtral-family ring cache: decoding past the window stays exact."""
    cfg = configs.get_smoke("mixtral_8x7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    W = cfg.attn_window
    params = transformer.init_params(cfg, jax.random.PRNGKey(3))
    B, S = 1, W + 24                      # sequence longer than the window
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full, _ = transformer.forward(cfg, params, {"tokens": tokens})
    cache = transformer.init_cache(cfg, B, W)          # ring of window size
    outs = []
    for t in range(S):
        lg, cache = transformer.forward_decode(
            cfg, params, cache, {"tokens": tokens[:, t:t + 1]}, jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    rel = float(jnp.abs(dec - full).max()) / float(jnp.abs(full).max())
    assert rel < 3e-2, rel


def test_config_registry_exact_values():
    """Spot-check published configuration numbers."""
    c = configs.get_config("qwen2.5-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (64, 5120, 40, 8, 27648, 152064)
    m = configs.get_config("mixtral-8x7b")
    assert m.moe.n_experts == 8 and m.moe.top_k == 2 and m.attn_window == 4096
    q = configs.get_config("qwen2-moe-a2.7b")
    assert q.moe.n_experts == 60 and q.moe.top_k == 4 and q.moe.d_shared == 5632
    h = configs.get_config("hymba-1.5b")
    assert h.n_heads == 25 and h.n_kv_heads == 5 and h.ssm_state == 16
    r = configs.get_config("rwkv6-1.6b")
    assert r.layer_kind == "rwkv6" and r.d_ff == 7168 and r.vocab == 65536


def test_long_context_skip_rules():
    assert configs.supports_shape("rwkv6-1.6b", "long_500k")
    assert configs.supports_shape("mixtral-8x7b", "long_500k")
    assert configs.supports_shape("hymba-1.5b", "long_500k")
    assert not configs.supports_shape("qwen2.5-32b", "long_500k")
    assert not configs.supports_shape("musicgen-large", "long_500k")
