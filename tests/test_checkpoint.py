"""Checkpoint manager: atomicity, retention, restart semantics."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (17, 5)),
                       "b": jnp.zeros(5)},
            "opt": {"m": jnp.ones((17, 5)), "step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    t = _tree(0)
    save_pytree(str(tmp_path / "ck"), t, {"note": "hi"})
    restored, meta = load_pytree(str(tmp_path / "ck"), like=t)
    assert meta["note"] == "hi"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_is_invisible(tmp_path):
    p = str(tmp_path / "ck")
    save_pytree(p, _tree(0))
    os.remove(os.path.join(p, "DONE"))      # simulate a torn write
    with pytest.raises(FileNotFoundError):
        load_pytree(p, like=_tree(0))


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (10, 20, 30):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [20, 30]
    assert mgr.latest_step() == 30
    restored, meta = mgr.restore(like=_tree(0))
    assert meta["step"] == 30


def test_manager_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, _tree(1))
    mgr.wait()
    assert mgr.latest_step() == 1


def test_restore_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(0, _tree(0))
    bad = {"params": {"w": jnp.zeros((17, 5))}}   # missing leaves is fine...
    restored, _ = mgr.restore(like=bad)           # subset restore works
    with pytest.raises(KeyError):
        mgr.restore(like={"nope": jnp.zeros(3)})
