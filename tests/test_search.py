"""Search semantics: exactness, pruning accounting, k-NN, filter cascade."""
import numpy as np
import pytest

from repro.core import build, filter_training, search, tree
from repro.core.summaries import znormalize


@pytest.fixture(scope="module", params=["dstree", "isax"])
def index_small(request, randwalk_small):
    if request.param == "dstree":
        return tree.build_dstree(randwalk_small[:2000], leaf_capacity=64)
    return tree.build_isax(randwalk_small[:2000], leaf_capacity=64)


def brute_force(index, queries, k=1):
    S = np.asarray(index.series[: index.n_series])
    d = np.sqrt(((queries[:, None, :] - S[None]) ** 2).sum(-1))
    rows = np.argsort(d, axis=1)[:, :k]
    return np.take_along_axis(d, rows, 1), np.asarray(index.order)[rows]


def test_exact_search_equals_brute_force(index_small, queries_small):
    res = search.search_batched(index_small, queries_small, use_filters=False)
    want_d, want_i = brute_force(index_small, queries_small)
    np.testing.assert_allclose(res.dists[:, 0], want_d[:, 0], rtol=1e-4)
    assert (res.ids[:, 0] == want_i[:, 0]).all()
    # pruning accounting is consistent
    assert (res.searched + res.pruned_lb + res.pruned_filter
            == index_small.n_leaves).all()
    assert (res.pruned_filter == 0).all()


def test_knn_search_matches_brute_force(index_small, queries_small):
    k = 5
    res = search.search_batched(index_small, queries_small, k=k,
                                use_filters=False)
    want_d, want_i = brute_force(index_small, queries_small, k=k)
    np.testing.assert_allclose(res.dists, want_d, rtol=1e-4)
    assert (np.sort(res.ids, 1) == np.sort(want_i, 1)).all()


def test_early_search_equals_batched(index_small, queries_small):
    for qi in range(4):
        r1 = search.search_early(index_small, queries_small[qi],
                                 use_filters=False)
        r2 = search.search_batched(index_small, queries_small[qi:qi + 1],
                                   use_filters=False)
        np.testing.assert_allclose(r1.dists, r2.dists, rtol=1e-5)
        assert r1.ids[0, 0] == r2.ids[0, 0]


def test_filters_only_prune_never_corrupt_results(randwalk_small):
    """With absurdly conservative offsets the LeaFi search stays exact."""
    cfg = build.LeaFiConfig(backbone="dstree", leaf_capacity=64,
                            n_global=60, n_local=16,
                            t_filter_over_t_series=10.0,
                            train=filter_training.TrainConfig(epochs=5))
    lfi = build.build_leafi(randwalk_small[:1500], cfg)
    q = znormalize(randwalk_small[:8] + 0.3)
    exact = lfi.search_exact(q)
    # +1e6 offsets → d_F is far below any bsf → no filter pruning
    big = np.full(lfi.index.n_leaves, 1e6, np.float32)
    res = search.search_batched(
        lfi.index, q, filter_params=lfi.filter_params,
        leaf_ids=lfi.leaf_ids, tuner=None, quality_target=None,
        use_filters=True)
    np.testing.assert_allclose(res.dists, exact.dists, rtol=1e-4)


def test_quality_target_search_recall(randwalk_small):
    cfg = build.LeaFiConfig(backbone="dstree", leaf_capacity=64,
                            n_global=200, n_local=50,
                            t_filter_over_t_series=10.0,
                            train=filter_training.TrainConfig(epochs=40))
    lfi = build.build_leafi(randwalk_small, cfg)
    q = znormalize(randwalk_small[np.random.default_rng(5).integers(
        0, len(randwalk_small), 64)] + 0.2 * np.random.default_rng(6)
        .standard_normal((64, randwalk_small.shape[1])).astype(np.float32))
    exact = lfi.search_exact(q)
    res = lfi.search(q, quality_target=0.99)
    recall = float((res.dists[:, 0] <= exact.dists[:, 0] * (1 + 1e-5) + 1e-6)
                   .mean())
    assert recall >= 0.9, recall           # loose bound for a tiny build
    assert res.searched.mean() <= exact.searched.mean() + 1e-9
