"""Training-loop integration: loss decreases, compression path trains."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer
from repro.optim import AdamWConfig, adamw_init


@pytest.mark.parametrize("compress", [False, True])
def test_loss_decreases_over_steps(compress):
    cfg = configs.get_smoke("codeqwen1_5_7b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(transformer.make_train_step(
        cfg, AdamWConfig(lr=3e-3), compress_grads=compress))
    rng = np.random.default_rng(0)
    # fixed batch: the model must be able to overfit it
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32),
    }
    losses = []
    for _ in range(30):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[:3] + losses[-3:]
    assert np.isfinite(losses).all()


def test_compressed_grads_close_to_exact():
    cfg = configs.get_smoke("glm4_9b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32),
    }
    exact = jax.jit(transformer.make_train_step(cfg))
    comp = jax.jit(transformer.make_train_step(cfg, compress_grads=True))
    p1, _, m1 = exact(params, adamw_init(params), batch)
    p2, _, m2 = comp(params, adamw_init(params), batch)
    # same loss (compression is post-grad), near-identical update direction
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    d1 = jnp.concatenate([(a - b).ravel() for a, b in zip(
        jax.tree.leaves(p1), jax.tree.leaves(params))])
    d2 = jnp.concatenate([(a - b).ravel() for a, b in zip(
        jax.tree.leaves(p2), jax.tree.leaves(params))])
    cos = float((d1 @ d2) / (jnp.linalg.norm(d1) * jnp.linalg.norm(d2)))
    assert cos > 0.98, cos
