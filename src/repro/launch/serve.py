"""Serving driver: prefill + batched decode with the ring KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
        --batch 4 --prompt-len 64 --gen 32

LeaFi retrieval serving (the similarity-search substrate) is a thin driver
over :mod:`repro.serving` with ``--arch leafi``: it cold-starts a
:class:`~repro.serving.session.ServingSession` from a checkpoint (or builds
a smoke-sized index and checkpoints it when ``--ckpt`` is given), pre-warms
the per-(bucket, k) programs, and drives a seeded Poisson open-loop trace of
heterogeneous requests (mixed per-query quality targets) through the
dynamic micro-batcher, reporting p50/p95/p99 latency, throughput, pruning
and per-target-group achieved recall.

    PYTHONPATH=src python -m repro.launch.serve --arch leafi --batch 32 \
        --requests 256 --rate 200 --targets 0.9,0.95,0.99 \
        --ckpt /tmp/leafi_ckpt

``--dist`` additionally routes a batch through the leaf-sharded shard_map
search (``core/distributed.py``) over every visible device, timing both
per-shard strategies — with the fixed-width compaction's survivor capacity
auto-tuned from the serving telemetry's observed survivor counts.

Filter-health observability: ``--shadow-rate R`` re-executes a
deterministic fraction R of requests through the exact scan off the
critical path (true recall + per-miss leaf/bound attribution);
``--health-dump PATH`` writes the windowed per-leaf scoreboard JSON
(``Telemetry.filters_needing_attention`` is the programmatic form); and
``--explain RID`` prints one request's full bound-attribution report.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from .. import configs
from ..models import transformer


def _print_serve_report(report: dict, label: str = "") -> None:
    tag = f" [{label}]" if label else ""
    if report["n_requests"] == 0:
        # zero completions (e.g. an empty trace): throughput/makespan are
        # absent and every windowed stat is NaN — report that, don't crash
        print(f"served{tag} 0 requests in {report['n_batches']} batches "
              f"(no completions)")
        return
    print(f"served{tag} {report['n_requests']} requests in "
          f"{report['n_batches']} batches "
          f"(padding {report['padding_fraction']:.1%}): "
          f"{report['throughput_qps']:.1f} qps, latency "
          f"p50 {report['p50']*1e3:.1f}ms / p95 {report['p95']*1e3:.1f}ms "
          f"/ p99 {report['p99']*1e3:.1f}ms, pruning "
          f"{report['pruning_ratio']:.3f}")
    for t, rec in report["recall_by_target"].items():
        print(f"  target {t:.3f}: achieved recall {rec['recall']:.3f} "
              f"(n={rec['n']})")


def serve_leafi(args) -> None:
    """Open-loop micro-batched serving over the LeaFi engine."""
    import numpy as np

    from ..core import build, filter_training
    from ..core.summaries import znormalize
    from ..obs import SpanRecorder, export as obs_export, set_recorder
    from ..serving import MicroBatcher, ServingSession, poisson_trace

    recorder = None
    if args.trace_dump:
        # isolated capture: build + serve spans land here, not in the
        # process default recorder
        recorder = SpanRecorder()
        set_recorder(recorder)

    targets = tuple(float(t) for t in args.targets.split(","))
    # per-leaf health needs the engine's audit stream; shadow/health/explain
    # all imply it (results stay bitwise identical with it on)
    audit = bool(args.shadow_rate > 0 or args.health_dump
                 or args.explain is not None)
    session_kw = dict(strategy=args.strategy, warm_start=args.warm_start,
                      audit=audit, shadow_rate=args.shadow_rate,
                      shadow_seed=args.seed)
    if args.ckpt and os.path.exists(os.path.join(args.ckpt, "DONE")):
        t0 = time.perf_counter()
        session = ServingSession.from_checkpoint(args.ckpt, **session_kw)
        print(f"cold start from {args.ckpt}: "
              f"{time.perf_counter() - t0:.2f}s "
              f"({session.lfi.index.n_series} series, "
              f"{len(session.lfi.leaf_ids)} filters)")
    else:
        rng = np.random.default_rng(args.seed)
        n, m = 20_000, 128
        S = rng.standard_normal((n, m), dtype=np.float32).cumsum(axis=1)
        print(f"building LeaFi index over {n}x{m} series...")
        lfi = build.build_leafi(S, build.LeaFiConfig(
            backbone="dstree", leaf_capacity=256, n_global=200, n_local=60,
            t_filter_over_t_series=20.0,
            train=filter_training.TrainConfig(epochs=40)))
        session = ServingSession(lfi, **session_kw)
        if args.ckpt:
            session.save(args.ckpt)
            print(f"checkpointed index to {args.ckpt} "
                  f"(next start is a cold start)")

    idx = session.lfi.index
    rng = np.random.default_rng(args.seed + 1)
    pool = znormalize(
        np.asarray(idx.series[:idx.n_series])[
            rng.integers(0, idx.n_series, 256)]
        + 0.3 * rng.standard_normal((256, idx.length)).astype(np.float32))

    n_warm = session.warmup(max_batch=args.batch, ks=(args.k,),
                            queries=pool, targets=targets)
    print(f"warmed {n_warm} (bucket, k) programs "
          f"[strategy={args.strategy}]")

    trace = poisson_trace(pool, rate=args.rate, n_requests=args.requests,
                          targets=targets, ks=(args.k,), seed=args.seed)
    exact = session.search_exact(np.stack([r.query for r in trace]))
    oracle = {r.rid: float(exact.dists[i, 0])
              for i, r in enumerate(trace)}

    service_time = None
    if args.pipeline:
        # pipelined serving needs an injected virtual clock (the host can't
        # time overlapped execution): model per-batch cost from one timed
        # warm full-bucket search, scaled by bucket fill.
        q = pool[np.arange(args.batch) % len(pool)]
        t = np.asarray(targets)[np.arange(args.batch) % len(targets)]
        t0 = time.perf_counter()
        session._search_async(q, t, args.k).result()
        model_s = time.perf_counter() - t0
        service_time = lambda b: model_s * max(b.bucket / args.batch, 0.25)  # noqa: E731
        print(f"pipeline depth {args.pipeline}: service model "
              f"{model_s*1e3:.1f}ms/full batch")

    report = session.serve(
        trace, batcher=MicroBatcher(max_batch=args.batch,
                                    max_wait=args.max_wait_ms / 1e3),
        recall_oracle=oracle, service_time=service_time,
        pipeline=args.pipeline)
    _print_serve_report(report)

    if "shadow" in report:
        sh = report["shadow"]
        print(f"shadow audit: {sh['n_shadowed']} queries re-executed "
              f"exactly (rate {args.shadow_rate:g}), true recall "
              f"{sh['recall_mean']:.3f}, {len(sh['misses'])} lost true "
              f"neighbor(s)")
        for m in sh["misses"][:5]:
            print(f"  rid {m['rid']}: neighbor #{m['id']} at "
                  f"{m['dist']:.4f} lost to leaf {m['leaf']} "
                  f"({m['bound']} bound)")
    flagged = session.telemetry.filters_needing_attention()
    if audit and flagged:
        print(f"filters needing attention ({len(flagged)} leaves):")
        for r in flagged[:5]:
            print(f"  leaf {r.leaf}: {','.join(r.reasons)} "
                  f"(violation rate {r.violation_rate:.3f}, worst "
                  f"residual {r.resid_min:.3f}, shadow misses "
                  f"{r.shadow_misses})")

    if args.health_dump:
        import json
        with open(args.health_dump, "w") as fh:
            json.dump(session.telemetry.health.snapshot(), fh, indent=2,
                      default=float)
        print(f"health scoreboard dumped to {args.health_dump}")

    if args.explain is not None:
        from ..obs import explain as obs_explain
        from ..serving import explain_query
        match = [r for r in trace if r.rid == args.explain] or [trace[0]]
        r = match[0]
        ctx = explain_query(session, r.query, target=r.quality_target,
                            k=r.k, rid=r.rid)
        print(obs_explain.render_text(ctx))

    if args.dist:
        if args.k == 1:
            serve_leafi_dist_trace(session.lfi, trace, args, oracle)
        else:
            print("(--dist trace serving needs --k 1; the distributed "
                  "exchange reduces a single nn distance)")
        serve_leafi_distributed(session.lfi, pool[:args.batch],
                                session.telemetry)
        session_for_summary = session
    else:
        session_for_summary = session

    if args.summary:
        import json
        print("telemetry summary:")
        print(json.dumps(session_for_summary.telemetry.summary(), indent=2,
                         default=float))

    if args.metrics_dump:
        obs_export.write_metrics(args.metrics_dump,
                                 session.telemetry.registry)
        fmt = ("prometheus" if args.metrics_dump.endswith(".prom")
               else "jsonl")
        print(f"metrics dumped to {args.metrics_dump} ({fmt})")
    if args.trace_dump:
        set_recorder(None)
        obs_export.write_chrome_trace(args.trace_dump,
                                      spans=recorder.drain(),
                                      batch_log=report["batches"])
        print(f"chrome trace dumped to {args.trace_dump} "
              f"(open in https://ui.perfetto.dev)")


def serve_leafi_dist_trace(lfi, trace, args, oracle) -> None:
    """Serve the same open-loop trace through the shard_map executor.

    Shards the index over every visible device on a 1×D mesh and drives the
    identical micro-batched trace through a
    :class:`~repro.serving.session.DistributedExecutor` (per-query conformal
    offset rows through shard_map; pipelined when ``--pipeline``).
    """
    import numpy as np

    from ..core import distributed
    from ..serving import DistributedExecutor, MicroBatcher, ServingSession

    D = max(len(jax.devices()), 1)
    mesh = distributed.make_search_mesh(1, D)
    executor = DistributedExecutor(lfi, mesh, strategy=args.strategy)
    session = ServingSession(lfi, strategy=args.strategy,
                             warm_start=args.warm_start, executor=executor)
    targets = tuple(float(t) for t in args.targets.split(","))
    with mesh:
        session.warmup(max_batch=args.batch, ks=(1,), targets=targets)
        service_time = None
        if args.pipeline:
            q = np.asarray(lfi.index.series[:args.batch])
            t = np.asarray(targets)[np.arange(args.batch) % len(targets)]
            t0 = time.perf_counter()
            session._search_async(q, t, 1).result()
            model_s = time.perf_counter() - t0
            service_time = lambda b: model_s * max(b.bucket / args.batch, 0.25)  # noqa: E731
        report = session.serve(
            trace, batcher=MicroBatcher(max_batch=args.batch,
                                        max_wait=args.max_wait_ms / 1e3),
            recall_oracle=oracle, service_time=service_time,
            pipeline=args.pipeline)
    _print_serve_report(report, label=f"dist x{D}")


def serve_leafi_distributed(lfi, q, telemetry=None) -> None:
    """Route the same requests through the shard_map search (1-NN).

    Shards the index over every visible device on a 1×D mesh; run with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=D`` to smoke the
    multi-shard path off-TPU.  Compares both per-shard strategies — the
    masked scan and the fixed-width survivor compaction (the default, which
    skips non-survivor distance compute with fully static shapes).  When
    serving telemetry is available, the compaction's survivor capacity comes
    from its observed survivor-count percentile instead of the static P/8
    default (conservative: counts were observed on the unsharded leaf set).
    """
    import numpy as np

    from ..core import distributed, engine

    D = max(len(jax.devices()), 1)
    mesh = distributed.make_search_mesh(1, D)
    sharded = distributed.shard_leafi(lfi, n_shards=D)
    P = sharded.leaf_size.shape[1]
    tuned = None
    if telemetry is not None and telemetry.survivors:
        tuned = telemetry.suggest_max_survivors(P)
        print(f"distributed serve: {D} shard(s), {P} leaf slots/shard, "
              f"max_survivors {tuned} (telemetry-tuned; static default "
              f"{engine.default_max_survivors(P)})")
    else:
        print(f"distributed serve: {D} shard(s), {P} leaf slots/shard")
    for strategy in ("scan", "compact"):
        run, *_ = distributed.make_distributed_search(
            mesh, sharded, strategy=strategy,
            max_survivors=tuned if strategy == "compact" else None)
        with mesh:
            nn, total = run(jnp.asarray(q))         # warmup / compile
            jax.block_until_ready(nn)
            t0 = time.perf_counter()
            nn, total = run(jnp.asarray(q))
            jax.block_until_ready(nn)
            dt = time.perf_counter() - t0
        print(f"serve[dist/{strategy:7s}] {q.shape[0]} queries 1-NN: "
              f"{dt*1e3:.1f}ms  total searched "
              f"{np.asarray(total).mean():.1f} leaves/query")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--strategy", default="compact",
                    choices=("scan", "compact"),
                    help="engine execution plan for --arch leafi")
    ap.add_argument("--k", type=int, default=5,
                    help="neighbours per request (--arch leafi)")
    ap.add_argument("--requests", type=int, default=128,
                    help="open-loop trace length (--arch leafi)")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate, req/s (--arch leafi)")
    ap.add_argument("--targets", default="0.9,0.95,0.99",
                    help="comma-separated per-request quality targets")
    ap.add_argument("--max-wait-ms", type=float, default=20.0,
                    help="micro-batcher deadline-flush wait")
    ap.add_argument("--ckpt", default=None,
                    help="index checkpoint dir: loads if present, "
                         "else builds and saves (--arch leafi)")
    ap.add_argument("--dist", action="store_true",
                    help="also smoke the sharded (shard_map) search path "
                         "(--arch leafi only; with --k 1 the full trace is "
                         "re-served through the distributed executor; set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N "
                         "for N shards off-TPU)")
    ap.add_argument("--pipeline", type=int, default=0,
                    help="pipelined serving depth (batches in flight; "
                         "0 = serial; --arch leafi)")
    ap.add_argument("--warm-start", action="store_true",
                    help="cross-batch bsf warm-starting (--arch leafi)")
    ap.add_argument("--summary", action="store_true",
                    help="print the session telemetry summary (rolling "
                         "percentiles incl. queue-wait/form/execute phases)")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="dump the serving metrics registry on exit: "
                         "JSON-lines, or Prometheus text exposition when "
                         "PATH ends in .prom (--arch leafi)")
    ap.add_argument("--shadow-rate", type=float, default=0.0,
                    help="fraction of requests re-executed exactly off the "
                         "critical path for true-recall auditing "
                         "(deterministic per-rid sampling; --arch leafi)")
    ap.add_argument("--health-dump", default=None, metavar="PATH",
                    help="dump the per-leaf filter-health scoreboard "
                         "(windowed audit + shadow evidence) as JSON on "
                         "exit (--arch leafi; implies audited serving)")
    ap.add_argument("--explain", type=int, default=None, metavar="RID",
                    help="print a per-query explain report (bound "
                         "attribution, residuals, shadow-truth misses) for "
                         "one request id of the trace (--arch leafi)")
    ap.add_argument("--trace-dump", default=None, metavar="PATH",
                    help="dump a Chrome trace-event JSON of the serve run "
                         "(batch dispatch/in-flight/harvest lanes + host "
                         "spans; open in Perfetto) (--arch leafi)")
    args = ap.parse_args()

    if args.arch == "leafi":
        serve_leafi(args)
        return

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.input_mode != "tokens":
        raise SystemExit("serve example drives token models; "
                         "see retrieval_serving.py for embedding backbones")
    total = args.prompt_len + args.gen
    cache_len = configs.decode_cache_len(cfg, total)
    params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    key = jax.random.PRNGKey(args.seed + 1)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)

    prefill = jax.jit(transformer.make_prefill_step(cfg, cache_len))
    decode = jax.jit(transformer.make_decode_step(cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": prompt})
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.1f}ms")

    tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tokens]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = decode(params, cache, {"tokens": tokens}, pos)
        tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tokens)
    jax.block_until_ready(tokens)
    t_dec = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decode {args.gen-1} steps: {t_dec*1e3:.1f}ms "
          f"({t_dec/(args.gen-1)*1e3:.1f}ms/tok/batch)")
    print("generated ids[0,:16]:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
