"""Serving driver: prefill + batched decode with the ring KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
        --batch 4 --prompt-len 64 --gen 32

LeaFi retrieval serving (the similarity-search substrate) goes through the
same driver with ``--arch leafi``: it builds a smoke-sized LeaFi index and
answers batched k-NN requests through the :mod:`repro.core.engine` cascade,
reporting per-batch latency for both engine strategies.

    PYTHONPATH=src python -m repro.launch.serve --arch leafi --batch 32

``--dist`` additionally routes the batch through the leaf-sharded shard_map
search (``core/distributed.py``) over every visible device, timing both
per-shard strategies (masked scan vs fixed-width survivor compaction).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import configs
from ..models import transformer


def serve_leafi(args) -> None:
    """Batched retrieval serving through the engine (scan vs compact)."""
    import numpy as np

    from ..core import build, filter_training
    from ..core.summaries import znormalize

    rng = np.random.default_rng(args.seed)
    n, m = 20_000, 128
    S = rng.standard_normal((n, m), dtype=np.float32).cumsum(axis=1)
    print(f"building LeaFi index over {n}x{m} series...")
    lfi = build.build_leafi(S, build.LeaFiConfig(
        backbone="dstree", leaf_capacity=256, n_global=200, n_local=60,
        t_filter_over_t_series=20.0,
        train=filter_training.TrainConfig(epochs=40)))
    q = znormalize(S[rng.integers(0, n, args.batch)]
                   + 0.3 * rng.standard_normal((args.batch, m))
                   .astype(np.float32))

    for strategy in ("scan", "compact"):
        lfi.search(q, k=5, quality_target=0.99, strategy=strategy)  # warmup
        t0 = time.perf_counter()
        res = lfi.search(q, k=5, quality_target=0.99, strategy=strategy)
        dt = time.perf_counter() - t0
        print(f"serve[{strategy:7s}] {args.batch} queries k=5: "
              f"{dt*1e3:.1f}ms  searched {res.searched.mean():.1f} "
              f"computed {res.computed.mean():.1f} "
              f"of {res.n_leaves} leaves/query")

    if args.dist:
        serve_leafi_distributed(lfi, q)


def serve_leafi_distributed(lfi, q) -> None:
    """Route the same requests through the shard_map search (1-NN).

    Shards the index over every visible device on a 1×D mesh; run with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=D`` to smoke the
    multi-shard path off-TPU.  Compares both per-shard strategies — the
    masked scan and the fixed-width survivor compaction (the default, which
    skips non-survivor distance compute with fully static shapes).
    """
    import numpy as np

    from ..core import distributed

    D = max(len(jax.devices()), 1)
    mesh = distributed.make_search_mesh(1, D)
    sharded = distributed.shard_leafi(lfi, n_shards=D)
    print(f"distributed serve: {D} shard(s), "
          f"{sharded.leaf_size.shape[1]} leaf slots/shard")
    for strategy in ("scan", "compact"):
        run, *_ = distributed.make_distributed_search(
            mesh, sharded, strategy=strategy)
        with mesh:
            nn, total = run(jnp.asarray(q))         # warmup / compile
            jax.block_until_ready(nn)
            t0 = time.perf_counter()
            nn, total = run(jnp.asarray(q))
            jax.block_until_ready(nn)
            dt = time.perf_counter() - t0
        print(f"serve[dist/{strategy:7s}] {q.shape[0]} queries 1-NN: "
              f"{dt*1e3:.1f}ms  total searched "
              f"{np.asarray(total).mean():.1f} leaves/query")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dist", action="store_true",
                    help="also smoke the sharded (shard_map) search path "
                         "(--arch leafi only; set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N for N "
                         "shards off-TPU)")
    args = ap.parse_args()

    if args.arch == "leafi":
        serve_leafi(args)
        return

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.input_mode != "tokens":
        raise SystemExit("serve example drives token models; "
                         "see retrieval_serving.py for embedding backbones")
    total = args.prompt_len + args.gen
    cache_len = configs.decode_cache_len(cfg, total)
    params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    key = jax.random.PRNGKey(args.seed + 1)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)

    prefill = jax.jit(transformer.make_prefill_step(cfg, cache_len))
    decode = jax.jit(transformer.make_decode_step(cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": prompt})
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.1f}ms")

    tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tokens]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = decode(params, cache, {"tokens": tokens}, pos)
        tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tokens)
    jax.block_until_ready(tokens)
    t_dec = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decode {args.gen-1} steps: {t_dec*1e3:.1f}ms "
          f"({t_dec/(args.gen-1)*1e3:.1f}ms/tok/batch)")
    print("generated ids[0,:16]:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
