import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes (16×16 single-pod, 2×16×16 two-pod) need 512
placeholder host devices.  Nothing here allocates real arrays — parameters,
optimizer state, batches and caches are ShapeDtypeStructs.

Per cell this script records:
  * compiled.memory_analysis()    — proves the cell fits HBM,
  * compiled.cost_analysis()      — FLOPs / bytes for §Roofline,
  * the parsed collective schedule (bytes by kind, while-loop aware),
  * the three roofline terms and the dominant bottleneck.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out experiments/dryrun
"""
import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..analysis import roofline as roofline_mod
from ..models import sharding as shmod
from ..models import transformer
from ..models.config import resolve_attn_policy
from ..optim import adamw_init
from .mesh import make_production_mesh


def _batch_spec(mesh, rules, shapes_dict):
    """Shard the leading batch dim over dp where divisible."""
    dp = rules.get("batch")
    out = {}
    for k, v in shapes_dict.items():
        if dp is None:
            out[k] = NamedSharding(mesh, P())
            continue
        axes = (dp,) if isinstance(dp, str) else tuple(dp)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        lead = v.shape[0] if v.shape else 0
        spec = (dp,) + (None,) * (len(v.shape) - 1) \
            if lead and lead % total == 0 else (None,) * len(v.shape)
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def _cache_shardings(mesh, cfg, rules, cache_shapes):
    specs = transformer.cache_specs(cfg, rules)
    out = {}
    for k, sds in cache_shapes.items():
        sp = list(specs[k])
        # divisibility guard per dim
        for i, ax in enumerate(sp):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            if sds.shape[i] % total != 0:
                sp[i] = None
        out[k] = NamedSharding(mesh, P(*sp))
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               smoke: bool = False, overrides: dict | None = None):
    """Build + lower + compile one cell; returns (compiled, meta)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    info = configs.input_specs(arch, shape_name, smoke=smoke,
                               overrides=overrides)
    cfg, shape = info["config"], info["shape"]
    tp = mesh.shape["model"]
    policy = resolve_attn_policy(cfg, tp)
    mode = {"train": "train", "prefill": "prefill",
            "decode": "decode"}[shape.kind]
    rules = shmod.make_rules(mode, policy, mesh, cfg)
    pspecs = shmod.param_specs(cfg, rules)
    param_shapes = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    param_sh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    meta = {"arch": arch, "shape": shape_name, "policy": policy,
            "mesh": dict(mesh.shape), "n_devices": mesh.size,
            "n_params": cfg.n_params, "n_active_params": cfg.n_active_params}

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(adamw_init, param_shapes)
        opt_sh = {"m": param_sh, "v": param_sh,
                  "step": NamedSharding(mesh, P())}
        batch_sh = _batch_spec(mesh, rules, info["inputs"])
        step = transformer.make_train_step(cfg)

        def fn(params, opt, batch):
            return step(params, opt, batch)

        jitted = jax.jit(fn, in_shardings=(param_sh, opt_sh, batch_sh),
                         out_shardings=(param_sh, opt_sh, None),
                         donate_argnums=(0, 1))
        with shmod.sharding_context(mesh, rules):
            lowered = jitted.lower(param_shapes, opt_shapes, info["inputs"])
    elif shape.kind == "prefill":
        prefill = transformer.make_prefill_step(cfg, info["cache_len"])
        batch_sh = _batch_spec(mesh, rules, info["inputs"])
        jitted = jax.jit(prefill, in_shardings=(param_sh, batch_sh))
        with shmod.sharding_context(mesh, rules):
            lowered = jitted.lower(param_shapes, info["inputs"])
    else:  # decode
        decode = transformer.make_decode_step(cfg)
        batch_sh = _batch_spec(mesh, rules, info["inputs"])
        cache_sh = _cache_shardings(mesh, cfg, rules, info["cache"])
        jitted = jax.jit(
            decode,
            in_shardings=(param_sh, cache_sh, batch_sh,
                          NamedSharding(mesh, P())),
            out_shardings=(None, cache_sh),
            donate_argnums=(1,))
        with shmod.sharding_context(mesh, rules):
            lowered = jitted.lower(param_shapes, info["cache"],
                                   info["inputs"], info["pos"])

    compiled = lowered.compile()
    return compiled, cfg, shape, meta


def run_leafi_serve(multi_pod: bool, strategy: str = "compact") -> dict:
    """Dry-run the PAPER's own system at pod scale: the leaf-sharded LeaFi
    search (core/distributed.py) lowered on the production mesh.

    Sizing mirrors the paper's production setting: 25M series × len 256
    (= the paper's datasets), ~16k leaves (MESSI-like), ~10k max leaf size,
    one stacked MLP filter slot per leaf, 1024-query request batch.
    ``strategy`` picks the per-shard phase-2 body: "compact" (default) is
    the fixed-width survivor compaction — proves the static-shape plan
    (survivor buffer + overflow conditional) lowers and fits on the
    production mesh; "scan" is the masked-scan fallback.
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    from ..core import distributed
    n_shards = mesh.shape["model"]
    m, h = 256, 256
    leaves_per_shard = 1024
    rows_per_shard = 25_000_000 // n_shards + 10_000
    specs = distributed.search_input_specs(
        n_shards, leaves_per_shard, rows_per_shard, m, h,
        n_queries=1024, coord_dim=16)
    fn, _, _ = distributed.build_search_fn(mesh, max_leaf=10_000,
                                           strategy=strategy)
    t0 = time.perf_counter()
    with mesh:
        lowered = fn.lower(*specs)
        compiled = lowered.compile()
    hlo = compiled.as_text()
    terms = roofline_mod.roofline_from_compiled(
        compiled, n_devices=mesh.size, hlo_text=hlo)
    return {
        "arch": "leafi-serve", "shape": "q1024_n25m",
        "strategy": strategy,
        "mesh": dict(mesh.shape), "status": "ok",
        "compile_s": round(time.perf_counter() - t0, 1),
        "memory": roofline_mod.memory_report(compiled),
        "roofline": terms.as_dict(),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             smoke: bool = False, overrides: dict | None = None) -> dict:
    t0 = time.perf_counter()
    compiled, cfg, shape, meta = lower_cell(arch, shape_name, multi_pod,
                                            smoke, overrides)
    if overrides:
        meta = dict(meta, overrides=overrides)
    t_compile = time.perf_counter() - t0
    hlo = compiled.as_text()
    terms = roofline_mod.roofline_from_compiled(
        compiled, n_devices=meta["n_devices"],
        model_flops=roofline_mod.model_flops_per_step(cfg, shape),
        hlo_text=hlo)
    mem = roofline_mod.memory_report(compiled)
    from ..analysis.hlo_collectives import hlo_stats
    sched = hlo_stats(hlo, f32_as_bf16=True)
    out = dict(meta)
    out.update({
        "status": "ok",
        "compile_s": round(t_compile, 1),
        "memory": mem,
        "roofline": terms.as_dict(),
        "collective_schedule": {
            "bytes_by_kind": sched.bytes_by_kind,
            "count_by_kind": sched.count_by_kind,
        },
        "hlo_bytes": len(hlo),
    })
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache variant (decode cells)")
    ap.add_argument("--tag", default="", help="suffix for output files")
    args = ap.parse_args()
    overrides = {"kv_quant": True} if args.kv_quant else None

    if args.arch == "leafi-serve":
        os.makedirs(args.out, exist_ok=True)
        for mp in {"single": [False], "multi": [True],
                   "both": [False, True]}[args.mesh]:
            tag = f"leafi_serve__{'pod2' if mp else 'pod1'}{args.tag}"
            try:
                rec = run_leafi_serve(mp)
                print(f"OK   {tag} compile={rec['compile_s']}s "
                      f"dominant={rec['roofline']['dominant']}")
            except Exception as e:  # noqa: BLE001
                rec = {"status": "error", "error": repr(e),
                       "trace": traceback.format_exc()[-2000:]}
                print(f"FAIL {tag}: {e}")
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1, default=str)
        return

    archs = configs.ARCH_IDS if args.arch == "all" \
        else [configs.PUBLIC_IDS.get(args.arch, args.arch)]
    shapes = list(configs.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"SKIP {tag} (exists)")
                    continue
                if not configs.supports_shape(arch, shape):
                    rec = {"arch": arch, "shape": shape, "status": "skipped",
                           "reason": "full-attention arch at 524k context "
                                     "(DESIGN.md §skips)"}
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"SKIP {tag} (inapplicable)")
                    continue
                try:
                    rec = run_cell(arch, shape, mp, smoke=args.smoke,
                                   overrides=overrides)
                    dom = rec["roofline"]["dominant"]
                    print(f"OK   {tag} compile={rec['compile_s']}s "
                          f"dominant={dom} "
                          f"frac={rec['roofline']['roofline_fraction']:.3f}")
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape, "status": "error",
                           "multi_pod": mp, "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"FAIL {tag}: {e}")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=str)


if __name__ == "__main__":
    main()
