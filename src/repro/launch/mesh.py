"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state: mesh creation happens only inside launchers, after any
XLA_FLAGS the entrypoint set have taken effect.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """axis_types only exists on newer jax; older versions default to Auto."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(model: int = 1):
    """Whatever-fits mesh for local runs/examples (1 device ⇒ (1, 1))."""
    n = jax.device_count()
    data = max(n // model, 1)
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_type_kwargs(2))
