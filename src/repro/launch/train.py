"""Production training driver (also the end-to-end example backend).

    PYTHONPATH=src python -m repro.launch.train --arch codeqwen1.5-7b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Features exercised here (and tested in tests/test_train_loop.py):
  * deterministic stateless data pipeline (step-addressed → elastic-safe),
  * AdamW + cosine schedule + grad clipping,
  * atomic async checkpointing with --resume restart,
  * straggler detection + heartbeat registry wired around the step loop
    (single-host here; the control plane is transport-agnostic),
  * optional int8 gradient compression flag (cross-pod path).
"""
from __future__ import annotations

import argparse
import time

import jax

from .. import configs
from ..checkpoint import CheckpointManager
from ..data.tokens import TokenPipeline, TokenPipelineConfig
from ..models import transformer
from ..optim import AdamWConfig, adamw_init
from ..runtime import HeartbeatRegistry, StragglerDetector


def make_batch(pipe, cfg, step, batch, seq):
    raw = pipe.batch(step)
    d = {"tokens": raw["tokens"], "labels": raw["labels"]}
    if cfg.input_mode == "embeddings":
        key = jax.random.fold_in(jax.random.PRNGKey(1), step)
        d = {"embeds": jax.random.normal(key, (batch, seq, cfg.d_model)),
             "labels": raw["labels"]}
    elif cfg.input_mode == "mixed":
        n_img = max(seq // 4, 1)
        key = jax.random.fold_in(jax.random.PRNGKey(2), step)
        d = {"tokens": raw["tokens"][:, : seq - n_img],
             "patches": jax.random.normal(key, (batch, n_img, cfg.d_model)),
             "labels": raw["labels"][:, : seq - n_img]}
    return d


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    print(f"arch={cfg.name} params={cfg.n_params/1e6:.1f}M "
          f"(active {cfg.n_active_params/1e6:.1f}M)")

    pipe = TokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed))
    params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params)
    step0 = 0

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    if args.resume and ckpt.latest_step() is not None:
        (params, opt_state), meta = ckpt.restore(like=(params, opt_state))
        step0 = int(meta["step"]) + 1
        print(f"resumed from step {meta['step']}")

    train_step = jax.jit(transformer.make_train_step(
        cfg, AdamWConfig(lr=args.lr)), donate_argnums=(0, 1))

    reg = HeartbeatRegistry([0], timeout_s=600)
    stragglers = StragglerDetector([0])
    losses = []
    t_last = time.perf_counter()
    for step in range(step0, args.steps):
        batch = make_batch(pipe, cfg, step, args.batch, args.seq)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.perf_counter() - t_last
        t_last = time.perf_counter()
        reg.beat(0)
        stragglers.observe(0, dt)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} {dt*1e3:7.1f}ms")
        if step % args.ckpt_every == 0 and step > step0:
            ckpt.save(step, (params, opt_state))
    ckpt.save(args.steps - 1, (params, opt_state), blocking=True)
    ckpt.wait()
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}); "
          f"checkpoints at {args.ckpt_dir}: {ckpt.all_steps()}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
