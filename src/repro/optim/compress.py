"""Gradient compression for cross-pod all-reduce.

At 2+ pods the `pod` axis rides the slow inter-pod links; int8 block
quantization cuts those collective bytes 4x.  Scheme: per-block (last dim
tiles of 256) max-abs scaling, stochastic-rounding-free symmetric int8.
Used by the train step when ``TrainStepConfig.compress_pod_grads`` is set:
grads are psum'ed in int8 across `pod` (decompress-after), full precision
within a pod.  Error feedback (residual carry) keeps the bias bounded.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

BLOCK = 256


def int8_compress(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (...) → (int8 payload, per-block scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray,
                    shape: tuple, dtype=jnp.float32) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)
