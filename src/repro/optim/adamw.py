"""AdamW with decoupled weight decay and global-norm clipping.

No optax offline — this is a minimal, sharding-transparent implementation:
optimizer state mirrors the parameter pytree, so whatever PartitionSpecs the
params carry (TP over `model`, ZeRO/FSDP over `data`) apply to m/v too, and
pjit keeps the update fully sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: Any) -> dict:
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves))


def adamw_update(params: Any, grads: Any, state: dict, cfg: AdamWConfig,
                 lr_scale: jnp.ndarray | float = 1.0
                 ) -> Tuple[Any, dict, jnp.ndarray]:
    """One AdamW step.  Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        new_p = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        )
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [n[0] for n in new])
    new_state = {
        "m": jax.tree.unflatten(treedef, [n[1] for n in new]),
        "v": jax.tree.unflatten(treedef, [n[2] for n in new]),
        "step": step,
    }
    return new_params, new_state, gnorm
