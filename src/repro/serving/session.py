"""Serving session: a warmed, checkpointable facade over a built LeaFi index.

A :class:`ServingSession` owns the three things a long-lived serving process
needs beyond the engine itself:

* **cold start** — the built index (backbone arrays, stacked filter params,
  conformal tuner) round-trips through :mod:`repro.checkpoint` as one atomic
  pytree checkpoint (:func:`save_index` / :func:`load_index`), so a restart
  loads in seconds instead of re-running Alg. 1's build pipeline;
* **program cache pre-warm** — :meth:`ServingSession.warmup` drives one
  dummy search per (bucket, k) shape through the session's engine strategy,
  so jit compilation happens before traffic, not under it (the batcher's
  pow2 buckets are what keeps this set small);
* **execution + accounting** — :meth:`ServingSession.execute` answers one
  :class:`~repro.serving.batcher.MicroBatch` (per-query quality targets
  lowered to (B, F) conformal offset rows), and :meth:`ServingSession.serve`
  drives a whole open-loop trace through the micro-batcher, folding latency,
  pruning, survivor and recall counters into the session's
  :class:`~repro.serving.telemetry.Telemetry`.

Execution is split into an async **dispatch** (submit the batch's engine
programs; JAX returns device-array futures) and a blocking **harvest**
(materialize results), so :meth:`ServingSession.serve` can run *pipelined*
(``pipeline=1``): batch N+1's host-side formation and dispatch overlap
batch N's device execution.  Cross-batch **bsf warm-starting**
(``warm_start=True``) seeds each batch with prune-only upper bounds derived
from recently answered queries (:mod:`repro.serving.warmstart`), and
:class:`DistributedExecutor` routes the same micro-batches through the
shard_map'd multi-chip search with per-query conformal offset rows.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from .. import checkpoint
from ..core import build, conformal, search
from ..core.flat_index import FlatIndex
from ..obs import span
from . import batcher as batcher_mod
from .batcher import MicroBatch, MicroBatcher, Request, _pow2_floor
from .telemetry import (Telemetry, latency_percentiles,
                        observe_recall_cell, recall_summary)
from .warmstart import BsfCache

# ---------------------------------------------------------------------------
# index persistence (cold start)
# ---------------------------------------------------------------------------

_CONFIG_FIELDS = ("backbone", "leaf_capacity", "n_segments", "word_len",
                  "n_global", "n_local", "calib_fraction", "a",
                  "t_filter_over_t_series", "filter_memory_budget_bytes",
                  "hidden", "filter_type", "weight_dtype", "seed")


def save_index(path: str, lfi: build.LeaFiIndex,
               metadata: Optional[dict] = None) -> None:
    """Checkpoint a built LeaFi index (atomic; see checkpoint.save_pytree).

    Arrays (series, leaf layout, summarization payload, stacked filter
    params, tuner knots) go into the pytree; scalars and structure (kind,
    sizes, config) ride in the metadata blob, so :func:`load_index` can
    reconstruct without a template object.
    """
    idx = lfi.index
    tuner = lfi.tuner
    if lfi.filter_params is not None and \
            str(lfi.filter_params["w1"].dtype) == "bfloat16":
        # np.savez silently drops the bfloat16 dtype (round-trips as raw
        # void bytes), so bf16 indexes don't checkpoint: save the float32
        # index and build.requantize_leafi after load instead.
        raise ValueError(
            "bfloat16 filter weights cannot be checkpointed (np.savez "
            "loses the dtype); save the float32 index and requantize "
            "after load (build.requantize_leafi)")
    calib = getattr(lfi, "calib", None)
    tree = {
        "series": np.asarray(idx.series),
        "order": np.asarray(idx.order),
        "leaf_start": np.asarray(idx.leaf_start),
        "leaf_size": np.asarray(idx.leaf_size),
        "payload": {k: np.asarray(v) for k, v in idx.payload.items()},
        "filter_params": ({k: np.asarray(v)
                           for k, v in lfi.filter_params.items()}
                          if lfi.filter_params is not None else {}),
        "leaf_ids": np.asarray(lfi.leaf_ids, np.int64),
        "tuner": ({"knots_q": tuner.knots_q, "knots_o": tuner.knots_o,
                   "slopes": tuner.slopes, "max_offset": tuner.max_offset}
                  if tuner is not None else {}),
        "calib": ({"queries": np.asarray(calib.queries),
                   "d_lb": np.asarray(calib.d_lb),
                   "d_L": np.asarray(calib.d_L)}
                  if calib is not None else {}),
    }
    cfg = dataclasses.asdict(lfi.config)
    cfg.pop("train", None)                    # training recipe: not needed
    meta = {"kind": idx.kind, "max_leaf_size": int(idx.max_leaf_size),
            "n_series": int(idx.n_series), "length": int(idx.length),
            "config": cfg,
            "build_report": {k: float(v)
                             for k, v in lfi.build_report.items()}}
    meta.update(metadata or {})
    checkpoint.save_pytree(path, tree, meta)


def load_index(path: str) -> build.LeaFiIndex:
    """Rebuild a LeaFiIndex from a :func:`save_index` checkpoint.

    Search over the loaded index is pinned identical to the saved one
    (tests/test_serving.py): the arrays round-trip verbatim and the engine
    sees the same inputs in the same process context.
    """
    flat, meta = checkpoint.load_pytree(path)

    def group(name: str):
        """One top-level entry: a leaf array, or a dict of its children."""
        pre = f"['{name}']"
        if pre in flat:
            return flat[pre]
        return {k[len(pre) + 1:][2:-2]: v
                for k, v in flat.items() if k.startswith(pre + "/")}

    index = FlatIndex(
        kind=meta["kind"], series=group("series"), order=group("order"),
        leaf_start=group("leaf_start"), leaf_size=group("leaf_size"),
        max_leaf_size=int(meta["max_leaf_size"]),
        n_series=int(meta["n_series"]), length=int(meta["length"]),
        payload=group("payload"))
    params = group("filter_params") or None
    tn = group("tuner")
    tuner = conformal.AutoTuner(**tn) if tn else None
    cal = group("calib")
    calib = build.CalibSplit(**cal) if cal else None
    cfg_kw = {k: meta["config"][k] for k in _CONFIG_FIELDS
              if k in meta.get("config", {})}
    return build.LeaFiIndex(
        index=index, filter_params=params, leaf_ids=group("leaf_ids"),
        tuner=tuner, config=build.LeaFiConfig(**cfg_kw),
        build_report=dict(meta.get("build_report", {})), calib=calib)


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------


def _pow2_buckets(max_batch: int) -> List[int]:
    """Every bucket a MicroBatcher capped at ``max_batch`` can emit."""
    return [1 << i for i in range(_pow2_floor(max_batch).bit_length())]


# ---------------------------------------------------------------------------
# distributed execution backend (socket → shard_map)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _DistResult:
    """SearchResult-shaped view of the distributed exchange's outputs.

    The multi-chip search reduces a single nn distance and a psum'd
    searched-leaf total per query; per-leaf prune attribution and series
    ids stay shard-local (they never cross the pmin), so those fields are
    absent here.
    """
    dists: np.ndarray            # (Q, 1)
    searched: np.ndarray         # (Q,)
    n_leaves: int
    computed: Optional[np.ndarray] = None
    ids: Optional[np.ndarray] = None


@dataclasses.dataclass
class _PendingDist:
    """In-flight distributed batch: device-array futures until result()."""
    nn: object
    n_searched: object
    n_leaves: int

    def block_until_ready(self) -> "_PendingDist":
        import jax
        jax.block_until_ready(self.nn)
        return self

    def result(self) -> _DistResult:
        return _DistResult(dists=np.asarray(self.nn)[:, None],
                           searched=np.asarray(self.n_searched),
                           n_leaves=self.n_leaves)


class DistributedExecutor:
    """Routes serving micro-batches through the shard_map multi-chip search.

    Builds one jitted per-query-offset program over ``mesh``
    (:func:`repro.core.distributed.make_distributed_search` with
    ``per_query_offsets=True``): each query carries its own (L,) conformal
    offset row — mixed quality targets in one compiled program — plus the
    (Q,) prune-only ``bsf_ub`` warm bound.  ``donate=True`` hands the
    per-call query/offset/bound buffers to XLA so steady-state serving
    re-uses their device allocations (skipped on CPU, where donation is
    ignored).  k=1 only: the distributed exchange reduces a single nn
    distance per query.
    """

    def __init__(self, lfi: build.LeaFiIndex, mesh, *,
                 data_axes=("data",), model_axis: str = "model",
                 strategy: str = "compact",
                 max_survivors: Optional[int] = None,
                 dist_impl: Optional[str] = None, donate: bool = True):
        from ..core import distributed
        self.lfi = lfi
        self.n_leaves = lfi.index.n_leaves
        n_model = int(mesh.shape[model_axis])
        self.sharded = distributed.shard_leafi(lfi, n_model)
        self.run, self._idx_args, _, _ = distributed.make_distributed_search(
            mesh, self.sharded, data_axes=data_axes, model_axis=model_axis,
            strategy=strategy, max_survivors=max_survivors,
            dist_impl=dist_impl, per_query_offsets=True, donate=donate)

    def _offset_rows(self, targets, B: int) -> np.ndarray:
        """Per-query (B, L) conformal offset rows; +inf rows ⇒ exact search.

        ``d_F = pred − offset``, so a +inf offset drives every filter bound
        to −inf — the filter cascade can never fire and the distributed
        search answers exactly, from the same compiled program.
        """
        L = self.n_leaves
        if targets is None:
            return np.full((B, L), np.inf, np.float32)
        if self.lfi.tuner is None:
            return np.zeros((B, L), np.float32)
        off = conformal.scatter_offsets(
            self.lfi.tuner, self.lfi.leaf_ids, L,
            np.asarray(targets, np.float64))
        return np.asarray(off, np.float32).reshape(B, L)

    def dispatch(self, queries: np.ndarray, targets, k: int,
                 bsf_ub: Optional[np.ndarray] = None) -> _PendingDist:
        if int(k) != 1:
            raise ValueError("DistributedExecutor serves k=1 only "
                             f"(got k={k})")
        q = np.asarray(queries, np.float32)
        ub = (np.full(q.shape[0], np.inf, np.float32) if bsf_ub is None
              else np.asarray(bsf_ub, np.float32))
        nn, n_s = self.run(q, self._offset_rows(targets, q.shape[0]), ub)
        return _PendingDist(nn=nn, n_searched=n_s, n_leaves=self.n_leaves)


@dataclasses.dataclass
class PendingBatch:
    """One dispatched micro-batch awaiting harvest (FIFO, seq-ordered)."""
    pending: object               # PendingSearch | _PendingDist
    batch: MicroBatch
    seq: int
    # warm-start seed the batch was dispatched with (None when cold/off);
    # kept so the shadow sampler can attribute seed-bound exclusions
    bsf_ub: Optional[np.ndarray] = None


class ServingSession:
    """A query-serving runtime over one built LeaFi index.

    ``warm_start=True`` enables cross-batch bsf warm-starting: each
    dispatched batch is seeded with prune-only upper bounds from a rolling
    cache of recently answered queries (see :mod:`repro.serving.warmstart`
    for the triangle-inequality bound and the exactness argument).  Harvested
    results are *staged* and only committed to the cache ``warm_lag`` batches
    later, which makes serial and pipelined serving (any
    ``pipeline <= warm_lag + 1``) observe identical cache states — the
    trace-replay determinism tests pin serial vs ``pipeline=1`` bitwise.

    ``executor`` swaps the single-host engine for a
    :class:`DistributedExecutor` (k=1): batches flow through the shard_map
    search with per-query conformal offset rows instead of
    ``search_batched``.

    ``audit=True`` threads the engine's per-leaf
    :class:`~repro.obs.audit.FilterAudit` through every served batch
    (results stay bitwise identical) and folds it into the telemetry's
    :class:`~repro.obs.health.LeafHealthBoard`; ``shadow_rate > 0``
    attaches a :class:`~repro.serving.shadow.ShadowSampler` that captures
    a deterministic fraction of requests at harvest for off-critical-path
    exact-scan auditing (``serve`` drains it once per trace).  Both are
    single-host features: the distributed executor's exchange reduces a
    single nn distance, so there is nothing leaf-wise to audit host-side.
    """

    def __init__(self, lfi: build.LeaFiIndex, *, strategy: str = "compact",
                 dist_impl: Optional[str] = None,
                 telemetry: Optional[Telemetry] = None,
                 warm_start: bool = False, warm_lag: int = 1,
                 warm_capacity: int = 256,
                 executor: Optional[DistributedExecutor] = None,
                 audit: bool = False, shadow_rate: float = 0.0,
                 shadow_seed: int = 0):
        self.lfi = lfi
        self.strategy = strategy
        self.dist_impl = dist_impl
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.warm_start = bool(warm_start)
        self.warm_lag = int(warm_lag)
        self.warm_cache = BsfCache(capacity=warm_capacity)
        self.executor = executor
        self.audit = bool(audit) and executor is None
        self.shadow: Optional["ShadowSampler"] = None
        if shadow_rate > 0.0:
            from .shadow import ShadowSampler
            self.shadow = ShadowSampler(self, rate=shadow_rate,
                                        seed=shadow_seed)
        self._seq = 0
        self._warmed: set = set()

    # -- cold start ---------------------------------------------------------

    @classmethod
    def from_checkpoint(cls, path: str, **kw) -> "ServingSession":
        return cls(load_index(path), **kw)

    def save(self, path: str, metadata: Optional[dict] = None) -> None:
        save_index(path, self.lfi, metadata)

    # -- program pre-warm ---------------------------------------------------

    def warmup(self, *, max_batch: int = 64, ks: Sequence[int] = (1,),
               buckets: Optional[Sequence[int]] = None,
               queries: Optional[np.ndarray] = None,
               targets: Sequence[float] = (0.9, 0.99)) -> int:
        """Compile the per-(bucket, k) programs before traffic arrives.

        ``queries`` should be representative of live traffic when possible —
        the compact strategy's inner programs are additionally keyed on
        survivor-count buckets, which depend on how well real queries prune
        (the scan strategy is exactly one program per (bucket, k)).  Returns
        the number of (bucket, k) shapes warmed.
        """
        buckets = list(buckets) if buckets is not None \
            else _pow2_buckets(max_batch)
        if queries is None:
            idx = self.lfi.index
            queries = np.asarray(idx.series[:max(buckets)])
        n = 0
        for k in ks:
            for b in buckets:
                if (b, k) in self._warmed:
                    continue
                q = np.asarray(queries)[np.arange(b) % len(queries)]
                t = np.asarray(targets, np.float64)[np.arange(b)
                                                    % len(targets)]
                self._search_async(q, t, k).result()
                self._warmed.add((b, k))
                n += 1
        return n

    # -- execution ----------------------------------------------------------

    def _search_async(self, queries: np.ndarray, targets, k: int,
                      bsf_ub: Optional[np.ndarray] = None):
        """Dispatch one batch through the session's execution backend.

        Returns a pending handle (``.result()`` blocks): the distributed
        executor when one is attached, else the single-host async engine
        path with per-query targets lowered to (B, F) offset rows.
        """
        if self.executor is not None:
            return self.executor.dispatch(queries, targets, k, bsf_ub)
        lfi = self.lfi
        return search.search_batched_async(
            lfi.index, queries, k=k, filter_params=lfi.filter_params,
            leaf_ids=lfi.leaf_ids, tuner=lfi.tuner,
            quality_target=targets, use_filters=targets is not None,
            strategy=self.strategy, dist_impl=self.dist_impl,
            filter_type=getattr(lfi.config, "filter_type", "mlp"),
            bsf_ub=bsf_ub, audit=self.audit)

    def search(self, queries: np.ndarray,
               quality_targets=None, k: int = 1,
               record: bool = True, **kw) -> search.SearchResult:
        """One batched search; per-query targets lowered to offset rows."""
        lfi = self.lfi
        kw.setdefault("filter_type", getattr(lfi.config, "filter_type",
                                             "mlp"))
        res = search.search_batched(
            lfi.index, queries, k=k, filter_params=lfi.filter_params,
            leaf_ids=lfi.leaf_ids, tuner=lfi.tuner,
            quality_target=quality_targets,
            use_filters=quality_targets is not None,
            strategy=self.strategy, dist_impl=self.dist_impl, **kw)
        if record:
            Q = np.atleast_2d(queries).shape[0]
            self.telemetry.record_batch(res, n_valid=Q, bucket=Q)
        return res

    def search_exact(self, queries: np.ndarray,
                     k: int = 1) -> search.SearchResult:
        return self.search(queries, quality_targets=None, k=k, record=False)

    def dispatch(self, batch: MicroBatch) -> PendingBatch:
        """Submit one micro-batch asynchronously (returns before compute).

        Order of operations matters for determinism: the warm cache first
        *commits* staged results from batches ``<= seq − 1 − warm_lag``
        (identical in serial and pipelined serving — see the class
        docstring), then seeds this batch's prune-only bounds.  Host-side
        cost (offset lowering + program submit) is recorded as the ``form``
        latency phase; per-request queue waits (arrival → batch formation,
        virtual clock) ride along.
        """
        t0 = time.perf_counter()
        seq = self._seq
        self._seq += 1
        with span("serve.dispatch", cat="serve", seq=seq,
                  bucket=batch.bucket, n_valid=batch.n_valid, k=batch.k):
            bsf_ub = None
            if self.warm_start:
                self.warm_cache.commit_through(seq - 1 - self.warm_lag)
                bsf_ub = self.warm_cache.seed(batch.queries, batch.k)
            pending = self._search_async(batch.queries, batch.targets,
                                         batch.k, bsf_ub=bsf_ub)
        self.telemetry.record_phases(
            queue_wait=(batch.formed_at - batch.arrivals).tolist(),
            form_s=time.perf_counter() - t0)
        return PendingBatch(pending=pending, batch=batch, seq=seq,
                            bsf_ub=bsf_ub)

    def harvest(self, pb: PendingBatch):
        """Block on one dispatched batch; fold telemetry + warm staging."""
        t0 = time.perf_counter()
        with span("serve.harvest", cat="serve", seq=pb.seq,
                  bucket=pb.batch.bucket, n_valid=pb.batch.n_valid):
            res = pb.pending.result()
        self.telemetry.record_phases(exec_s=time.perf_counter() - t0)
        b = pb.batch
        if self.warm_start:
            kth = np.asarray(res.dists)[:b.n_valid, -1]
            self.warm_cache.stage(pb.seq, b.queries[:b.n_valid], kth, b.k)
        self.telemetry.record_batch(res, n_valid=b.n_valid, bucket=b.bucket)
        if getattr(res, "audit", None) is not None:
            # audit planes cover every bucket slot (padded rows repeat row
            # 0 — real queries for the accounting identity's purposes)
            self.telemetry.record_audit(res.audit, n_queries=b.bucket)
        if self.shadow is not None:
            self.shadow.capture(b, res, bsf_ub=pb.bsf_ub)
        return res

    def execute(self, batch: MicroBatch):
        """Answer one micro-batch synchronously (dispatch + harvest)."""
        return self.harvest(self.dispatch(batch))

    # -- open-loop serving --------------------------------------------------

    def serve(self, trace: Sequence[Request], *,
              batcher: Optional[MicroBatcher] = None,
              recall_oracle: Optional[Dict[int, float]] = None,
              service_time: Optional[Callable[[MicroBatch], float]] = None,
              pipeline: int = 0) -> dict:
        """Drive a whole arrival trace; returns a *per-trace* report.

        Every number in the report describes this trace alone — the
        session's :attr:`telemetry` keeps the rolling lifetime view across
        traces (and is also fed by this run).  Completions store a
        per-request projection (top-1 distance + searched count), not the
        batch results, so memory stays O(1) per request on long traces.

        ``recall_oracle`` maps rid → exact 1-NN distance; when given, each
        completion is scored against it (the paper's recall@1 rule) and
        folded into the per-target-group recall estimators.
        ``service_time`` replaces measured wall-clock with injected
        per-batch costs (fully deterministic runs for tests; see
        benchmarks/serve_bench.py for the fixed-schedule-replay use).

        ``pipeline=N`` (N ≥ 1) serves through
        :func:`~repro.serving.batcher.run_trace_pipelined` with up to N
        batches in flight — dispatch of batch N+1 overlaps device execution
        of batch N.  Requires an injected ``service_time`` (the virtual
        clock cannot be measured while execution overlaps); the batch
        sequence, completion times, and results are identical to the serial
        loop on the same trace (tests pin this bitwise).
        """
        batcher = batcher or MicroBatcher()

        def extract(res: search.SearchResult, pos: int) -> dict:
            return {"dist": float(np.asarray(res.dists)[pos, 0]),
                    "searched": float(np.asarray(res.searched)[pos]),
                    "n_leaves": res.n_leaves}

        if pipeline:
            completions, batch_log = batcher_mod.run_trace_pipelined(
                trace, batcher, self.dispatch, self.harvest,
                service_time=service_time, extract=extract,
                max_in_flight=pipeline)
        else:
            completions, batch_log = batcher_mod.run_trace(
                trace, batcher, self.execute, service_time=service_time,
                extract=extract)
        lats: List[float] = []
        searched: List[float] = []
        for c in completions.values():
            self.telemetry.record_latency(c["latency"])
            lats.append(c["latency"])
            searched.append(c["result"]["searched"])
        # score recall with the calibration-time rule (one shared
        # definition: conformal.recall_at_1), vectorized over the trace
        recall: Dict[float, list] = {}
        scored = ([] if recall_oracle is None else
                  [(rid, c) for rid, c in completions.items()
                   if rid in recall_oracle])
        if scored:
            hits = np.asarray(conformal.recall_at_1(
                np.asarray([c["result"]["dist"] for _, c in scored],
                           np.float32),
                np.asarray([recall_oracle[rid] for rid, _ in scored],
                           np.float32))) > 0
            for (rid, c), hit in zip(scored, hits):
                self.telemetry.observe_recall(c["target"], bool(hit))
                observe_recall_cell(recall, c["target"], bool(hit))
        n_valid = sum(b["n_valid"] for b in batch_log)
        n_slots = sum(b["bucket"] for b in batch_log)
        n_leaves = (next(iter(completions.values()))["result"]["n_leaves"]
                    if completions else 0)
        report = {
            "n_requests": len(completions),
            "n_batches": len(batch_log),
            "padding_fraction": (n_slots - n_valid) / max(n_slots, 1),
            "pruning_ratio": (1.0 - float(np.mean(searched)) / n_leaves
                              if searched and n_leaves else float("nan")),
            "recall_by_target": recall_summary(recall),
        }
        report.update(latency_percentiles(lats))
        if completions:
            first = min(r.arrival for r in trace)
            last = max(c["finish"] for c in completions.values())
            report["throughput_qps"] = len(completions) / max(last - first,
                                                              1e-12)
            report["makespan_s"] = last - first
        report["n_programs_warmed"] = len(self._warmed)
        if self.shadow is not None and self.shadow.pending_count:
            # off the critical path by construction: every completion above
            # is already timed/committed before the exact scans run
            shadow_report = self.shadow.drain()
            self.telemetry.record_shadow(shadow_report)
            report["shadow"] = shadow_report
        report["batches"] = batch_log
        report["completions"] = completions
        return report
