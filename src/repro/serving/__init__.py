"""LeaFi serving runtime: dynamic micro-batching over the search engine.

Public API:
    MicroBatcher, Request, MicroBatch      admission queue + flush policy
    poisson_trace, run_trace               open-loop traffic + event drive
    run_trace_pipelined                    overlapped dispatch/execute drive
    ServingSession, save_index, load_index warmed sessions + cold start
    DistributedExecutor                    micro-batches → shard_map search
    BsfCache                               cross-batch bsf warm-starting
    Telemetry, latency_percentiles         rolling serving counters
    ShadowSampler, explain_query           sampled exact-scan audit + explain
"""
from .batcher import (MicroBatch, MicroBatcher, Request,  # noqa: F401
                      poisson_trace, run_trace, run_trace_pipelined)
from .session import (DistributedExecutor, PendingBatch,  # noqa: F401
                      ServingSession, load_index, save_index)
from .shadow import ShadowSampler, explain_query          # noqa: F401
from .telemetry import Telemetry, latency_percentiles     # noqa: F401
from .warmstart import BsfCache                           # noqa: F401
