"""LeaFi serving runtime: dynamic micro-batching over the search engine.

Public API:
    MicroBatcher, Request, MicroBatch      admission queue + flush policy
    poisson_trace, run_trace               open-loop traffic + event drive
    ServingSession, save_index, load_index warmed sessions + cold start
    Telemetry, latency_percentiles         rolling serving counters
"""
from .batcher import (MicroBatch, MicroBatcher, Request,  # noqa: F401
                      poisson_trace, run_trace)
from .session import (ServingSession, load_index,         # noqa: F401
                      save_index)
from .telemetry import Telemetry, latency_percentiles     # noqa: F401
