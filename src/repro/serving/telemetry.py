"""Serving telemetry: rolling latency percentiles, pruning/survivor
counters, and an online achieved-recall estimator per quality-target group.

Everything is windowed (bounded deques) so a long-lived serving session
reports *recent* behaviour: latency p50/p95/p99 over the last W requests,
pruning ratio and survivor counts over the last W queries, and per-target
recall as a running (hits, total) pair per distinct requested target.

The survivor-count window doubles as the feedback signal for the
fixed-width distributed compaction: :meth:`Telemetry.suggest_max_survivors`
feeds a percentile of the observed counts to
:func:`repro.core.engine.tuned_max_survivors`, replacing the static P/8
capacity default with one the live workload justifies (ROADMAP PR-3
follow-up).
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Optional, Sequence

import numpy as np

from ..core import engine


def latency_percentiles(samples, pcts: Sequence[int] = (50, 95, 99)
                        ) -> Dict[str, float]:
    """{'p50': …, 'p95': …, 'p99': …} from a latency sample iterable."""
    arr = np.asarray(list(samples), np.float64)
    if arr.size == 0:
        return {f"p{p}": float("nan") for p in pcts}
    return {f"p{p}": float(np.percentile(arr, p)) for p in pcts}


def observe_recall_cell(cells: Dict[float, list], target: float,
                        hit: bool) -> None:
    """Fold one recall@1 outcome into a {target: [hits, total]} accumulator.

    The one definition of target-group keying (rounded to 6 decimals),
    shared by the lifetime :class:`Telemetry` window and the per-trace
    report in :meth:`~repro.serving.session.ServingSession.serve`."""
    cell = cells.setdefault(round(float(target), 6), [0, 0])
    cell[0] += bool(hit)
    cell[1] += 1


def recall_summary(cells: Dict[float, list]) -> Dict[float, Dict[str, float]]:
    """{target: {'recall': …, 'n': …}} view of a recall-cell accumulator."""
    return {t: {"recall": h / n if n else float("nan"), "n": n}
            for t, (h, n) in sorted(cells.items())}


class Telemetry:
    """Rolling serving counters; one instance per :class:`ServingSession`."""

    def __init__(self, window: int = 4096):
        self.window = window
        self.latencies: deque = deque(maxlen=window)      # seconds/request
        self.searched: deque = deque(maxlen=window)       # leaves/query
        self.survivors: deque = deque(maxlen=window)      # computed leaves/q
        # end-to-end latency decomposition (the pipeline-bubble view):
        # queue-wait is per request on the trace's virtual clock; batch
        # formation/dispatch and device-execute (result-harvest wait) are
        # per batch on the host's real clock.  In pipelined serving the
        # execute component is the *residual* wait after overlap — near
        # zero when dispatch of batch N+1 fully hides batch N's compute.
        self.queue_wait: deque = deque(maxlen=window)     # s/request
        self.form_s: deque = deque(maxlen=window)         # s/batch (host)
        self.exec_s: deque = deque(maxlen=window)         # s/batch (device)
        self._recall: Dict[float, list] = {}              # target → [hit, n]
        self.n_leaves: Optional[int] = None
        self.n_requests = 0
        self.n_batches = 0
        self.n_padded = 0                                 # wasted batch slots

    # -- recording ----------------------------------------------------------

    def record_batch(self, result, n_valid: int, bucket: int) -> None:
        """Fold one executed batch's SearchResult (valid rows only)."""
        self.n_batches += 1
        self.n_requests += n_valid
        self.n_padded += bucket - n_valid
        self.n_leaves = result.n_leaves
        self.searched.extend(np.asarray(result.searched)[:n_valid].tolist())
        if result.computed is not None:
            self.survivors.extend(
                np.asarray(result.computed)[:n_valid].tolist())

    def record_latency(self, seconds: float) -> None:
        self.latencies.append(float(seconds))

    def record_phases(self, *, queue_wait=None, form_s: float = None,
                      exec_s: float = None) -> None:
        """Fold one batch's latency-phase observations.

        ``queue_wait``: iterable of per-request waits (arrival → batch
        formation, virtual clock); ``form_s``: host batch-formation +
        dispatch seconds; ``exec_s``: device-execute / harvest-wait seconds.
        """
        if queue_wait is not None:
            self.queue_wait.extend(float(w) for w in queue_wait)
        if form_s is not None:
            self.form_s.append(float(form_s))
        if exec_s is not None:
            self.exec_s.append(float(exec_s))

    def observe_recall(self, target: float, hit: bool) -> None:
        """One request's recall@1 outcome against the exact oracle."""
        observe_recall_cell(self._recall, target, hit)

    # -- reading ------------------------------------------------------------

    def latency_percentiles(self) -> Dict[str, float]:
        return latency_percentiles(self.latencies)

    def pruning_ratio(self) -> float:
        if not self.searched or not self.n_leaves:
            return float("nan")
        return 1.0 - float(np.mean(self.searched)) / self.n_leaves

    def recall_by_target(self) -> Dict[float, Dict[str, float]]:
        return recall_summary(self._recall)

    def suggest_max_survivors(self, n_leaves: Optional[int] = None,
                              pct: float = 99.0) -> int:
        """Percentile-based survivor capacity from the observed window.

        Cold-start guard: with fewer observations than the ``pct``-th
        percentile needs to be meaningful (≈ ``100/(100−pct)`` samples, 100
        at the default p99), the estimate is floored at the engine's static
        default — a handful of easy early queries must not lock in an
        unstable low capacity (tests/test_serving.py pins this).
        """
        L = n_leaves if n_leaves is not None else (self.n_leaves or 1)
        min_samples = int(np.ceil(100.0 / max(100.0 - pct, 1.0)))
        return engine.tuned_max_survivors(np.asarray(self.survivors), L, pct,
                                          min_samples=min_samples)

    def phase_percentiles(self) -> Dict[str, Dict[str, float]]:
        """Rolling p50/p95/p99 of each latency phase (seconds)."""
        return {"queue_wait": latency_percentiles(self.queue_wait),
                "form": latency_percentiles(self.form_s),
                "execute": latency_percentiles(self.exec_s)}

    def summary(self) -> dict:
        out = {"n_requests": self.n_requests, "n_batches": self.n_batches,
               "padding_fraction": (self.n_padded /
                                    max(self.n_padded + self.n_requests, 1)),
               "pruning_ratio": self.pruning_ratio(),
               "recall_by_target": self.recall_by_target()}
        out.update(self.latency_percentiles())
        if self.queue_wait or self.form_s or self.exec_s:
            out["phases"] = self.phase_percentiles()
        if self.survivors:
            out["survivors_mean"] = float(np.mean(self.survivors))
            out["suggested_max_survivors"] = self.suggest_max_survivors()
        return out
