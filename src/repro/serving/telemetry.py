"""Serving telemetry: a facade over the :mod:`repro.obs.metrics` registry.

Every number the serving runtime reports — rolling latency percentiles,
pruning/survivor counters, per-target achieved recall — lives in registry
instruments (counters / gauges / windowed histograms), not in a parallel
deque implementation: ``Telemetry`` is the serving-shaped view over one
:class:`~repro.obs.metrics.MetricsRegistry`.  That buys three things:

* one export path — ``session.telemetry.registry`` snapshots/dumps as
  JSON-lines or Prometheus text like any other instrumented component
  (``launch/serve.py --metrics-dump``);
* windowed semantics for free — histograms keep lifetime count/sum plus a
  bounded rolling window, so a long-lived session reports *recent*
  behaviour (latency p50/p95/p99 over the last W requests, pruning and
  survivor counts over the last W queries);
* the recall-drift watchdog — achieved recall@1 per requested target feeds
  a :class:`~repro.obs.metrics.RecallDriftMonitor`, whose per-target flag
  is the staleness hook ROADMAP item 1's recalibration trigger consumes.

Determinism contract: only the ``form``/``exec`` phase histograms are fed
host wall-clock time, and they are registered ``wall=True`` so registry
snapshots segregate them under the ``"wall"`` subtree (the
trace-determinism test masks exactly that subtree).  Latency and
queue-wait ride the batcher's virtual clock under an injected
``service_time`` and are then bitwise-reproducible.

The survivor-count window doubles as the feedback signal for the
fixed-width distributed compaction: :meth:`Telemetry.suggest_max_survivors`
feeds a percentile of the observed counts to
:func:`repro.core.engine.tuned_max_survivors`, replacing the static P/8
capacity default with one the live workload justifies (ROADMAP PR-3
follow-up).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core import engine
from ..obs.health import LeafHealthBoard, LeafHealthReport
from ..obs.metrics import Histogram, MetricsRegistry, RecallDriftMonitor


def latency_percentiles(samples, pcts: Sequence[int] = (50, 95, 99)
                        ) -> Dict[str, float]:
    """{'p50': …, 'p95': …, 'p99': …} from a latency sample iterable.

    NaN-safe: an empty sample set yields NaN percentiles, never a
    traceback (the zero-request serve-report contract)."""
    arr = np.asarray(list(samples), np.float64)
    if arr.size == 0:
        return {f"p{p}": float("nan") for p in pcts}
    return {f"p{p}": float(np.percentile(arr, p)) for p in pcts}


def observe_recall_cell(cells: Dict[float, list], target: float,
                        hit: bool) -> None:
    """Fold one recall@1 outcome into a {target: [hits, total]} accumulator.

    The one definition of target-group keying (rounded to 6 decimals),
    shared by the lifetime :class:`Telemetry` window and the per-trace
    report in :meth:`~repro.serving.session.ServingSession.serve`."""
    cell = cells.setdefault(round(float(target), 6), [0, 0])
    cell[0] += bool(hit)
    cell[1] += 1


def recall_summary(cells: Dict[float, list]) -> Dict[float, Dict[str, float]]:
    """{target: {'recall': …, 'n': …}} view of a recall-cell accumulator."""
    return {t: {"recall": h / n if n else float("nan"), "n": n}
            for t, (h, n) in sorted(cells.items())}


class _WindowView:
    """Deque-shaped live view over one histogram's (unlabeled) window.

    Keeps the pre-registry ``Telemetry`` surface working: code that reads
    ``telemetry.latencies`` / ``len(telemetry.queue_wait)`` or seeds a
    window with ``telemetry.survivors.extend([...])`` goes through the
    registry instrument, so lifetime count/sum stay consistent with the
    window it mutates.
    """

    __slots__ = ("_hist",)

    def __init__(self, hist: Histogram):
        self._hist = hist

    def _window(self):
        s = self._hist._series.get(())
        return s.window if s is not None else ()

    def __len__(self) -> int:
        return len(self._window())

    def __iter__(self):
        return iter(list(self._window()))

    def __bool__(self) -> bool:
        return len(self) > 0

    def append(self, value: float) -> None:
        self._hist.observe(float(value))

    def extend(self, values) -> None:
        self._hist.extend(values)

    def clear(self) -> None:
        self._hist.reset_window()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"_WindowView({list(self._window())!r})"


class Telemetry:
    """Registry-backed rolling serving counters; one per ServingSession.

    ``registry=None`` creates a private :class:`MetricsRegistry` so
    concurrent sessions (and determinism tests) stay isolated; pass
    ``repro.obs.get_registry()`` to aggregate into the process-wide one.
    All instrument names carry the ``serve_`` prefix.
    """

    def __init__(self, window: int = 4096,
                 registry: Optional[MetricsRegistry] = None,
                 drift_window: int = 512, drift_min_samples: int = 64,
                 drift_slack: float = 0.0):
        self.window = window
        self.registry = registry if registry is not None else \
            MetricsRegistry()
        r = self.registry
        self._c_requests = r.counter(
            "serve_requests_total", help="valid requests answered")
        self._c_batches = r.counter(
            "serve_batches_total", help="micro-batches executed")
        self._c_padded = r.counter(
            "serve_padded_slots_total", help="wasted pow2-bucket slots")
        self._g_n_leaves = r.gauge(
            "serve_index_leaves", help="leaf count of the served index")
        self._g_pruning = r.gauge(
            "serve_pruning_ratio_windowed",
            help="1 - mean(searched)/n_leaves over the rolling window")
        self._h_latency = r.histogram(
            "serve_latency_s", window=window,
            help="end-to-end request latency (virtual clock under an "
                 "injected service_time)")
        self._h_searched = r.histogram(
            "serve_searched_leaves", window=window,
            help="leaves actually scanned per query")
        self._h_survivors = r.histogram(
            "serve_survivor_leaves", window=window,
            help="leaves the engine paid distance compute for, per query")
        self._h_queue_wait = r.histogram(
            "serve_queue_wait_s", window=window,
            help="request arrival -> batch formation (virtual clock)")
        # host wall-clock phases: segregated under the snapshot's "wall"
        # subtree so determinism tests can mask them (see module docstring)
        self._h_form = r.histogram(
            "serve_form_s", window=window, wall=True,
            help="host batch-formation + dispatch seconds per batch")
        self._h_exec = r.histogram(
            "serve_exec_s", window=window, wall=True,
            help="device-execute / harvest-wait seconds per batch")
        self.drift = RecallDriftMonitor(
            r, window=drift_window, min_samples=drift_min_samples,
            slack=drift_slack, prefix="serve")
        self.health = LeafHealthBoard(registry=r)
        self._recall: Dict[float, list] = {}              # target → [hit, n]
        self.n_leaves: Optional[int] = None

    # -- the pre-registry deque surface (live window views) -----------------

    @property
    def latencies(self) -> _WindowView:
        return _WindowView(self._h_latency)

    @property
    def searched(self) -> _WindowView:
        return _WindowView(self._h_searched)

    @property
    def survivors(self) -> _WindowView:
        return _WindowView(self._h_survivors)

    @property
    def queue_wait(self) -> _WindowView:
        return _WindowView(self._h_queue_wait)

    @property
    def form_s(self) -> _WindowView:
        return _WindowView(self._h_form)

    @property
    def exec_s(self) -> _WindowView:
        return _WindowView(self._h_exec)

    @property
    def n_requests(self) -> int:
        return int(self._c_requests.value())

    @property
    def n_batches(self) -> int:
        return int(self._c_batches.value())

    @property
    def n_padded(self) -> int:
        return int(self._c_padded.value())

    # -- recording ----------------------------------------------------------

    def record_batch(self, result, n_valid: int, bucket: int) -> None:
        """Fold one executed batch's SearchResult (valid rows only)."""
        self._c_batches.inc()
        self._c_requests.inc(n_valid)
        self._c_padded.inc(bucket - n_valid)
        self.n_leaves = result.n_leaves
        self._g_n_leaves.set(result.n_leaves)
        self._h_searched.extend(
            np.asarray(result.searched)[:n_valid].tolist())
        if result.computed is not None:
            self._h_survivors.extend(
                np.asarray(result.computed)[:n_valid].tolist())
        self._g_pruning.set(self.pruning_ratio())

    def record_latency(self, seconds: float) -> None:
        self._h_latency.observe(float(seconds))

    def record_phases(self, *, queue_wait=None, form_s: float = None,
                      exec_s: float = None) -> None:
        """Fold one batch's latency-phase observations.

        ``queue_wait``: iterable of per-request waits (arrival → batch
        formation, virtual clock); ``form_s``: host batch-formation +
        dispatch seconds; ``exec_s``: device-execute / harvest-wait seconds.
        """
        if queue_wait is not None:
            self._h_queue_wait.extend(float(w) for w in queue_wait)
        if form_s is not None:
            self._h_form.observe(float(form_s))
        if exec_s is not None:
            self._h_exec.observe(float(exec_s))

    def record_audit(self, audit: dict, n_queries: int) -> None:
        """Fold one audited batch's per-leaf FilterAudit dict
        (``SearchResult.audit``) into the rolling health board."""
        self.health.record_audit(audit, n_queries=n_queries)

    def record_shadow(self, shadow_report: dict) -> None:
        """Fold one drained shadow batch (``ShadowSampler.drain`` report):
        miss attributions reach the health board leaf-wise."""
        self.health.record_shadow(shadow_report.get("misses", ()),
                                  n_queries=shadow_report.get("n_shadowed",
                                                              0))

    def filters_needing_attention(self, **kw) -> List["LeafHealthReport"]:
        """Per-leaf staleness trigger (supersedes the per-target-only
        :meth:`recall_drifting` hook for ROADMAP item 1): flagged leaves,
        most severe first, from the windowed audit + shadow evidence."""
        return self.health.filters_needing_attention(**kw)

    def observe_recall(self, target: float, hit: bool) -> None:
        """One request's recall@1 outcome against the exact oracle.

        Feeds both the lifetime per-target accumulator and the windowed
        :class:`RecallDriftMonitor` (whose per-target flag is the
        recalibration hook)."""
        observe_recall_cell(self._recall, target, hit)
        self.drift.observe(target, hit)

    def flush_windows(self) -> None:
        """Drop every histogram's windowed samples (lifetime totals and
        recall accumulators survive) — e.g. after a recalibration, so the
        rolling views describe post-change behaviour only."""
        for h in (self._h_latency, self._h_searched, self._h_survivors,
                  self._h_queue_wait, self._h_form, self._h_exec):
            h.reset_window()

    # -- reading ------------------------------------------------------------

    def latency_percentiles(self) -> Dict[str, float]:
        return latency_percentiles(self._h_latency.window_values())

    def pruning_ratio(self) -> float:
        vals = self._h_searched.window_values()
        if not vals or not self.n_leaves:
            return float("nan")
        return 1.0 - float(np.mean(vals)) / self.n_leaves

    def recall_by_target(self) -> Dict[float, Dict[str, float]]:
        return recall_summary(self._recall)

    def recall_drifting(self) -> Dict[float, bool]:
        """Per-target windowed drift flags (ROADMAP item 1's hook)."""
        return self.drift.drifting()

    def suggest_max_survivors(self, n_leaves: Optional[int] = None,
                              pct: float = 99.0) -> int:
        """Percentile-based survivor capacity from the observed window.

        Cold-start guard: with fewer observations than the ``pct``-th
        percentile needs to be meaningful (≈ ``100/(100−pct)`` samples, 100
        at the default p99), the estimate is floored at the engine's static
        default — a handful of easy early queries must not lock in an
        unstable low capacity (tests/test_serving.py pins this).
        """
        L = n_leaves if n_leaves is not None else (self.n_leaves or 1)
        min_samples = int(np.ceil(100.0 / max(100.0 - pct, 1.0)))
        return engine.tuned_max_survivors(
            np.asarray(self._h_survivors.window_values()), L, pct,
            min_samples=min_samples)

    def phase_percentiles(self) -> Dict[str, Dict[str, float]]:
        """Rolling p50/p95/p99 of each latency phase (seconds)."""
        return {
            "queue_wait": latency_percentiles(
                self._h_queue_wait.window_values()),
            "form": latency_percentiles(self._h_form.window_values()),
            "execute": latency_percentiles(self._h_exec.window_values())}

    def summary(self) -> dict:
        surv = self._h_survivors.window_values()
        out = {"n_requests": self.n_requests, "n_batches": self.n_batches,
               "padding_fraction": (self.n_padded /
                                    max(self.n_padded + self.n_requests, 1)),
               "pruning_ratio": self.pruning_ratio(),
               "recall_by_target": self.recall_by_target()}
        out.update(self.latency_percentiles())
        if self.queue_wait or self.form_s or self.exec_s:
            out["phases"] = self.phase_percentiles()
        if surv:
            out["survivors_mean"] = float(np.mean(surv))
            out["suggested_max_survivors"] = self.suggest_max_survivors()
        drift = self.recall_drifting()
        if drift:
            out["recall_windowed"] = self.drift.windowed_recall()
            out["recall_drifting"] = drift
        flagged = self.filters_needing_attention()
        if flagged:
            out["filters_needing_attention"] = [r.to_dict()
                                                for r in flagged]
        return out

    def snapshot(self) -> dict:
        """The backing registry's deterministic snapshot (see obs.metrics)."""
        return self.registry.snapshot()
