"""Dynamic micro-batching for the LeaFi serving runtime.

Heterogeneous requests (mixed ``k``, mixed per-query ``quality_target``,
open-loop arrivals) drain from an admission queue into shape-bucketed padded
batches:

* requests group by ``k`` first — ``k`` is a static program argument (top-k
  width), so each k-group owns its own FIFO queue and its own jit programs;
  quality targets ride along as *data* (a (B,) array lowered to per-query
  conformal offset rows), never as program shape.
* batch sizes pad up to power-of-two buckets capped at ``max_batch``, so the
  jit cache holds a handful of programs per k instead of one per observed
  batch size.
* flush policy: a k-group flushes when ``max_batch`` requests are pending
  (size flush — emits full buckets) or when its oldest pending request has
  waited ``max_wait`` (deadline flush — emits one partial batch padded to
  the next bucket).  Latency SLOs pick ``max_wait``; throughput picks
  ``max_batch``.

The batcher is pure and clockless: :meth:`MicroBatcher.poll` takes ``now``
explicitly and has no hidden state beyond the queues, so a seeded arrival
trace replays to the identical batch sequence (tests/test_serving.py pins
this).  :func:`run_trace` is the matching discrete-event open-loop driver:
arrival times are fixed up front (load does not adapt to service times —
the open-loop harness of serving benchmarks), virtual time advances by
measured (or injected) per-batch service times, and per-request latency is
completion − arrival.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.engine import _next_pow2

_EPS = 1e-12


def _pow2_floor(n: int) -> int:
    return 1 << (max(int(n), 1).bit_length() - 1)


@dataclasses.dataclass
class Request:
    """One k-NN query admission."""
    rid: int
    query: np.ndarray                 # (m,)
    k: int = 1
    quality_target: float = 0.99
    arrival: float = 0.0              # seconds on the trace's virtual clock
    pool_row: Optional[int] = None    # provenance when drawn from a pool


@dataclasses.dataclass
class MicroBatch:
    """A padded, shape-bucketed batch ready for one engine call."""
    queries: np.ndarray               # (B, m) — rows ≥ n_valid repeat row 0
    targets: np.ndarray               # (B,) per-query quality targets
    k: int
    rids: List[int]                   # (n_valid,) request ids, FIFO order
    arrivals: np.ndarray              # (n_valid,)
    n_valid: int
    formed_at: float

    @property
    def bucket(self) -> int:
        return self.queries.shape[0]


class MicroBatcher:
    """Admission queue + pow2-bucket flush policy (pure, deterministic).

    A non-pow2 ``max_batch`` rounds *down* to a power of two, so emitted
    buckets never exceed the caller's cap and warmup
    (:meth:`~repro.serving.session.ServingSession.warmup`, which floors the
    same way) always covers every bucket this batcher can form.
    """

    def __init__(self, max_batch: int = 64, max_wait: float = 0.02):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = _pow2_floor(max_batch)
        self.max_wait = float(max_wait)
        self._queues: Dict[int, deque] = {}

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def submit(self, req: Request) -> None:
        self._queues.setdefault(req.k, deque()).append(req)

    def next_deadline(self) -> float:
        """Earliest instant a deadline flush becomes due (+inf if idle)."""
        heads = [q[0].arrival for q in self._queues.values() if q]
        return min(heads) + self.max_wait if heads else float("inf")

    def _form(self, reqs: Sequence[Request], now: float) -> MicroBatch:
        n = len(reqs)
        B = min(_next_pow2(n), self.max_batch)
        queries = np.stack([r.query for r in reqs])
        if B > n:                      # pad with row 0; results are dropped
            queries = np.concatenate(
                [queries, np.broadcast_to(queries[0], (B - n,) +
                                          queries.shape[1:])])
        targets = np.full(B, reqs[0].quality_target, np.float64)
        targets[:n] = [r.quality_target for r in reqs]
        return MicroBatch(queries=queries, targets=targets, k=reqs[0].k,
                          rids=[r.rid for r in reqs],
                          arrivals=np.array([r.arrival for r in reqs]),
                          n_valid=n, formed_at=now)

    def poll(self, now: float) -> List[MicroBatch]:
        """Flush everything due at ``now``; FIFO within each k-group."""
        out: List[MicroBatch] = []
        for k in sorted(self._queues):
            q = self._queues[k]
            while len(q) >= self.max_batch:                  # size flush
                out.append(self._form([q.popleft()
                                       for _ in range(self.max_batch)], now))
            if q and now - q[0].arrival >= self.max_wait - _EPS:
                out.append(self._form([q.popleft()           # deadline flush
                                       for _ in range(len(q))], now))
        return out


# ---------------------------------------------------------------------------
# traffic generation + open-loop discrete-event drive
# ---------------------------------------------------------------------------


def poisson_trace(query_pool: np.ndarray, *, rate: float, n_requests: int,
                  targets: Sequence[float] = (0.9, 0.95, 0.99),
                  target_probs: Optional[Sequence[float]] = None,
                  ks: Sequence[int] = (1,), seed: int = 0,
                  start: float = 0.0) -> List[Request]:
    """Seeded Poisson (open-loop) arrival trace over a query pool.

    Arrival gaps are exponential at ``rate`` req/s; each request draws a
    pool row (recorded as ``pool_row`` so oracles keyed on the pool need no
    reverse lookup), a quality target, and a k uniformly (targets
    optionally weighted).  The trace is a plain list — replayable,
    shuffle-free, and the only source of randomness in a serving run.
    """
    rng = np.random.default_rng(seed)
    arrivals = start + np.cumsum(rng.exponential(1.0 / rate, n_requests))
    rows = rng.integers(0, len(query_pool), n_requests)
    tsel = rng.choice(len(targets), n_requests, p=target_probs)
    ksel = rng.integers(0, len(ks), n_requests)
    return [Request(rid=i, query=np.asarray(query_pool[rows[i]]),
                    k=int(ks[ksel[i]]),
                    quality_target=float(targets[tsel[i]]),
                    arrival=float(arrivals[i]), pool_row=int(rows[i]))
            for i in range(n_requests)]


def run_trace(trace: Sequence[Request], batcher: MicroBatcher,
              execute: Callable[[MicroBatch], object], *,
              service_time: Optional[Callable[[MicroBatch], float]] = None,
              extract: Optional[Callable[[object, int], object]] = None,
              ) -> Tuple[Dict[int, dict], List[dict]]:
    """Drive an open-loop trace through the batcher (discrete-event loop).

    Virtual time advances by per-batch service times — measured wall-clock
    around ``execute`` by default, or injected via ``service_time`` (fixed
    costs make the whole run, batch composition included, deterministic —
    the batcher-policy tests use this).  Arrivals are admitted whenever the
    clock passes them; when nothing is due the clock jumps to the next
    event (arrival or flush deadline), so idle time costs nothing.

    Returns ``(completions, batch_log)``: ``completions[rid]`` has the
    request's ``latency``/``finish``/``target``/``k`` plus the executor's
    per-request payload under ``result`` (row index ``pos``).  By default
    ``result`` is the whole batch return value (shared by every member —
    fine for short traces); pass ``extract(batch_result, pos)`` to store a
    per-request projection instead, keeping completion memory O(1) per
    request on long-lived traces.  ``batch_log``
    records each batch's bucket, fill, members and service time — plus the
    measured ``wall`` seconds around ``execute`` even when ``service_time``
    injects the clock, so a fixed (deterministic) schedule can be replayed
    against real execution costs (benchmarks/serve_bench.py does exactly
    that to measure steady-state throughput without compile noise).
    """
    trace = sorted(trace, key=lambda r: (r.arrival, r.rid))
    completions: Dict[int, dict] = {}
    batch_log: List[dict] = []
    now = trace[0].arrival if trace else 0.0
    i = 0
    while i < len(trace) or batcher.pending:
        while i < len(trace) and trace[i].arrival <= now + _EPS:
            batcher.submit(trace[i])
            i += 1
        batches = batcher.poll(now)
        if not batches:
            nxt = batcher.next_deadline()
            if i < len(trace):
                nxt = min(nxt, trace[i].arrival)
            now = max(now, nxt)
            continue
        for b in batches:
            t0 = time.perf_counter()
            result = execute(b)
            wall = time.perf_counter() - t0
            dt = wall if service_time is None else float(service_time(b))
            now += dt
            batch_log.append({"formed_at": b.formed_at, "finish": now,
                              "bucket": b.bucket, "n_valid": b.n_valid,
                              "k": b.k, "service": dt, "wall": wall,
                              "rids": list(b.rids)})
            for pos, rid in enumerate(b.rids):
                completions[rid] = {
                    "latency": now - float(b.arrivals[pos]),
                    "finish": now, "pos": pos,
                    "target": float(b.targets[pos]), "k": b.k,
                    "result": (result if extract is None
                               else extract(result, pos))}
    return completions, batch_log


def run_trace_pipelined(trace: Sequence[Request], batcher: MicroBatcher,
                        dispatch: Callable[[MicroBatch], object],
                        harvest: Callable[[object], object], *,
                        service_time: Callable[[MicroBatch], float],
                        extract: Optional[Callable[[object, int], object]] = None,
                        max_in_flight: int = 1,
                        program_key: Optional[Callable[[MicroBatch], object]]
                        = None) -> Tuple[Dict[int, dict], List[dict]]:
    """Pipelined variant of :func:`run_trace`: overlap dispatch and execute.

    ``dispatch(batch)`` submits the batch asynchronously (JAX async dispatch
    — host returns as soon as the computation is enqueued) and returns a
    pending handle; ``harvest(handle)`` blocks until its results are ready.
    Up to ``max_in_flight`` batches run concurrently, so host-side batch
    formation for batch N+1 overlaps device execution of batch N.

    A batch is harvested (in FIFO dispatch order) before dispatching the
    next one when the pipeline is full **or** when the next batch maps to
    the same compiled program — ``program_key(batch)``, default
    ``(bucket, k)`` — because donated input buffers make a second in-flight
    batch per program illegal.

    Determinism contract: ``service_time`` is **required** — the virtual
    clock must advance by injected per-batch costs at dispatch, exactly as
    the serial loop advances at execute, so batch composition, completion
    times, and the batch sequence are identical to :func:`run_trace` on the
    same trace (tests pin this).  Measured host timings land in the log
    instead: ``dispatch_s`` (submit cost, also logged as ``wall``),
    ``harvest_s`` (residual blocking wait after overlap), and real
    ``t_disp``/``t_done`` timestamps for throughput replay
    (benchmarks/serve_bench.py derives pipelined batch costs from
    inter-harvest gaps).
    """
    if service_time is None:
        raise ValueError("run_trace_pipelined needs an injected service_time"
                         " (the virtual clock cannot be measured while"
                         " execution overlaps dispatch)")
    if max_in_flight < 1:
        raise ValueError("max_in_flight must be >= 1")
    if program_key is None:
        program_key = lambda b: (b.bucket, b.k)      # noqa: E731

    trace = sorted(trace, key=lambda r: (r.arrival, r.rid))
    completions: Dict[int, dict] = {}
    batch_log: List[dict] = []
    inflight: deque = deque()      # (handle, batch, key, log_entry) FIFO

    def _retire():
        handle, b, _key, entry = inflight.popleft()
        t0 = time.perf_counter()
        result = harvest(handle)
        t1 = time.perf_counter()
        entry["harvest_s"] = t1 - t0
        entry["t_done"] = t1
        for pos, rid in enumerate(b.rids):
            completions[rid]["result"] = (result if extract is None
                                          else extract(result, pos))

    now = trace[0].arrival if trace else 0.0
    i = 0
    while i < len(trace) or batcher.pending:
        while i < len(trace) and trace[i].arrival <= now + _EPS:
            batcher.submit(trace[i])
            i += 1
        batches = batcher.poll(now)
        if not batches:
            nxt = batcher.next_deadline()
            if i < len(trace):
                nxt = min(nxt, trace[i].arrival)
            now = max(now, nxt)
            continue
        for b in batches:
            key = program_key(b)
            while inflight and (len(inflight) >= max_in_flight
                                or any(e[2] == key for e in inflight)):
                _retire()
            t0 = time.perf_counter()
            handle = dispatch(b)
            t1 = time.perf_counter()
            dt = float(service_time(b))
            now += dt
            entry = {"formed_at": b.formed_at, "finish": now,
                     "bucket": b.bucket, "n_valid": b.n_valid,
                     "k": b.k, "service": dt, "wall": t1 - t0,
                     "rids": list(b.rids), "dispatch_s": t1 - t0,
                     "t_disp": t1, "harvest_s": None, "t_done": None}
            batch_log.append(entry)
            inflight.append((handle, b, key, entry))
            for pos, rid in enumerate(b.rids):
                completions[rid] = {
                    "latency": now - float(b.arrivals[pos]),
                    "finish": now, "pos": pos,
                    "target": float(b.targets[pos]), "k": b.k,
                    "result": None}
    while inflight:
        _retire()
    return completions, batch_log
