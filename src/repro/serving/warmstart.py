"""Cross-batch bsf warm-starting for the serving runtime.

A served batch's answers are a free by-product: every returned k-th NN
distance is a *witnessed* distance for its query.  By the triangle
inequality, a new query ``q`` that lands near a recently answered query
``q'`` inherits an upper bound on its own true k-th NN distance::

    d_k(q)  <=  ||q - q'||  +  d_k(q')

(the k points within ``d_k(q')`` of ``q'`` are all within the right-hand
side of ``q``).  :class:`BsfCache` keeps a rolling window of recent
(query, k-th distance) pairs per ``k`` and seeds each outgoing batch with
the tightest such bound over the window.

The bound is **prune-only**: the engine uses it as ``min(bsf, ub)`` in the
*lower-bound* prune (``bsf_ub`` through :func:`repro.core.engine.run_cascade`
and the distributed shard body) but never in the learned-filter test —
conformal offsets are calibrated against the unseeded bsf trajectory, so a
warm filter threshold would collapse recall — and never merges it into the
top-k heap or the carried bsf, so returned distances stay witnessed.  In
exact mode (no filters) answers are bitwise-unchanged; with filters the
conformal recall semantics are preserved (see tests/test_serving.py): a
leaf with lb > ub holds no true top-k member.  A small
inflation ``(1 + eps) + eps`` absorbs float32 rounding between this cache's
distance computation and the engine's.

Determinism across serving modes: pipelined serving harvests batch ``N``
*after* dispatching ``N+1``, so batch ``N+1`` cannot see batch ``N``'s
results.  Updates are therefore *staged* with their batch sequence number
and only committed at dispatch of batch ``seq`` for staged entries with
``seq_staged <= seq - 1 - warm_lag`` (``warm_lag=1``).  The serial loop
applies the same rule, holding back its freshest harvest — both modes then
observe identical cache states and produce bitwise-identical traces.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np


class BsfCache:
    """Rolling per-``k`` cache of answered (query, k-th distance) pairs."""

    def __init__(self, capacity: int = 256, inflate: float = 1e-6):
        self.capacity = int(capacity)
        self.inflate = float(inflate)
        # k → deque of (query (m,), kth_dist) pairs, newest last
        self._rings: Dict[int, deque] = {}
        # staged (seq, k, queries (B, m), dists (B,)) awaiting commit
        self._staged: List[Tuple[int, int, np.ndarray, np.ndarray]] = []

    # -- seeding -------------------------------------------------------------

    def seed(self, queries: np.ndarray, k: int) -> Optional[np.ndarray]:
        """(B,) prune-only upper bounds for ``queries``, or None when cold.

        ``ub[i] = min_j ||q_i - c_j|| + d_j`` over the ``k``-ring, inflated
        by ``(1 + eps) + eps`` against float32 rounding.
        """
        ring = self._rings.get(int(k))
        if not ring:
            return None
        cq = np.stack([e[0] for e in ring])                  # (W, m)
        cd = np.asarray([e[1] for e in ring], np.float32)    # (W,)
        q = np.asarray(queries, np.float32)
        # direct diff-based distances — the matmul decomposition can go
        # negative under cancellation, which would *tighten* the bound
        diff = q[:, None, :] - cq[None, :, :]                # (B, W, m)
        dist = np.sqrt(np.einsum("bwm,bwm->bw", diff, diff))
        ub = (dist + cd[None, :]).min(axis=1)
        return (ub * (1.0 + self.inflate) + self.inflate).astype(np.float32)

    # -- recording -----------------------------------------------------------

    def update(self, queries: np.ndarray, kth_dists: np.ndarray,
               k: int) -> None:
        """Fold answered queries into the ``k``-ring (immediately)."""
        ring = self._rings.setdefault(int(k), deque(maxlen=self.capacity))
        q = np.asarray(queries, np.float32)
        d = np.asarray(kth_dists, np.float32)
        for i in range(q.shape[0]):
            if np.isfinite(d[i]):                    # skip padded/failed rows
                ring.append((q[i].copy(), float(d[i])))

    def stage(self, seq: int, queries: np.ndarray, kth_dists: np.ndarray,
              k: int) -> None:
        """Hold a harvested batch's results until :meth:`commit_through`."""
        self._staged.append((int(seq),
                             int(k),
                             np.asarray(queries, np.float32).copy(),
                             np.asarray(kth_dists, np.float32).copy()))

    def commit_through(self, seq: int) -> None:
        """Commit staged entries with ``seq_staged <= seq`` (in seq order)."""
        due = sorted((e for e in self._staged if e[0] <= seq),
                     key=lambda e: e[0])
        self._staged = [e for e in self._staged if e[0] > seq]
        for _, k, q, d in due:
            self.update(q, d, k)

    def reset(self) -> None:
        self._rings.clear()
        self._staged.clear()

    def __len__(self) -> int:
        return sum(len(r) for r in self._rings.values())
