"""Shadow ground-truth sampling: online exact-scan audit of served answers.

Calibration-split recall numbers are computed once, at build time, on
held-out queries — the Lernaean Hydra lesson (Echihabi et al.) is that
approximate-with-guarantees claims are only credible when measured against
exact ground truth *on the traffic actually served*.  The
:class:`ShadowSampler` does exactly that at a bounded cost: a
deterministic, seeded fraction of live requests is captured at harvest and
later re-executed through the session's **exact** (unfiltered) search path,
off the critical path.  Comparing the served kNN against the true kNN
yields per-query *true* recall, and every lost true neighbor is attributed
to the leaf that held it and the bound that pruned that leaf — naming the
guilty filter for :meth:`repro.serving.telemetry.Telemetry.
filters_needing_attention`.

Sampling is a pure function of the request id (Knuth multiplicative hash),
so reruns of the same trace shadow the same requests regardless of
batching, pipelining or arrival timing — the determinism tests rely on
this.

Attribution is post-hoc against the *served* k-th distance ``kth`` (the
final bsf) and the warm-start seed ``ub`` the batch was dispatched with.
For a missed true neighbor residing in leaf ``l``:

* ``box``    — ``d_lb[l] > kth``: the summarization lower bound excluded
  it.  Cannot happen for a true miss up to float rounding (the lower bound
  is exact: ``d_lb[l] ≤ d(q, x) < kth`` for any true neighbor ``x`` in
  ``l``), so this label is effectively a float-tie diagnostic.
* ``seed``   — ``d_lb[l] ≤ kth`` but ``d_lb[l] > min(kth, ub)``: only the
  warm-start bound excluded it.  Same exactness argument (``ub`` upper
  bounds the true k-th distance; see :mod:`repro.serving.warmstart`), same
  diagnostic role.
* ``filter`` — ``d_F[l] > kth``: the conformal-adjusted learned filter
  would have pruned the leaf at the final bsf.  This is the expected
  attribution for real misses — LeaFi's whole bargain is that *only* the
  filters may trade recall.
* ``timing`` — none of the above fired against the final bsf: the leaf
  was pruned mid-cascade against a looser intermediate bsf that a bound
  cannot re-trigger post-hoc (rare; counted but never flags a filter).

The bounds are checked in cascade order (box → seed → filter), mirroring
the engine's attribution stages (``repro.obs.trace``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core import bounds as bounds_mod
from ..core import conformal, search

_KNUTH = 2654435761                      # Knuth multiplicative hash constant


def sample_mask(rids: Sequence[int], rate: float,
                seed: int = 0) -> np.ndarray:
    """Deterministic per-request sampling decision, batching-invariant.

    ``hash(rid) = (rid · 2654435761 + seed) mod 2³²`` mapped to [0, 1);
    a request is shadowed iff that value is below ``rate``.
    """
    r = np.asarray(rids, np.uint64)
    h = (r * np.uint64(_KNUTH) + np.uint64(seed)) % np.uint64(1 << 32)
    return (h.astype(np.float64) / float(1 << 32)) < float(rate)


def leaf_of_ids(index, ids: Sequence[int]) -> np.ndarray:
    """Global leaf id holding each *original* series id.

    ``index.order`` maps sorted position → original id; inverting it and
    bucketing by ``leaf_start`` names the leaf:
    ``searchsorted(leaf_start, pos, 'right') − 1``.
    """
    order = np.asarray(index.order)
    inv = np.empty(order.shape[0], np.int64)
    inv[order] = np.arange(order.shape[0])
    pos = inv[np.asarray(ids, np.int64)]
    starts = np.asarray(index.leaf_start)
    return np.searchsorted(starts, pos, side="right") - 1


def _bound_rows(lfi, queries: np.ndarray,
                targets: Optional[np.ndarray]) -> tuple:
    """(Q, L) summarization lower bounds + conformal-adjusted filter bounds
    for ``queries`` (−inf d_F where no filter / filters unused)."""
    import jax.numpy as jnp
    q = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
    d_lb = np.asarray(bounds_mod.lower_bounds(lfi.index, q))
    if lfi.filter_params is None or targets is None:
        return d_lb, np.full_like(d_lb, -np.inf)
    offsets = None
    if lfi.tuner is not None:
        offsets = lfi.tuner.offsets(np.asarray(targets, np.float64))
    d_F = np.asarray(search.predictions_for_all_leaves(
        lfi.index, lfi.filter_params, lfi.leaf_ids, q, offsets,
        filter_type=getattr(lfi.config, "filter_type", "mlp")))
    return d_lb, d_F


def attribute_misses(served_dists, served_ids, true_dists, true_ids,
                     d_lb_row, d_F_row, ub: float,
                     leaf_of: np.ndarray) -> tuple:
    """Score one query's served kNN against its exact kNN.

    Rank-wise hit rule shared with calibration
    (:func:`repro.core.conformal.recall_at_1`, applied per rank), so the
    shadow recall estimator and the calibration-split estimator agree in
    definition.  Returns ``(recall, misses)`` where each miss dict carries
    the lost neighbor's id/distance, its leaf, and the attributed bound.
    """
    sd = np.asarray(served_dists, np.float32).reshape(-1)
    td = np.asarray(true_dists, np.float32).reshape(-1)
    hits = np.asarray(conformal.recall_at_1(sd, td)) > 0
    kth = float(sd[-1])
    misses = []
    for j in np.nonzero(~hits)[0]:
        leaf = int(leaf_of[j])
        lb = float(d_lb_row[leaf])
        d_f = float(d_F_row[leaf])
        if lb > kth:
            bound = "box"
        elif np.isfinite(ub) and lb > min(kth, float(ub)):
            bound = "seed"
        elif d_f > kth:
            bound = "filter"
        else:
            bound = "timing"
        misses.append({"id": int(np.asarray(true_ids).reshape(-1)[j]),
                       "rank": int(j),
                       "dist": float(td[j]), "leaf": leaf, "bound": bound,
                       "d_lb": lb, "d_F": d_f, "served_kth": kth})
    return float(hits.mean()), misses


@dataclasses.dataclass
class _Captured:
    """One shadow-sampled request awaiting its exact re-execution."""
    rid: int
    query: np.ndarray
    target: Optional[float]
    k: int
    served_dists: np.ndarray     # (k,)
    served_ids: np.ndarray       # (k,)
    ub: float                    # warm-start seed at dispatch (+inf if none)


class ShadowSampler:
    """Deterministic sampled exact-scan auditor for a serving session.

    Duck-typed over the session: needs ``session.lfi`` and
    ``session.search_exact`` only.  :meth:`capture` is called by
    ``ServingSession.harvest`` for every answered batch (cheap: a hash per
    request, a row copy per sampled request); :meth:`drain` runs the
    accumulated exact scans in bulk — call it off the critical path
    (``ServingSession.serve`` drains once per trace).
    """

    def __init__(self, session, rate: float, seed: int = 0):
        if not 0.0 <= float(rate) <= 1.0:
            raise ValueError(f"shadow rate must be in [0, 1], got {rate}")
        self.session = session
        self.rate = float(rate)
        self.seed = int(seed)
        self._pending: List[_Captured] = []
        self.n_shadowed = 0              # lifetime drained shadow queries
        self.n_misses = 0
        self._recall_hits = 0.0          # Σ per-query recall (for the mean)
        self.reports: List[dict] = []    # per-query drained reports

    # -- capture (harvest path, cheap) --------------------------------------

    def wants(self, rid: int) -> bool:
        return bool(sample_mask([rid], self.rate, self.seed)[0])

    def capture(self, batch, res,
                bsf_ub: Optional[np.ndarray] = None) -> int:
        """Stash this batch's sampled requests; returns how many."""
        take = sample_mask(batch.rids, self.rate, self.seed)
        dists = np.asarray(res.dists)
        ids = np.asarray(res.ids)
        n = 0
        for i in np.nonzero(take)[0]:
            ub = float("inf") if bsf_ub is None else float(bsf_ub[i])
            self._pending.append(_Captured(
                rid=int(batch.rids[i]), query=batch.queries[i].copy(),
                target=(None if batch.targets is None
                        else float(batch.targets[i])),
                k=int(batch.k), served_dists=dists[i].copy(),
                served_ids=ids[i].copy(), ub=ub))
            n += 1
        return n

    @property
    def pending_count(self) -> int:
        return len(self._pending)

    # -- drain (off the critical path) --------------------------------------

    def drain(self) -> dict:
        """Exact-scan every captured request; score + attribute misses.

        Returns a batch report (``n_shadowed``, ``recall_mean``, flattened
        ``misses``, ``per_query`` details) and folds it into the lifetime
        counters; :class:`~repro.serving.telemetry.Telemetry.record_shadow`
        accepts it directly.
        """
        pending, self._pending = self._pending, []
        per_query: List[dict] = []
        all_misses: List[dict] = []
        by_k: Dict[int, List[_Captured]] = {}
        for e in pending:
            by_k.setdefault(e.k, []).append(e)
        for k, entries in sorted(by_k.items()):
            queries = np.stack([e.query for e in entries])
            targets = (None if all(e.target is None for e in entries)
                       else np.asarray([0.0 if e.target is None else e.target
                                        for e in entries], np.float64))
            exact = self.session.search_exact(queries, k=k)
            d_lb, d_F = _bound_rows(self.session.lfi, queries, targets)
            for i, e in enumerate(entries):
                true_ids = np.asarray(exact.ids)[i]
                leaf_of = leaf_of_ids(self.session.lfi.index, true_ids)
                recall, misses = attribute_misses(
                    e.served_dists, e.served_ids,
                    np.asarray(exact.dists)[i], true_ids,
                    d_lb[i], d_F[i], e.ub, leaf_of)
                for m in misses:
                    m["rid"] = e.rid
                    m["target"] = e.target
                per_query.append({"rid": e.rid, "k": k, "target": e.target,
                                  "recall": recall,
                                  "n_misses": len(misses)})
                all_misses.extend(misses)
        self.n_shadowed += len(per_query)
        self.n_misses += len(all_misses)
        self._recall_hits += sum(r["recall"] for r in per_query)
        self.reports.extend(per_query)
        return {"n_shadowed": len(per_query),
                "recall_mean": (float(np.mean([r["recall"]
                                               for r in per_query]))
                                if per_query else float("nan")),
                "misses": all_misses, "per_query": per_query}

    def summary(self) -> dict:
        """Lifetime view across every drain."""
        return {"rate": self.rate, "n_shadowed": self.n_shadowed,
                "n_misses": self.n_misses,
                "recall_mean": (self._recall_hits / self.n_shadowed
                                if self.n_shadowed else float("nan")),
                "n_pending": self.pending_count}


# ---------------------------------------------------------------------------
# per-query explain (gathers everything the renderer needs)
# ---------------------------------------------------------------------------


def explain_query(session, query: np.ndarray, *, target=None, k: int = 1,
                  rid=None, top_leaves: int = 8,
                  shadow: bool = True) -> dict:
    """Assemble the explain context for one query (see ``repro.obs.explain``).

    Runs the session's filtered search with ``trace=True`` + ``audit=True``
    (a single-query audit's per-leaf planes *are* the per-leaf verdicts),
    plus the exact shadow scan when ``shadow=True``, and attributes every
    lost true neighbor.  Render with
    :func:`repro.obs.explain.render_text` / ``render_json``.
    """
    q = np.atleast_2d(np.asarray(query, np.float32))
    qt = None if target is None else np.asarray([target], np.float64)
    res = session.search(q, quality_targets=qt, k=k, record=False,
                         trace=True, audit=True)
    d_lb, d_F = _bound_rows(session.lfi, q, qt)
    ctx: dict = {"k": int(k), "target": target,
                 "strategy": getattr(session, "strategy", None)}
    if rid is not None:
        ctx["rid"] = rid
    ctx["served"] = {"dists": np.asarray(res.dists)[0].tolist(),
                     "ids": np.asarray(res.ids)[0].tolist()}
    cascade = {"n_leaves": res.n_leaves,
               "searched": int(np.asarray(res.searched)[0]),
               "computed": (None if res.computed is None
                            else int(np.asarray(res.computed)[0]))}
    if res.trace is not None:
        for name in ("pruned_box", "pruned_seed", "pruned_filter",
                     "probed", "overflow", "distances"):
            cascade[name] = int(res.trace[name][0])
    ctx["cascade"] = cascade
    if res.audit is not None:
        a = res.audit
        near = np.argsort(d_lb[0], kind="stable")[:top_leaves]
        rows = []
        for leaf in near:
            leaf = int(leaf)
            if a["pruned_box"][leaf]:
                verdict = "box"
            elif a["pruned_seed"][leaf]:
                verdict = "seed"
            elif a["pruned_filter"][leaf]:
                verdict = "filter"
            else:
                verdict = "kept"
            d_f = float(d_F[0, leaf])
            rows.append({"leaf": leaf, "d_lb": float(d_lb[0, leaf]),
                         "d_F": (None if not np.isfinite(d_f) else d_f),
                         "verdict": verdict})
        ctx["leaves"] = rows
    if shadow:
        exact = session.search_exact(q, k=k)
        true_ids = np.asarray(exact.ids)[0]
        leaf_of = leaf_of_ids(session.lfi.index, true_ids)
        recall, misses = attribute_misses(
            np.asarray(res.dists)[0], np.asarray(res.ids)[0],
            np.asarray(exact.dists)[0], true_ids,
            d_lb[0], d_F[0], float("inf"), leaf_of)
        ctx["shadow"] = {"true_dists": np.asarray(exact.dists)[0].tolist(),
                         "true_ids": true_ids.tolist(),
                         "recall": recall, "misses": misses}
    telemetry = getattr(session, "telemetry", None)
    if telemetry is not None and hasattr(telemetry,
                                         "filters_needing_attention"):
        flagged = telemetry.filters_needing_attention(limit=5)
        if flagged:
            ctx["health"] = [r.to_dict() for r in flagged]
    return ctx
