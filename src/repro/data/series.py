"""Synthetic data-series generators.

The paper evaluates on RandWalk (synthetic) + four real datasets (Seismic,
Astro, Deep, SIFT) that are not available offline.  RandWalk follows the
paper's exact protocol [17]: cumulative sums of N(0,1) steps.  For the other
domains we provide *stand-ins* with matching surface statistics (length,
heavy autocorrelation for seismic-like, bursty transients for astro-like,
low-dimensional near-manifold structure for deep/sift-like image
descriptors).  They exercise the same index/filter behaviors (clustered
leaves, imbalanced node-wise distance ranges); absolute numbers differ from
the paper's real-data tables and are labeled as stand-ins in EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np


def randwalk(n: int, m: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, m), dtype=np.float32).cumsum(axis=1)


def seismic_like(n: int, m: int, seed: int = 0) -> np.ndarray:
    """AR(2)-filtered noise with occasional event bursts (heavy autocorr)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, m + 64), dtype=np.float32)
    for t in range(2, m + 64):
        x[:, t] += 1.6 * x[:, t - 1] - 0.68 * x[:, t - 2]
    events = rng.random((n, 1)) < 0.3
    t0 = rng.integers(0, m, (n, 1))
    amp = rng.gamma(2.0, 2.0, (n, 1)).astype(np.float32)
    tt = np.arange(m + 64)[None, :]
    burst = amp * np.exp(-0.05 * np.abs(tt - t0 - 64)) * events
    return (x + burst.astype(np.float32))[:, 64:]


def astro_like(n: int, m: int, seed: int = 0) -> np.ndarray:
    """Quasi-periodic light curves + flares (long-term AGN variability)."""
    rng = np.random.default_rng(seed)
    t = np.arange(m, dtype=np.float32)[None, :]
    periods = rng.uniform(8, 64, (n, 1)).astype(np.float32)
    phase = rng.uniform(0, 2 * np.pi, (n, 1)).astype(np.float32)
    amp = rng.lognormal(0, 0.5, (n, 1)).astype(np.float32)
    base = amp * np.sin(2 * np.pi * t / periods + phase)
    walk = rng.standard_normal((n, m), dtype=np.float32).cumsum(1) * 0.1
    flare_t = rng.integers(0, m, (n, 1))
    flare = (rng.random((n, 1)) < 0.4) * np.exp(
        -0.2 * np.clip(t - flare_t, 0, None)) * (t >= flare_t) * \
        rng.gamma(2, 1.5, (n, 1))
    return (base + walk + flare).astype(np.float32)


def _clustered_vectors(n: int, m: int, seed: int, n_clusters: int,
                       intrinsic_dim: int, noise: float) -> np.ndarray:
    """Near-manifold clustered vectors (image-descriptor-like)."""
    rng = np.random.default_rng(seed)
    out = np.empty((n, m), np.float32)
    sizes = rng.multinomial(n, np.ones(n_clusters) / n_clusters)
    row = 0
    for c in range(n_clusters):
        k = sizes[c]
        center = rng.standard_normal(m).astype(np.float32) * 2.0
        basis = rng.standard_normal((intrinsic_dim, m)).astype(np.float32)
        coef = rng.standard_normal((k, intrinsic_dim)).astype(np.float32)
        out[row:row + k] = center + coef @ basis / np.sqrt(intrinsic_dim) \
            + noise * rng.standard_normal((k, m)).astype(np.float32)
        row += k
    rng.shuffle(out, axis=0)
    return out


def deep_like(n: int, m: int = 96, seed: int = 0) -> np.ndarray:
    return _clustered_vectors(n, m, seed, n_clusters=max(n // 2000, 8),
                              intrinsic_dim=16, noise=0.3)


def sift_like(n: int, m: int = 128, seed: int = 0) -> np.ndarray:
    v = _clustered_vectors(n, m, seed, n_clusters=max(n // 1500, 8),
                           intrinsic_dim=24, noise=0.5)
    return np.abs(v)  # SIFT descriptors are non-negative histograms


SERIES_GENERATORS: Dict[str, Callable] = {
    "randwalk": randwalk,
    "seismic": seismic_like,
    "astro": astro_like,
    "deep": deep_like,
    "sift": sift_like,
}

DEFAULT_LENGTHS = {"randwalk": 256, "seismic": 256, "astro": 256,
                   "deep": 96, "sift": 128}


def make_series_dataset(name: str, n: int, m: int | None = None,
                        seed: int = 0) -> np.ndarray:
    m = m or DEFAULT_LENGTHS[name]
    return SERIES_GENERATORS[name](n, m, seed)


def make_query_set(series: np.ndarray, n_queries: int, noise: float,
                   seed: int = 0) -> np.ndarray:
    """Paper §5.1: uniform random samples + `noise` gaussian noise, applied
    in z-normalized space (series have unit variance there)."""
    from ..core.summaries import znormalize
    rng = np.random.default_rng(seed)
    base = znormalize(series[rng.integers(0, len(series), n_queries)])
    noisy = base + noise * rng.standard_normal(base.shape).astype(np.float32)
    return znormalize(noisy)
