from .series import SERIES_GENERATORS, make_series_dataset          # noqa: F401
from .tokens import TokenPipeline, TokenPipelineConfig               # noqa: F401
