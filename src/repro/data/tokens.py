"""Deterministic, shard-aware token pipeline for the LM substrate.

Design goals for 1000+-node runs:
* **Stateless addressing** — batch `i` of shard `s` is a pure function of
  (seed, step, shard), so resharding after an elastic re-mesh never replays
  or skips data, and restart-from-checkpoint needs only the step counter.
* **Zero host state** — no iterators to checkpoint; the cursor IS the step.
* Synthetic corpus: a seeded PRNG stream with Zipfian token marginals (so
  embedding-gather and softmax see realistic skew), plus an optional
  "document" structure with EOS resets for packing-sensitive code paths.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    eos_id: int = 0
    mean_doc_len: int = 512


class TokenPipeline:
    """batch(step, shard, n_shards) → dict of (local_batch, seq) arrays."""

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        # precompute the Zipf CDF once (vocab can be 150k: fine on host)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._cdf = jnp.asarray(np.cumsum(probs / probs.sum()),
                                dtype=jnp.float32)

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        cfg = self.cfg
        if cfg.global_batch % n_shards:
            raise ValueError("global_batch must divide n_shards")
        local = cfg.global_batch // n_shards
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), shard)
        ku, kd = jax.random.split(key)
        u = jax.random.uniform(ku, (local, cfg.seq_len + 1))
        tokens = jnp.searchsorted(self._cdf, u).astype(jnp.int32)
        # EOS resets with geometric document lengths
        doc_break = jax.random.uniform(kd, (local, cfg.seq_len + 1)) \
            < (1.0 / cfg.mean_doc_len)
        tokens = jnp.where(doc_break, cfg.eos_id, tokens)
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
        }

    def host_batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        return {k: np.asarray(v)
                for k, v in self.batch(step, shard, n_shards).items()}
