"""Atomic, resumable checkpointing (no orbax offline).

Layout per step:  <dir>/step_<n>/
    tree.msgpack      — pytree structure + array manifests (+ user metadata)
    arrays.npz        — all array leaves, keyed by manifest index
    DONE              — commit marker (written last; readers require it)

Writes go to a tmp directory and are committed with an atomic rename, so a
killed writer can never leave a half-readable checkpoint — the basis of the
crash/restart story.  An optional background thread makes saves async
(train loop never blocks on disk); ``wait()`` drains it before exit.

Sharded/global arrays are fetched with ``jax.device_get`` (host-local full
value).  On a real multi-host pod each host writes its addressable shards
under ``host_<i>/`` — single-process here, but the layout is forward
compatible.
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any, Optional

import jax
import msgpack
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_pytree(path: str, tree: Any, metadata: Optional[dict] = None) -> None:
    """Atomic save of an arbitrary array pytree."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    arrays = {}
    manifest = []
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        arrays[f"a{i}"] = arr
        manifest.append({"path": p, "key": f"a{i}",
                         "dtype": str(arr.dtype), "shape": list(arr.shape)})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    blob = msgpack.packb({"manifest": manifest,
                          "metadata": metadata or {}}, use_bin_type=True)
    with open(os.path.join(tmp, "tree.msgpack"), "wb") as f:
        f.write(blob)
    with open(os.path.join(tmp, "DONE"), "w") as f:
        f.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_pytree(path: str, like: Any = None) -> tuple[Any, dict]:
    """Load a saved pytree.  If ``like`` is given, restore into its structure
    (paths must match); otherwise return a flat {path: array} dict."""
    if not os.path.exists(os.path.join(path, "DONE")):
        raise FileNotFoundError(f"checkpoint at {path} is not committed")
    with open(os.path.join(path, "tree.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read(), raw=False)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        by_path = {e["path"]: z[e["key"]] for e in meta["manifest"]}
    if like is None:
        return by_path, meta["metadata"]
    paths, leaves, treedef = _flatten_with_paths(like)
    missing = [p for p in paths if p not in by_path]
    if missing:
        raise KeyError(f"checkpoint missing {len(missing)} leaves, "
                       f"e.g. {missing[:3]}")
    new_leaves = [by_path[p].astype(np.asarray(leaf).dtype)
                  for p, leaf in zip(paths, leaves)]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), meta["metadata"]


class CheckpointManager:
    """Step-indexed checkpoints with retention and optional async saves."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, name, "DONE")):
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None,
             blocking: Optional[bool] = None) -> None:
        blocking = (not self.async_save) if blocking is None else blocking
        # materialize on host *before* handing to the thread so the train
        # loop can donate/overwrite its buffers immediately.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        meta = dict(metadata or {})
        meta["step"] = step

        def work():
            save_pytree(self._step_dir(step), host_tree, meta)
            self._gc()

        self.wait()
        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def restore(self, like: Any, step: Optional[int] = None
                ) -> tuple[Any, dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return load_pytree(self._step_dir(step), like)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
