"""Observability subsystem: cascade traces, metrics registry, span profiling.

Three layers, importable from anywhere in the repo (this package depends
only on numpy/jax — never on ``repro.core`` or ``repro.serving``, so the
engine and the serving runtime can both build on it without cycles):

* :mod:`repro.obs.trace` — ``CascadeTrace``, the statically-shaped aux
  pytree ``engine.run_cascade(trace=True)`` threads through the cascade
  (which bound pruned which leaf, survivors, overflow fallbacks, distance
  rows paid) — jit/shard_map-legal masked sums only.
* :mod:`repro.obs.audit` — ``FilterAudit``, the per-**leaf** transpose of
  the trace: prune counts by bound, work saved, and prediction-residual
  stats (safety violations included) for every leaf the engine scored
  exactly; psum-able through the distributed shard body.
* :mod:`repro.obs.health` — ``LeafHealthBoard``, the windowed per-leaf
  scoreboard over audit batches + shadow-truth misses behind the metrics
  registry; ``filters_needing_attention()`` is the staleness trigger
  ROADMAP item 1 consumes.
* :mod:`repro.obs.explain` — pure renderers (text + JSON) for per-query
  explain reports assembled by ``serving.shadow.explain_query``.
* :mod:`repro.obs.metrics` — process-wide ``MetricsRegistry`` (counters /
  gauges / windowed histograms with labels, snapshot/delta, JSON-lines and
  Prometheus export) plus the ``RecallDriftMonitor`` staleness hook;
  ``serving.Telemetry`` is a facade over these instruments.
* :mod:`repro.obs.spans` / :mod:`repro.obs.export` — host-side span
  timers with ``jax.profiler.TraceAnnotation`` pass-through and Chrome
  trace-event JSON export (Perfetto-viewable serving pipeline timelines).

See README "Observability" for schemas and the Perfetto workflow.
"""
from .audit import (AuditParts, FilterAudit, RESIDUAL_EDGES,
                    accounting_residual_leaf)
from .health import LeafHealthBoard, LeafHealthReport
from .metrics import (DEFAULT_REGISTRY, Counter, Gauge, Histogram,
                      MetricsRegistry, RecallDriftMonitor, get_registry)
from .spans import Span, SpanRecorder, get_recorder, recording, set_recorder, span
from .trace import (CascadeTrace, accounting_residual, combine, select,
                    to_numpy, zero_trace)
from . import audit, explain, export, health

__all__ = [
    "CascadeTrace", "accounting_residual", "combine", "select", "to_numpy",
    "zero_trace",
    "AuditParts", "FilterAudit", "RESIDUAL_EDGES",
    "accounting_residual_leaf",
    "LeafHealthBoard", "LeafHealthReport",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "RecallDriftMonitor", "DEFAULT_REGISTRY", "get_registry",
    "Span", "SpanRecorder", "get_recorder", "recording", "set_recorder",
    "span",
    "audit", "explain", "export", "health",
]
