"""Observability subsystem: cascade traces, metrics registry, span profiling.

Three layers, importable from anywhere in the repo (this package depends
only on numpy/jax — never on ``repro.core`` or ``repro.serving``, so the
engine and the serving runtime can both build on it without cycles):

* :mod:`repro.obs.trace` — ``CascadeTrace``, the statically-shaped aux
  pytree ``engine.run_cascade(trace=True)`` threads through the cascade
  (which bound pruned which leaf, survivors, overflow fallbacks, distance
  rows paid) — jit/shard_map-legal masked sums only.
* :mod:`repro.obs.metrics` — process-wide ``MetricsRegistry`` (counters /
  gauges / windowed histograms with labels, snapshot/delta, JSON-lines and
  Prometheus export) plus the ``RecallDriftMonitor`` staleness hook;
  ``serving.Telemetry`` is a facade over these instruments.
* :mod:`repro.obs.spans` / :mod:`repro.obs.export` — host-side span
  timers with ``jax.profiler.TraceAnnotation`` pass-through and Chrome
  trace-event JSON export (Perfetto-viewable serving pipeline timelines).

See README "Observability" for schemas and the Perfetto workflow.
"""
from .metrics import (DEFAULT_REGISTRY, Counter, Gauge, Histogram,
                      MetricsRegistry, RecallDriftMonitor, get_registry)
from .spans import Span, SpanRecorder, get_recorder, recording, set_recorder, span
from .trace import (CascadeTrace, accounting_residual, combine, select,
                    to_numpy, zero_trace)
from . import export

__all__ = [
    "CascadeTrace", "accounting_residual", "combine", "select", "to_numpy",
    "zero_trace",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "RecallDriftMonitor", "DEFAULT_REGISTRY", "get_registry",
    "Span", "SpanRecorder", "get_recorder", "recording", "set_recorder",
    "span",
    "export",
]
