"""Chrome trace-event JSON and metrics-dump export.

Renders the host-side observability state — recorded spans
(:mod:`repro.obs.spans`) and a serving ``batch_log`` (the per-batch dicts
``run_trace`` / ``run_trace_pipelined`` return) — as Chrome trace-event
JSON.  Load the file in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``: pipelined runs show batch N's in-flight device window
overlapping batch N+1's dispatch lane, which is the overlap
``run_trace_pipelined`` exists to create.

Lane layout (``tid``, named via metadata events):

* ``1`` dispatch — host batch formation + async submit (``dispatch_s``)
* ``2`` in-flight — submit to harvest-return (device + queue residency)
* ``3`` harvest — residual blocking wait (``harvest_s``)
* ``10 + lane`` — recorded spans, one lane per recording thread

Determinism contract (pinned by tests/test_obs.py): wall-clock readings
appear **only** in the ``ts``/``dur`` fields of emitted events; ``name``,
``cat``, ``tid`` and ``args`` carry deterministic run state only, so a
masked comparison of two seeded runs is bitwise.
"""
from __future__ import annotations

import json
from typing import Iterable, List, Optional

from .metrics import MetricsRegistry
from .spans import Span

_PID = 1
_TID_DISPATCH = 1
_TID_INFLIGHT = 2
_TID_HARVEST = 3
_TID_SPAN_BASE = 10


def _usec(t: float, t0: float) -> float:
    return round((t - t0) * 1e6, 3)


def _meta(tid: int, name: str) -> dict:
    return {"ph": "M", "pid": _PID, "tid": tid, "name": "thread_name",
            "args": {"name": name}}


def span_events(spans: Iterable[Span], t0: float) -> List[dict]:
    events = []
    for s in spans:
        events.append({
            "name": s.name, "cat": s.cat, "ph": "X", "pid": _PID,
            "tid": _TID_SPAN_BASE + s.lane,
            "ts": _usec(s.t0, t0), "dur": round(s.dur * 1e6, 3),
            "args": dict(s.args, depth=s.depth),
        })
    return events


def batch_events(batch_log: Iterable[dict], t0: float) -> List[dict]:
    """Dispatch / in-flight / harvest slices for every logged batch.

    Serial ``run_trace`` entries (no ``t_disp``) render as one combined
    execute slice; pipelined entries split into the three lanes so the
    overlap window is visible.
    """
    events = []
    for seq, entry in enumerate(batch_log):
        args = {"seq": seq, "bucket": entry.get("bucket"),
                "n_valid": entry.get("n_valid"), "k": entry.get("k"),
                "service": entry.get("service"),
                "n_requests": len(entry.get("rids", ()))}
        name = f"batch[{entry.get('bucket')}x k={entry.get('k')}]"
        t_disp = entry.get("t_disp")
        if t_disp is None:
            events.append({"name": name, "cat": "serve", "ph": "X",
                           "pid": _PID, "tid": _TID_DISPATCH,
                           "ts": 0.0, "dur": round(
                               float(entry.get("wall", 0.0)) * 1e6, 3),
                           "args": args})
            continue
        disp_s = float(entry.get("dispatch_s") or 0.0)
        events.append({"name": f"dispatch {name}", "cat": "serve",
                       "ph": "X", "pid": _PID, "tid": _TID_DISPATCH,
                       "ts": _usec(t_disp - disp_s, t0),
                       "dur": round(disp_s * 1e6, 3), "args": args})
        t_done = entry.get("t_done")
        if t_done is None:
            continue
        harv_s = float(entry.get("harvest_s") or 0.0)
        events.append({"name": f"in-flight {name}", "cat": "serve",
                       "ph": "X", "pid": _PID, "tid": _TID_INFLIGHT,
                       "ts": _usec(t_disp, t0),
                       "dur": round(max(t_done - harv_s - t_disp, 0.0)
                                    * 1e6, 3),
                       "args": args})
        events.append({"name": f"harvest {name}", "cat": "serve",
                       "ph": "X", "pid": _PID, "tid": _TID_HARVEST,
                       "ts": _usec(t_done - harv_s, t0),
                       "dur": round(harv_s * 1e6, 3), "args": args})
    return events


def chrome_trace(spans: Optional[Iterable[Span]] = None,
                 batch_log: Optional[Iterable[dict]] = None) -> dict:
    """Assemble a Chrome trace-event JSON object (``traceEvents`` list)."""
    spans = list(spans or ())
    batch_log = list(batch_log or ())
    starts = [s.t0 for s in spans]
    starts += [e["t_disp"] - float(e.get("dispatch_s") or 0.0)
               for e in batch_log if e.get("t_disp") is not None]
    t0 = min(starts) if starts else 0.0

    events = [_meta(_TID_DISPATCH, "serve/dispatch"),
              _meta(_TID_INFLIGHT, "serve/in-flight"),
              _meta(_TID_HARVEST, "serve/harvest")]
    for lane in sorted({s.lane for s in spans}):
        events.append(_meta(_TID_SPAN_BASE + lane, f"spans/lane{lane}"))
    events += batch_events(batch_log, t0)
    events += span_events(spans, t0)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path, spans=None, batch_log=None) -> dict:
    trace = chrome_trace(spans=spans, batch_log=batch_log)
    with open(path, "w") as fh:
        json.dump(trace, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return trace


def write_metrics(path, registry: MetricsRegistry) -> None:
    """Dump a registry as JSON-lines (``*.prom`` paths get Prometheus
    text exposition instead)."""
    text = (registry.prometheus_text() if str(path).endswith(".prom")
            else registry.to_jsonl())
    with open(path, "w") as fh:
        fh.write(text)


def mask_wallclock(trace: dict) -> dict:
    """Copy of a Chrome trace object with every ``ts``/``dur`` zeroed —
    the determinism tests compare masked traces bitwise."""
    events = []
    for e in trace.get("traceEvents", ()):
        e = dict(e)
        for key in ("ts", "dur"):
            if key in e:
                e[key] = 0.0
        events.append(e)
    out = dict(trace)
    out["traceEvents"] = events
    return out
