"""Process-wide metrics registry: counters, gauges, windowed histograms.

One instrument model backs every number the repo reports at runtime — the
serving :class:`~repro.serving.telemetry.Telemetry` facade is a thin layer
over instances of these instruments rather than a parallel implementation.

Design constraints, in order:

* **Determinism.**  Snapshots of instruments fed only virtual-clock or
  device-derived values are bitwise-reproducible run to run.  Anything fed
  wall-clock time must be declared ``wall=True`` at creation; snapshots
  segregate those instruments under a separate ``"wall"`` namespace so the
  trace-determinism test (tests/test_obs.py) can mask exactly one subtree.
* **Static memory.**  Histograms keep a bounded rolling window (deque) plus
  lifetime count/sum — same memory model as the old Telemetry deques.
* **Zero deps.**  numpy only; importable from any layer without cycles
  (``repro.obs`` imports nothing from ``repro.core``/``repro.serving``).

Labels are plain keyword arguments on the observation calls
(``counter.inc(1, target="0.99")``); each distinct sorted label set is an
independent series.  Export formats: :meth:`MetricsRegistry.to_jsonl`
(one JSON object per series per line) and
:meth:`MetricsRegistry.prometheus_text` (Prometheus text exposition 0.0.4).
"""
from __future__ import annotations

import collections
import json
import threading
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: dict) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_suffix(key: LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f"{k}={v}" for k, v in key)
    return "{" + inner + "}"


def _prom_escape(value: str) -> str:
    """Prometheus 0.0.4 label-value escaping: backslash first (so the other
    escapes don't double up), then quote and newline."""
    return (value.replace("\\", "\\\\")
            .replace('"', '\\"')
            .replace("\n", "\\n"))


def _prom_labels(key: LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{_prom_escape(v)}"' for k, v in key)
    return "{" + inner + "}"


class _Instrument:
    """Shared series bookkeeping for all instrument kinds."""

    kind = "instrument"

    def __init__(self, name: str, help: str = "", wall: bool = False):
        self.name = name
        self.help = help
        self.wall = bool(wall)
        self._lock = threading.Lock()

    def series(self):  # pragma: no cover - overridden
        raise NotImplementedError


class Counter(_Instrument):
    """Monotonically increasing per-label-set totals."""

    kind = "counter"

    def __init__(self, name, help="", wall=False):
        super().__init__(name, help, wall)
        self._values: Dict[LabelKey, float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment")
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def series(self):
        return sorted(self._values.items())


class Gauge(_Instrument):
    """Last-written value per label set."""

    kind = "gauge"

    def __init__(self, name, help="", wall=False):
        super().__init__(name, help, wall)
        self._values: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._values[_label_key(labels)] = float(value)

    def value(self, default: float = 0.0, **labels) -> float:
        return self._values.get(_label_key(labels), default)

    def series(self):
        return sorted(self._values.items())


class _HistSeries:
    __slots__ = ("count", "total", "window")

    def __init__(self, window: int):
        self.count = 0
        self.total = 0.0
        self.window = collections.deque(maxlen=window)


class Histogram(_Instrument):
    """Lifetime count/sum plus a bounded rolling window of raw samples.

    Percentiles are computed over the *window* (the serving runtime's
    rolling-window semantics); ``count``/``sum`` are lifetime.  An empty
    window yields NaN percentiles — callers render them, they do not
    traceback (the Telemetry empty-window contract).
    """

    kind = "histogram"

    def __init__(self, name, help="", wall=False, window: int = 4096):
        super().__init__(name, help, wall)
        self.window_size = int(window)
        self._series: Dict[LabelKey, _HistSeries] = {}

    def _get(self, key: LabelKey) -> _HistSeries:
        s = self._series.get(key)
        if s is None:
            s = self._series.setdefault(key, _HistSeries(self.window_size))
        return s

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            s = self._get(key)
            v = float(value)
            s.count += 1
            s.total += v
            s.window.append(v)

    def extend(self, values: Iterable[float], **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            s = self._get(key)
            for v in values:
                v = float(v)
                s.count += 1
                s.total += v
                s.window.append(v)

    def window_values(self, **labels) -> list:
        s = self._series.get(_label_key(labels))
        return list(s.window) if s is not None else []

    def count(self, **labels) -> int:
        s = self._series.get(_label_key(labels))
        return s.count if s is not None else 0

    def reset_window(self, **labels) -> None:
        """Drop windowed samples (lifetime count/sum survive).  With no
        labels given, flushes every series' window."""
        with self._lock:
            if labels:
                s = self._series.get(_label_key(labels))
                if s is not None:
                    s.window.clear()
            else:
                for s in self._series.values():
                    s.window.clear()

    def percentiles(self, pcts=(50, 95, 99), **labels) -> dict:
        """NaN-safe window percentiles: ``{"p50": …}``; NaN when empty."""
        vals = self.window_values(**labels)
        if not vals:
            return {f"p{g:g}": float("nan") for g in pcts}
        arr = np.asarray(vals, dtype=np.float64)
        return {f"p{g:g}": float(np.percentile(arr, g)) for g in pcts}

    def series(self):
        return sorted(self._series.items())


class MetricsRegistry:
    """Named instruments with idempotent creation and structured export.

    ``counter``/``gauge``/``histogram`` return the existing instrument when
    the name is already registered (and raise on a kind mismatch), so any
    layer can say ``registry.counter("serve_requests")`` without
    coordinating creation order.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: "collections.OrderedDict[str, _Instrument]" = \
            collections.OrderedDict()

    def _register(self, cls, name, **kwargs):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is not None:
                if not isinstance(inst, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as {inst.kind}")
                return inst
            inst = cls(name, **kwargs)
            self._instruments[name] = inst
            return inst

    def counter(self, name, help="", wall=False) -> Counter:
        return self._register(Counter, name, help=help, wall=wall)

    def gauge(self, name, help="", wall=False) -> Gauge:
        return self._register(Gauge, name, help=help, wall=wall)

    def histogram(self, name, help="", wall=False,
                  window: int = 4096) -> Histogram:
        return self._register(Histogram, name, help=help, wall=wall,
                              window=window)

    def get(self, name) -> Optional[_Instrument]:
        return self._instruments.get(name)

    def instruments(self):
        return list(self._instruments.values())

    # -- structured export --------------------------------------------------

    @staticmethod
    def _hist_summary(s: _HistSeries) -> dict:
        out = {"count": s.count, "sum": s.total}
        if s.window:
            arr = np.asarray(s.window, dtype=np.float64)
            out.update(window=len(s.window),
                       min=float(arr.min()), max=float(arr.max()),
                       p50=float(np.percentile(arr, 50)),
                       p95=float(np.percentile(arr, 95)),
                       p99=float(np.percentile(arr, 99)))
        else:
            out.update(window=0, min=None, max=None,
                       p50=None, p95=None, p99=None)
        return out

    def snapshot(self) -> dict:
        """Deterministic nested dict of every series' current state.

        Wall-clock instruments (``wall=True`` at creation) land under the
        ``"wall"`` key; everything else is bitwise-reproducible given the
        same seeded inputs, which is what the trace-determinism test pins.
        """
        out = {"counters": {}, "gauges": {}, "histograms": {}, "wall": {}}
        for inst in self._instruments.values():
            if inst.wall:
                bucket = out["wall"].setdefault(inst.kind + "s", {})
            else:
                bucket = out[inst.kind + "s"]
            for key, val in inst.series():
                label = inst.name + _label_suffix(key)
                if inst.kind == "histogram":
                    bucket[label] = self._hist_summary(val)
                else:
                    bucket[label] = val
        return out

    def delta(self, prev: dict) -> dict:
        """Counter movement since a previous :meth:`snapshot`."""
        cur = self.snapshot()
        out = {}
        for scope in ("counters",):
            prev_scope = prev.get(scope, {})
            for name, val in cur.get(scope, {}).items():
                d = val - prev_scope.get(name, 0.0)
                if d:
                    out[name] = d
        return out

    def to_jsonl(self) -> str:
        """One JSON object per series per line (ingestion-friendly dump)."""
        lines = []
        for inst in self._instruments.values():
            for key, val in inst.series():
                row = {"kind": inst.kind, "name": inst.name,
                       "labels": dict(key), "wall": inst.wall}
                if inst.kind == "histogram":
                    row.update(self._hist_summary(val))
                else:
                    row["value"] = val
                lines.append(json.dumps(row, sort_keys=True))
        return "\n".join(lines) + ("\n" if lines else "")

    def prometheus_text(self) -> str:
        """Prometheus text exposition (0.0.4) of every series."""
        out = []
        for inst in self._instruments.values():
            if inst.help:
                out.append(f"# HELP {inst.name} {inst.help}")
            prom_kind = ("summary" if inst.kind == "histogram"
                         else inst.kind)
            out.append(f"# TYPE {inst.name} {prom_kind}")
            for key, val in inst.series():
                if inst.kind == "histogram":
                    summ = self._hist_summary(val)
                    out.append(f"{inst.name}_count{_prom_labels(key)} "
                               f"{summ['count']}")
                    out.append(f"{inst.name}_sum{_prom_labels(key)} "
                               f"{summ['sum']}")
                    for q, p in (("0.5", "p50"), ("0.95", "p95"),
                                 ("0.99", "p99")):
                        if summ[p] is None:
                            continue
                        qkey = key + (("quantile", q),)
                        out.append(f"{inst.name}{_prom_labels(qkey)} "
                                   f"{summ[p]}")
                else:
                    out.append(f"{inst.name}{_prom_labels(key)} {val}")
        return "\n".join(out) + ("\n" if out else "")


#: Process-wide default registry.  Library code that is not handed an
#: explicit registry records here; tests and serving sessions that need
#: isolation construct their own.
DEFAULT_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return DEFAULT_REGISTRY


class RecallDriftMonitor:
    """Windowed achieved-recall watchdog per requested target.

    Feeds two gauges (``recall_windowed``, ``recall_drift`` — both labeled
    by target) and raises a per-target drift flag when the rolling window
    holds at least ``min_samples`` observations and its achieved recall
    sits more than ``slack`` below the requested target.  This is the hook
    ROADMAP item 1's staleness-triggered recalibration consumes: filter
    drift (stale training data after inserts) surfaces as sustained
    windowed recall below target long before the lifetime average moves.
    """

    def __init__(self, registry: MetricsRegistry, *, window: int = 512,
                 min_samples: int = 64, slack: float = 0.0,
                 prefix: str = "serve"):
        self.registry = registry
        self.window = int(window)
        self.min_samples = int(min_samples)
        self.slack = float(slack)
        self._hits: Dict[float, collections.deque] = {}
        self._recall_gauge = registry.gauge(
            f"{prefix}_recall_windowed",
            help="rolling-window achieved recall per requested target")
        self._drift_gauge = registry.gauge(
            f"{prefix}_recall_drift",
            help="1 when windowed recall sits below the requested target")

    @staticmethod
    def _key(target: float) -> float:
        return round(float(target), 6)

    def observe(self, target: float, hit: bool) -> None:
        t = self._key(target)
        dq = self._hits.get(t)
        if dq is None:
            dq = self._hits.setdefault(
                t, collections.deque(maxlen=self.window))
        dq.append(1.0 if hit else 0.0)
        label = f"{t:g}"
        rec = sum(dq) / len(dq)
        self._recall_gauge.set(rec, target=label)
        self._drift_gauge.set(
            1.0 if self._drifting(t, dq) else 0.0, target=label)

    def _drifting(self, target: float, dq) -> bool:
        if len(dq) < self.min_samples:
            return False
        return (sum(dq) / len(dq)) < (target - self.slack)

    def windowed_recall(self) -> dict:
        return {t: (sum(dq) / len(dq) if dq else float("nan"))
                for t, dq in sorted(self._hits.items())}

    def drifting(self) -> dict:
        """Per-target drift flags — ROADMAP item 1's recalibration hook."""
        return {t: self._drifting(t, dq)
                for t, dq in sorted(self._hits.items())}

    def any_drifting(self) -> bool:
        return any(self.drifting().values())
