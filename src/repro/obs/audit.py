"""Per-leaf filter health accumulators: the ``FilterAudit`` pytree.

:class:`~repro.obs.trace.CascadeTrace` answers *which bound saved which
compute* per **query**; ``FilterAudit`` transposes the question to per
**leaf** — which learned filter is earning its keep, how tight its
conformal-adjusted predictions run, and whether it violates its safety
contract on the leaves the engine *did* score exactly — at zero extra
distance computations.  Everything here is statically shaped masked
arithmetic (LF001: no host syncs, no data-dependent shapes), so the audit
is legal everywhere the engine is: jit, vmap, ``lax.cond`` branches, and
``shard_map`` bodies (collectives apply leaf-wise via ``jax.tree.map``).

Two-stage computation
---------------------

The engines emit per-(query, leaf) indicator planes — :class:`AuditParts`,
all ``(Q, L)`` — at the stage where the prune decision actually happened
(the same attribution stage ``CascadeTrace`` documents).  A single jitted
reduction, :func:`reduce_parts`, then folds the planes over the query axis
into the per-leaf :class:`FilterAudit` accumulators.  The split exists for
``engine.compact_bsf_cascade``: its overflow ``lax.cond`` must select
per-query between the compact mask-stage parts and the masked-scan
fallback's step-level parts *before* the leafwise reduction collapses the
query axis (:func:`select_parts`).

Residual semantics
------------------

For every leaf the engine scored exactly (``scored``; the leaf's true NN
distance to the query is a byproduct of the distance pass already paid),
the prediction residual is::

    residual = true_leaf_nn − d_F        # d_F = pred − conformal offset

measured only where the leaf carries a filter (``d_F`` finite; unfiltered
leaves ride at −inf and are excluded).  Positive residual = the adjusted
prediction under-estimates the leaf's NN distance (safe, possibly loose);
*negative* residual = the adjusted prediction over-estimates it — had the
bsf sat between the two, the filter would have pruned a leaf holding a
closer neighbor.  ``violations`` counts those, ``resid_min`` tracks the
worst one, and ``resid_buckets`` histograms the distribution against the
fixed :data:`RESIDUAL_EDGES` so tightness drift is visible without
shipping raw residuals off-device.

The per-leaf accounting identity (pinned in tests/test_obs.py)::

    pruned_box + pruned_seed + pruned_filter + kept == n_queries

holds per leaf for every engine path; for the distributed shard body it
holds per shard *after* the data-axis psum (each data shard sees a slice
of the query batch).  The distributed probe pass is deliberately **not**
audited: it is a collective bsf-seeding device outside the cascade's
prune decisions, and folding it in would double-count the probe leaf's
scan (``CascadeTrace.probed`` still accounts for its cost).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_INF = jnp.float32(jnp.inf)

#: Fixed residual-histogram bucket edges (z-normalized distance units).
#: Buckets are ``(-inf, e0], (e0, e1], …, (e_last, inf)`` — the two buckets
#: below 0.0 count safety-relevant negative residuals by severity, the ones
#: above measure filter tightness (how much pruning headroom the conformal
#: offset gave away).  Fixed at module level so histograms from different
#: batches/shards/processes add without re-binning.
RESIDUAL_EDGES = (-1.0, -0.1, 0.0, 0.1, 1.0, 10.0)
N_BUCKETS = len(RESIDUAL_EDGES) + 1


class AuditParts(NamedTuple):
    """Per-(query, leaf) decision planes, all ``(Q, L)``.

    ``p_box`` / ``p_seed`` / ``p_filter`` (bool): exact partition of the
    leaves excluded from the distance pass, by the first bound that
    excluded them (same stage semantics as ``CascadeTrace``).  ``kept``
    (bool): the complement — leaves whose rows entered the distance pass
    (for the compact strategy this includes the probe leaf).  ``scored``
    (bool): leaves with an exactly computed leaf-NN distance in
    ``leaf_nn`` — equals ``kept`` for the scan paths; a superset for the
    pairwise-union compact path (union co-residents are scored for free).
    ``leaf_nn`` (f32): the exact NN distance of the query to the leaf
    where ``scored``, +inf elsewhere.
    """

    p_box: jnp.ndarray
    p_seed: jnp.ndarray
    p_filter: jnp.ndarray
    kept: jnp.ndarray
    scored: jnp.ndarray
    leaf_nn: jnp.ndarray


class FilterAudit(NamedTuple):
    """Per-leaf audit accumulators; every field ``(L,)`` except
    ``resid_buckets`` ``(L, N_BUCKETS)``.

    Additive across batches/shards (:func:`combine`) except ``resid_min``,
    which combines by minimum — both directions are handled leaf-wise, so
    ``jax.lax.psum`` applies to everything but ``resid_min`` (the shard
    body psums the sums and pmins the min).
    """

    pruned_box: jnp.ndarray      # int32: queries this leaf was box-pruned for
    pruned_seed: jnp.ndarray     # int32: … excluded only by the bsf_ub seed
    pruned_filter: jnp.ndarray   # int32: … excluded by the learned filter
    kept: jnp.ndarray            # int32: queries whose distance pass paid it
    scored: jnp.ndarray          # int32: queries with an exact leaf-NN here
    rows_saved: jnp.ndarray      # int32: pruned-away distance rows (× size)
    resid_count: jnp.ndarray     # int32: residual observations (scored+filtered)
    resid_sum: jnp.ndarray       # f32:  Σ residual
    resid_sumsq: jnp.ndarray     # f32:  Σ residual²
    resid_min: jnp.ndarray       # f32:  worst (most negative) residual; +inf
    violations: jnp.ndarray      # int32: residual < 0 observations
    resid_buckets: jnp.ndarray   # int32 (L, N_BUCKETS) fixed-edge histogram


def zero_parts(n_queries: int, n_leaves: int) -> AuditParts:
    """All-false/+inf parts (cond fallback branches)."""
    f = jnp.zeros((n_queries, n_leaves), bool)
    return AuditParts(f, f, f, f, f, jnp.full((n_queries, n_leaves), _INF))


def zero_audit(n_leaves: int) -> FilterAudit:
    """Identity element of :func:`combine` for ``n_leaves`` leaves."""
    zi = jnp.zeros((n_leaves,), jnp.int32)
    zf = jnp.zeros((n_leaves,), jnp.float32)
    return FilterAudit(zi, zi, zi, zi, zi, zi, zi, zf, zf,
                       jnp.full((n_leaves,), _INF),
                       zi, jnp.zeros((n_leaves, N_BUCKETS), jnp.int32))


def select_parts(cond, a: AuditParts, b: AuditParts) -> AuditParts:
    """Per-query ``where(cond, a, b)`` across every plane (jit-legal)."""
    c = jnp.asarray(cond)[:, None]
    return AuditParts(*(jnp.where(c, x, y) for x, y in zip(a, b)))


@functools.partial(jax.jit, donate_argnums=())
def reduce_parts(parts: AuditParts, d_F: jnp.ndarray,
                 leaf_size: jnp.ndarray) -> FilterAudit:
    """Fold ``(Q, L)`` decision planes into the per-leaf accumulators.

    ONE jitted program on purpose: the compact engine is host-orchestrated,
    and dispatching these ~25 small reductions eagerly is a constant ~ms
    tax that would blow the obs bench's <5% audit-overhead budget (same
    reasoning as ``engine._compact_trace_stats``).

    ``d_F``: the ``(Q, L)`` conformal-adjusted predictions the engine
    pruned with (−inf ⇒ leaf has no filter → excluded from residuals).
    ``leaf_size``: ``(L,)`` rows per leaf, for the work-saved accounting.
    """
    i32 = jnp.int32
    pruned = parts.p_box | parts.p_seed | parts.p_filter
    sizes = leaf_size.astype(i32)
    # residuals only where the leaf-NN was exactly computed AND the leaf
    # actually carries a filter whose adjusted prediction is meaningful
    rmask = parts.scored & jnp.isfinite(d_F) & jnp.isfinite(parts.leaf_nn)
    resid = jnp.where(rmask, parts.leaf_nn - d_F, 0.0)
    # fixed-edge histogram as static masked sums (bucket b of value v:
    # edges[b-1] < v ≤ edges[b], open-ended at both tails)
    edges = jnp.asarray(RESIDUAL_EDGES, jnp.float32)
    bidx = jnp.searchsorted(edges, jnp.where(rmask, resid, _INF),
                            side="left")                 # (Q, L) in [0, NB]
    buckets = (rmask[:, :, None]
               & (bidx[:, :, None] == jnp.arange(N_BUCKETS)[None, None, :]))
    return FilterAudit(
        pruned_box=parts.p_box.sum(axis=0).astype(i32),
        pruned_seed=parts.p_seed.sum(axis=0).astype(i32),
        pruned_filter=parts.p_filter.sum(axis=0).astype(i32),
        kept=parts.kept.sum(axis=0).astype(i32),
        scored=parts.scored.sum(axis=0).astype(i32),
        rows_saved=(pruned.sum(axis=0).astype(i32) * sizes),
        resid_count=rmask.sum(axis=0).astype(i32),
        resid_sum=resid.sum(axis=0).astype(jnp.float32),
        resid_sumsq=(resid * resid).sum(axis=0).astype(jnp.float32),
        resid_min=jnp.where(rmask, resid, _INF).min(axis=0),
        violations=(rmask & (resid < 0.0)).sum(axis=0).astype(i32),
        resid_buckets=buckets.sum(axis=0).astype(i32))


def combine(a: FilterAudit, b: FilterAudit) -> FilterAudit:
    """Leaf-wise merge: sums everywhere, minimum for ``resid_min``."""
    merged = [x + y for x, y in zip(a, b)]
    merged[a._fields.index("resid_min")] = jnp.minimum(a.resid_min,
                                                       b.resid_min)
    return FilterAudit(*merged)


@functools.partial(jax.jit, static_argnames=("n_leaves",))
def scatter_global(audit: FilterAudit, leaf_global: jnp.ndarray,
                   n_leaves: int) -> FilterAudit:
    """Fold shard-local audits into global leaf order.

    ``audit``: fields shaped ``(S, P)`` (``(S, P, NB)`` for the buckets) —
    one row per model shard, as returned by the distributed search.
    ``leaf_global``: ``(S, P)`` global leaf id per shard slot; padding
    slots carry ``n_leaves`` and land in a scratch row that is sliced off
    (in-bounds by construction — index sanitizers stay quiet).
    """
    idx = leaf_global.reshape(-1)

    def fold(x, combine_min=False):
        flat = x.reshape((idx.shape[0],) + x.shape[2:])
        if combine_min:
            out = jnp.full((n_leaves + 1,) + flat.shape[1:], _INF)
            return out.at[idx].min(flat)[:n_leaves]
        out = jnp.zeros((n_leaves + 1,) + flat.shape[1:], flat.dtype)
        return out.at[idx].add(flat)[:n_leaves]

    return FilterAudit(*(fold(x, combine_min=(name == "resid_min"))
                         for name, x in zip(FilterAudit._fields, audit)))


def to_numpy(audit: FilterAudit) -> dict:
    """Host-side dict (field name → numpy array, counters widened to i64)."""
    out = {}
    for name, val in zip(audit._fields, audit):
        arr = np.asarray(val)
        out[name] = arr.astype(np.int64) if arr.dtype == np.int32 else arr
    return out


def accounting_residual_leaf(audit: FilterAudit,
                             n_queries: int) -> jnp.ndarray:
    """``n_queries − kept − Σ pruned_*`` per leaf — zero everywhere when
    the per-leaf attribution partition is exact (the tests pin this)."""
    pruned = audit.pruned_box + audit.pruned_seed + audit.pruned_filter
    return jnp.int32(n_queries) - audit.kept - pruned
