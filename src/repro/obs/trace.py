"""Device-side cascade flight recorder: the ``CascadeTrace`` aux pytree.

The engine's :class:`~repro.core.engine.EngineResult` counters answer the
paper's searched-leaf accounting (how many leaves the sequential cascade
*scans*).  ``CascadeTrace`` answers the complementary systems question —
*which bound saved which compute* — per query, with statically-shaped
masked sums only, so it is legal everywhere the engine is (jit, vmap,
shard_map; LF001: no host syncs, no data-dependent shapes).

Attribution semantics
---------------------

``pruned_box`` / ``pruned_seed`` / ``pruned_filter`` attribute every leaf
that was excluded from the engine's distance pass to the *first* bound that
excluded it, at the stage where the exclusion actually happened:

* ``strategy="scan"`` — the per-step cascade test is the only stage.  A
  leaf is ``pruned_box`` when its lower bound exceeds the witnessed bsf,
  ``pruned_seed`` when only the warm-start bound ``bsf_ub`` excluded it
  (``bsf < lb ≤`` never true; precisely: ``lb ≤ bsf`` but ``lb >
  min(bsf, ub)``), and ``pruned_filter`` when the conformal-adjusted
  prediction ``d_F`` exceeded the bsf.  ``probed == 0`` and ``survivors ==
  n_searched``.
* ``strategy="compact"`` — the phase-1 survivor mask is the stage that
  decides which leaves are ever gathered.  ``pruned_box``: ``d_lb > bsf0``
  (the probe's bsf seed); ``pruned_seed``: ``bsf0 ≥ d_lb > min(bsf0,
  bsf_ub)``; ``pruned_filter``: the remainder (``d_F > bsf0``).  The probe
  leaf is counted in ``probed`` (1 per query), not in ``survivors``.
* ``compact_bsf_cascade`` (the shard_map form) — same mask-stage
  attribution from the collective seed ``bsf0``; shard-padding leaves
  (``leaf_size == 0``) count as ``pruned_box``.  Queries whose survivors
  overflow the static capacity carry the masked-scan fallback's step-level
  attribution instead, flagged in ``overflow``.  ``probed == 0`` here —
  the distributed probe pass happens outside, in the shard body, which
  adds its own ``probed``/``distances`` contribution before the psum.

The accounting identity (pinned in tests/test_engine.py)::

    pruned_box + pruned_seed + pruned_filter == n_leaves − survivors − probed

holds per query for every strategy; for the shard body it holds per shard
with ``probed == 0`` before the body's probe contribution is added.

``distances`` counts exact distance *rows* (series compared) the engine
paid for a query: probe rows plus every gathered candidate row for the
compact paths, and the consulted (unpruned, valid) rows for the scan paths
— the masked scan's dead lanes are shape-static overhead, not evaluations,
and are not counted.

``replay_cascade(trace=True)`` exposes the complementary *replay-stage*
box/seed split of its ``n_pruned_lb`` counter (the compact strategies'
second look at the same leaves); it is not folded into ``CascadeTrace``
because the replay runs over already-gathered summaries — no compute left
to save.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np


class CascadeTrace(NamedTuple):
    """Per-query cascade accounting, all fields ``(Q,)`` int32.

    A NamedTuple so it is a pytree automatically — it can cross jit,
    ``lax.cond`` branches and ``shard_map`` boundaries, and collectives
    (``lax.psum``) apply leaf-wise via ``jax.tree.map``.
    """

    pruned_box: jnp.ndarray      # leaves excluded by the box lower bound
    pruned_seed: jnp.ndarray     # leaves excluded only by the bsf_ub seed
    pruned_filter: jnp.ndarray   # leaves excluded by the learned filter
    probed: jnp.ndarray          # phase-1 probe passes paid
    survivors: jnp.ndarray       # leaves entering the candidate (MXU) pass
    overflow: jnp.ndarray        # 1 ⇒ capacity overflow → scan fallback
    distances: jnp.ndarray       # exact distance rows computed


def zero_trace(n_queries: int) -> CascadeTrace:
    """All-zero trace for ``n_queries`` queries (cond branches, seeds)."""
    z = jnp.zeros((n_queries,), jnp.int32)
    return CascadeTrace(z, z, z, z, z, z, z)


def combine(a: CascadeTrace, b: CascadeTrace) -> CascadeTrace:
    """Field-wise sum — merge per-shard or per-batch traces."""
    return CascadeTrace(*(x + y for x, y in zip(a, b)))


def select(cond, a: CascadeTrace, b: CascadeTrace) -> CascadeTrace:
    """Per-query ``where(cond, a, b)`` across every field (jit-legal)."""
    c = jnp.asarray(cond)
    return CascadeTrace(*(jnp.where(c, x, y) for x, y in zip(a, b)))


def to_numpy(trace: CascadeTrace) -> dict:
    """Host-side dict of int64 numpy arrays (field name → ``(Q,)``)."""
    return {name: np.asarray(val, dtype=np.int64)
            for name, val in zip(trace._fields, trace)}


def accounting_residual(trace: CascadeTrace, n_leaves: int) -> jnp.ndarray:
    """``n_leaves − survivors − probed − Σ pruned_*`` — zero per query when
    the attribution partition is exact (the tests pin this)."""
    pruned = trace.pruned_box + trace.pruned_seed + trace.pruned_filter
    return jnp.int32(n_leaves) - trace.survivors - trace.probed - pruned
