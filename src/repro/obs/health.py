"""Windowed per-leaf filter-health scoreboard.

:class:`LeafHealthBoard` folds two observation streams into one rolling
per-leaf view and answers the question ROADMAP item 1 (mutable index →
targeted recalibration) is blocked on: *which* learned filter needs
attention, not just *that* recall is drifting.

* **Audit batches** — host-side dicts of the engine's per-leaf
  :class:`~repro.obs.audit.FilterAudit` (``repro.obs.audit.to_numpy``):
  prune/kept counts by bound, and prediction-residual stats for leaves the
  engine scored exactly.  A *negative* residual (``violations`` /
  ``resid_min``) means the conformal-adjusted prediction over-estimated
  that leaf's true NN distance — the filter would over-prune whenever the
  bsf lands between the two.  These arrive for free on every audited
  batch, so they are the high-volume early-warning stream.
* **Shadow misses** — per-miss attributions from the shadow ground-truth
  sampler (:mod:`repro.serving.shadow`): a *confirmed* lost true neighbor,
  named by the leaf that held it and the bound that pruned that leaf.
  These are rare (sampled) but each one is ground truth, so even a single
  filter-attributed miss flags its leaf.

Both streams are kept in bounded deques of recent batches (``window``
batches each), so a long-lived session reports *recent* behaviour and a
recalibration's effect is visible once the window rolls over
(:meth:`reset` drops the windows immediately).

When a :class:`~repro.obs.metrics.MetricsRegistry` is attached, the board
publishes lifetime counters (``health_violations_total``,
``health_shadow_misses_total{bound=…}``) and windowed gauges
(``health_flagged_leaves``, worst-k ``health_leaf_violation_rate{leaf=…}``)
so the scoreboard exports through the same JSON-lines / Prometheus path as
every other instrument.

Layering: this module depends only on numpy and :mod:`repro.obs.metrics` —
never on ``repro.core`` or ``repro.serving`` (the serving runtime feeds it
through :class:`repro.serving.telemetry.Telemetry`).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from .metrics import MetricsRegistry

#: Bounds a shadow miss can be attributed to (repro.serving.shadow).
MISS_BOUNDS = ("box", "seed", "filter", "timing")


@dataclasses.dataclass
class LeafHealthReport:
    """One flagged leaf: why it needs attention, with the evidence."""

    leaf: int                    # global leaf id
    reasons: List[str]           # subset of {"violation-rate",
                                 #            "deep-violation", "shadow-miss"}
    violations: int              # windowed negative-residual observations
    resid_count: int             # windowed residual observations
    violation_rate: float        # violations / resid_count (nan when 0 obs)
    resid_min: float             # worst (most negative) windowed residual
    resid_mean: float            # windowed mean residual (nan when 0 obs)
    shadow_misses: int           # windowed filter-attributed true-NN misses
    pruned_filter: int           # windowed filter-pruned query count
    scored: int                  # windowed exactly-scored query count

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class LeafHealthBoard:
    """Rolling per-leaf health over audit batches + shadow-truth misses.

    Flag criteria (tunable at construction; a leaf is flagged when *any*
    reason fires, and :meth:`filters_needing_attention` orders flagged
    leaves most-severe first — shadow misses, then violation rate):

    * ``"violation-rate"`` — windowed ``violations / resid_count`` exceeds
      ``violation_rate_threshold`` with at least ``min_resid_count``
      residual observations (so one unlucky float tie on a cold leaf
      doesn't page anyone);
    * ``"deep-violation"`` — the windowed worst residual is below
      ``resid_min_threshold`` (a single grossly unsafe prediction is
      meaningful even at a low rate: the offset no longer covers the
      error distribution's tail);
    * ``"shadow-miss"`` — at least ``min_shadow_misses`` shadow-confirmed
      true neighbors were lost to this leaf's *filter* bound (box/seed
      attributions are float-tie noise, not filter staleness — the exact
      lower bound cannot prune a true-neighbor leaf; see
      ``repro.serving.warmstart`` for the exactness argument).
    """

    def __init__(self, window: int = 64,
                 registry: Optional[MetricsRegistry] = None,
                 violation_rate_threshold: float = 0.05,
                 min_resid_count: int = 8,
                 resid_min_threshold: float = -0.5,
                 min_shadow_misses: int = 1,
                 worst_k: int = 5):
        self.window = int(window)
        self.violation_rate_threshold = float(violation_rate_threshold)
        self.min_resid_count = int(min_resid_count)
        self.resid_min_threshold = float(resid_min_threshold)
        self.min_shadow_misses = int(min_shadow_misses)
        self.worst_k = int(worst_k)
        self.n_leaves: Optional[int] = None
        self._audits: deque = deque(maxlen=self.window)   # (audit dict, Q)
        self._shadows: deque = deque(maxlen=self.window)  # list[miss dict]
        self.n_shadowed = 0                               # lifetime queries
        self._c_violations = self._c_misses = None
        self._g_flagged = self._g_worst = None
        if registry is not None:
            self._c_violations = registry.counter(
                "health_violations_total",
                help="negative prediction residuals on exactly-scored "
                     "leaves (audit stream)")
            self._c_misses = registry.counter(
                "health_shadow_misses_total",
                help="shadow-confirmed lost true neighbors, by pruning "
                     "bound")
            self._g_flagged = registry.gauge(
                "health_flagged_leaves",
                help="leaves currently needing attention (windowed)")
            self._g_worst = registry.gauge(
                "health_leaf_violation_rate",
                help="windowed violation rate of the worst-k leaves")

    # -- recording -----------------------------------------------------------

    def record_audit(self, audit: Dict[str, np.ndarray],
                     n_queries: int) -> None:
        """Fold one audited batch (``repro.obs.audit.to_numpy`` dict)."""
        L = int(np.asarray(audit["violations"]).shape[0])
        if self.n_leaves is None:
            self.n_leaves = L
        elif L != self.n_leaves:
            raise ValueError(
                f"audit batch has {L} leaves, board tracks {self.n_leaves}")
        self._audits.append((audit, int(n_queries)))
        if self._c_violations is not None:
            self._c_violations.inc(int(np.asarray(
                audit["violations"]).sum()))
        self._publish()

    def record_shadow(self, misses: Sequence[dict],
                      n_queries: int = 0) -> None:
        """Fold one drained shadow batch's miss attributions.

        Each miss is a dict with at least ``leaf`` (global id) and
        ``bound`` (one of :data:`MISS_BOUNDS`); ``n_queries`` counts the
        shadow-sampled queries behind the batch (misses or not), so the
        board can report a windowed miss *rate*, not just a count.
        """
        batch = [dict(m) for m in misses]
        self._shadows.append(batch)
        self.n_shadowed += int(n_queries)
        if self._c_misses is not None:
            for m in batch:
                self._c_misses.inc(1, bound=str(m.get("bound", "timing")))
        self._publish()

    def reset(self) -> None:
        """Drop the rolling windows (e.g. right after a recalibration)."""
        self._audits.clear()
        self._shadows.clear()
        self._publish()

    # -- aggregation ---------------------------------------------------------

    def window_totals(self) -> Dict[str, np.ndarray]:
        """Per-leaf aggregates over the rolling window (empty dict when no
        audit batch has been recorded)."""
        if not self._audits:
            return {}
        L = self.n_leaves
        tot = {k: np.zeros(L, np.int64)
               for k in ("violations", "resid_count", "scored", "kept",
                         "pruned_box", "pruned_seed", "pruned_filter",
                         "rows_saved")}
        tot["resid_sum"] = np.zeros(L, np.float64)
        tot["resid_min"] = np.full(L, np.inf, np.float64)
        tot["n_queries"] = 0
        for audit, q in self._audits:
            for k in tot:
                if k == "n_queries":
                    tot[k] += q
                elif k == "resid_min":
                    tot[k] = np.minimum(tot[k], np.asarray(audit[k],
                                                           np.float64))
                else:
                    tot[k] = tot[k] + np.asarray(audit[k], tot[k].dtype)
        misses = np.zeros(L, np.int64)          # filter-attributed only
        misses_any = np.zeros(L, np.int64)
        for batch in self._shadows:
            for m in batch:
                leaf = int(m.get("leaf", -1))
                if 0 <= leaf < L:
                    misses_any[leaf] += 1
                    if m.get("bound") == "filter":
                        misses[leaf] += 1
        tot["shadow_misses"] = misses
        tot["shadow_misses_any_bound"] = misses_any
        return tot

    def filters_needing_attention(
            self, limit: Optional[int] = None) -> List[LeafHealthReport]:
        """Flagged leaves, most severe first (the recalibration trigger).

        Severity order: shadow-confirmed filter misses (ground truth)
        descending, then windowed violation rate, then worst residual.
        ``limit`` caps the list (default: every flagged leaf).
        """
        tot = self.window_totals()
        if not tot:
            return []
        count = np.maximum(tot["resid_count"], 1)
        rate = tot["violations"] / count
        reports = []
        for leaf in range(self.n_leaves):
            reasons = []
            if (tot["resid_count"][leaf] >= self.min_resid_count
                    and rate[leaf] > self.violation_rate_threshold):
                reasons.append("violation-rate")
            if (tot["violations"][leaf] > 0
                    and tot["resid_min"][leaf] < self.resid_min_threshold):
                reasons.append("deep-violation")
            if tot["shadow_misses"][leaf] >= self.min_shadow_misses:
                reasons.append("shadow-miss")
            if not reasons:
                continue
            rc = int(tot["resid_count"][leaf])
            reports.append(LeafHealthReport(
                leaf=leaf, reasons=reasons,
                violations=int(tot["violations"][leaf]), resid_count=rc,
                violation_rate=(float(rate[leaf]) if rc else float("nan")),
                resid_min=float(tot["resid_min"][leaf]),
                resid_mean=(float(tot["resid_sum"][leaf]) / rc if rc
                            else float("nan")),
                shadow_misses=int(tot["shadow_misses"][leaf]),
                pruned_filter=int(tot["pruned_filter"][leaf]),
                scored=int(tot["scored"][leaf])))
        reports.sort(key=lambda r: (-r.shadow_misses, -r.violation_rate,
                                    r.resid_min, r.leaf))
        return reports[:limit] if limit is not None else reports

    def snapshot(self) -> dict:
        """JSON-serializable dump: flags + the per-leaf window table."""
        tot = self.window_totals()
        out = {
            "n_leaves": self.n_leaves,
            "window_batches": len(self._audits),
            "n_shadowed_lifetime": self.n_shadowed,
            "filters_needing_attention": [
                r.to_dict() for r in self.filters_needing_attention()],
        }
        if tot:
            out["leaves"] = {
                k: np.asarray(v).tolist() for k, v in tot.items()
                if isinstance(v, np.ndarray)}
            out["n_queries_windowed"] = int(tot["n_queries"])
        return out

    # -- registry publication ------------------------------------------------

    def _publish(self) -> None:
        if self._g_flagged is None:
            return
        flagged = self.filters_needing_attention()
        self._g_flagged.set(len(flagged))
        tot = self.window_totals()
        if not tot:
            return
        rate = tot["violations"] / np.maximum(tot["resid_count"], 1)
        worst = np.argsort(-rate, kind="stable")[:self.worst_k]
        for leaf in worst:
            self._g_worst.set(float(rate[leaf]), leaf=str(int(leaf)))
