"""Host-side span profiling: nested context-manager timers with
``jax.profiler.TraceAnnotation`` pass-through.

Spans answer "where did the wall-clock go" for the host-orchestrated
phases the device profiler cannot see — build-pipeline stages
(collect/train/calibrate), serving dispatch/harvest, checkpoint IO.  Each
``span(...)`` block records name, category, nesting depth, thread lane and
wall-clock ``(t0, dur)``; :mod:`repro.obs.export` renders the recorded
list as Chrome trace-event JSON for Perfetto.

When a JAX profiler trace is active, every span also enters a
``jax.profiler.TraceAnnotation`` of the same name, so host spans line up
against device timelines in TensorBoard/XPlane captures; with no active
profiler the annotation is a few-ns no-op.

Determinism contract: wall-clock readings stay inside the ``t0``/``dur``
fields (exported as Chrome ``ts``/``dur``); span names, categories, lanes
and args must be derived from deterministic run state only — the
trace-determinism test masks exactly ``ts``/``dur`` and pins the rest.

Instrumented code calls the module-level :func:`span`, which records into
the installed default recorder (a bounded deque, enabled from the start so
ad-hoc profiling needs no setup).  Drivers that want an isolated capture
install their own recorder via ``recording()``::

    with recording() as rec:
        run()
    export.write_chrome_trace(path, spans=rec.drain())
"""
from __future__ import annotations

import collections
import contextlib
import threading
import time
from typing import NamedTuple, Optional

try:  # pragma: no cover - import guard, exercised implicitly
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - jax always present in this repo
    _TraceAnnotation = None


class Span(NamedTuple):
    name: str
    cat: str
    t0: float          # wall-clock (time.perf_counter) — export as ts only
    dur: float         # wall-clock seconds — export as dur only
    lane: int          # small stable per-thread index (first-seen order)
    depth: int         # nesting depth within the lane
    args: dict         # deterministic metadata only (no wall-clock)


class SpanRecorder:
    """Bounded, thread-safe span sink.

    ``maxlen`` bounds memory for long-lived processes (old spans fall off);
    per-thread nesting depth is tracked thread-locally, and thread idents
    are normalized to dense ``lane`` indices in first-seen order so exports
    do not leak nondeterministic OS thread ids.
    """

    def __init__(self, maxlen: int = 65536, enabled: bool = True):
        self.enabled = enabled
        self._spans = collections.deque(maxlen=maxlen)
        self._lock = threading.Lock()
        self._lanes: dict = {}
        self._tls = threading.local()

    def _lane(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            lane = self._lanes.get(ident)
            if lane is None:
                lane = self._lanes.setdefault(ident, len(self._lanes))
        return lane

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "host", **args):
        if not self.enabled:
            yield self
            return
        depth = getattr(self._tls, "depth", 0)
        self._tls.depth = depth + 1
        ann = (_TraceAnnotation(name) if _TraceAnnotation is not None
               else contextlib.nullcontext())
        t0 = time.perf_counter()
        try:
            with ann:
                yield self
        finally:
            dur = time.perf_counter() - t0
            self._tls.depth = depth
            lane = self._lane()        # before taking _lock: not reentrant
            with self._lock:
                self._spans.append(
                    Span(name, cat, t0, dur, lane, depth, dict(args)))

    def spans(self) -> list:
        with self._lock:
            return list(self._spans)

    def drain(self) -> list:
        with self._lock:
            out = list(self._spans)
            self._spans.clear()
            return out

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()


_DEFAULT = SpanRecorder()
_current = _DEFAULT


def get_recorder() -> SpanRecorder:
    return _current


def set_recorder(recorder: Optional[SpanRecorder]) -> SpanRecorder:
    """Install ``recorder`` as the module-level sink (None → the built-in
    default); returns the previously installed one."""
    global _current
    prev = _current
    _current = recorder if recorder is not None else _DEFAULT
    return prev


@contextlib.contextmanager
def recording(recorder: Optional[SpanRecorder] = None):
    """Temporarily route :func:`span` into a fresh (or given) recorder."""
    rec = recorder if recorder is not None else SpanRecorder()
    prev = set_recorder(rec)
    try:
        yield rec
    finally:
        set_recorder(prev)


def span(name: str, cat: str = "host", **args):
    """Record a span into the currently installed recorder."""
    return _current.span(name, cat, **args)
