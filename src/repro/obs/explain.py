"""Per-query explain reports: pure renderers over a precomputed context.

The serving side assembles the facts (:func:`repro.serving.shadow.
explain_query` runs the traced search, the exact shadow scan and the
per-leaf bound lookups); this module only *renders* them — as
human-readable text (:func:`render_text`) or JSON (:func:`render_json`) —
so it stays importable from anywhere (obs depends on numpy only, never on
``repro.core`` / ``repro.serving``).

Context schema (every key optional; renderers skip what is absent)::

    {
      "rid": int, "k": int, "target": float | None, "strategy": str,
      "served":  {"dists": [k floats], "ids": [k ints]},
      "cascade": {"n_leaves": int, "searched": int, "computed": int,
                  "pruned_box": int, "pruned_seed": int,
                  "pruned_filter": int, "probed": int, "overflow": int,
                  "distances": int},
      "leaves":  [{"leaf": int, "d_lb": float, "d_F": float | None,
                   "verdict": "kept" | "box" | "seed" | "filter"}, ...],
                  # closest-first by d_lb; a bounded prefix, not all L
      "shadow":  {"true_dists": [k floats], "true_ids": [k ints],
                  "recall": float,
                  "misses": [{"id": int, "dist": float, "leaf": int,
                              "bound": "box"|"seed"|"filter"|"timing"},
                             ...]},
      "health":  [LeafHealthReport.to_dict(), ...],   # flagged leaves
    }
"""
from __future__ import annotations

import json
from typing import Any, Dict


def _f(v: Any, nd: int = 4) -> str:
    try:
        return f"{float(v):.{nd}f}"
    except (TypeError, ValueError):
        return str(v)


def render_json(ctx: Dict[str, Any], indent: int = 2) -> str:
    """The context as JSON (numpy scalars coerced via ``default=float``)."""
    return json.dumps(ctx, indent=indent, default=float)


def render_text(ctx: Dict[str, Any]) -> str:
    """The context as an aligned human-readable report."""
    lines = []
    head = "explain"
    if "rid" in ctx:
        head += f" rid={ctx['rid']}"
    if ctx.get("k") is not None:
        head += f" k={ctx['k']}"
    if ctx.get("target") is not None:
        head += f" target={_f(ctx['target'], 3)}"
    if ctx.get("strategy"):
        head += f" [{ctx['strategy']}]"
    lines.append(head)

    served = ctx.get("served")
    if served:
        pairs = ", ".join(f"#{i}:{_f(d)}" for i, d in
                          zip(served.get("ids", []),
                              served.get("dists", [])))
        lines.append(f"  served kNN: {pairs}")

    cas = ctx.get("cascade")
    if cas:
        lines.append(
            f"  cascade: {cas.get('searched', '?')} searched of "
            f"{cas.get('n_leaves', '?')} leaves "
            f"(box {cas.get('pruned_box', 0)}, seed "
            f"{cas.get('pruned_seed', 0)}, filter "
            f"{cas.get('pruned_filter', 0)}"
            + (f", probed {cas['probed']}" if cas.get("probed") else "")
            + (", OVERFLOW→scan" if cas.get("overflow") else "") + ")")
        if cas.get("distances") is not None:
            lines.append(f"  distance rows paid: {cas['distances']}")

    leaves = ctx.get("leaves")
    if leaves:
        lines.append("  nearest leaves (by summarization lower bound):")
        lines.append("    leaf   d_lb       d_F        verdict")
        for row in leaves:
            d_f = row.get("d_F")
            lines.append(
                f"    {row.get('leaf', '?'):>4}   "
                f"{_f(row.get('d_lb')):>9}  "
                f"{('-' if d_f is None else _f(d_f)):>9}  "
                f"{row.get('verdict', '?')}")

    sh = ctx.get("shadow")
    if sh:
        lines.append(f"  shadow truth: recall {_f(sh.get('recall'), 3)} "
                     f"vs exact scan")
        misses = sh.get("misses", [])
        if misses:
            for m in misses:
                lines.append(
                    f"    MISSED true neighbor #{m.get('id', '?')} at "
                    f"{_f(m.get('dist'))} — leaf {m.get('leaf', '?')} "
                    f"pruned by {m.get('bound', '?')} bound")
        else:
            lines.append("    no true neighbors lost")

    health = ctx.get("health")
    if health:
        lines.append("  filters needing attention:")
        for r in health:
            lines.append(
                f"    leaf {r.get('leaf', '?')}: "
                f"{','.join(r.get('reasons', []))} "
                f"(violation rate {_f(r.get('violation_rate'), 3)}, "
                f"worst residual {_f(r.get('resid_min'))}, "
                f"shadow misses {r.get('shadow_misses', 0)})")
    return "\n".join(lines)
