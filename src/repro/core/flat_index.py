"""Flattened, array-based index representation.

The tree builders emit this structure; everything downstream (lower bounds,
filter training, conformal calibration, search, distribution) consumes it.
It is a pytree, so it jits, shards and checkpoints like any other JAX state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FlatIndex:
    kind: str                      # "dstree" | "isax"
    series: np.ndarray             # (n + max_leaf, m) leaf-sorted, padded
    order: np.ndarray              # (n,) original id of sorted row i
    leaf_start: np.ndarray         # (L,)
    leaf_size: np.ndarray          # (L,)
    max_leaf_size: int
    n_series: int
    length: int
    payload: Dict[str, np.ndarray]  # summarization arrays per kind

    @property
    def n_leaves(self) -> int:
        return int(self.leaf_size.shape[0])

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.series, self.order, self.leaf_start, self.leaf_size,
                    self.payload)
        aux = (self.kind, self.max_leaf_size, self.n_series, self.length)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        series, order, leaf_start, leaf_size, payload = children
        kind, max_leaf_size, n_series, length = aux
        return cls(kind=kind, series=series, order=order,
                   leaf_start=leaf_start, leaf_size=leaf_size,
                   max_leaf_size=max_leaf_size, n_series=n_series,
                   length=length, payload=payload)

    # -- convenience --------------------------------------------------------
    def leaf_members(self, leaf: int) -> np.ndarray:
        """Original series ids stored in ``leaf`` (host-side helper)."""
        s = int(self.leaf_start[leaf])
        e = s + int(self.leaf_size[leaf])
        return np.asarray(self.order[s:e])

    def stats(self) -> Dict[str, float]:
        sizes = np.asarray(self.leaf_size)
        return {
            "n_leaves": float(len(sizes)),
            "max_leaf": float(sizes.max()),
            "mean_leaf": float(sizes.mean()),
            "min_leaf": float(sizes.min()),
        }
