"""Host-side tree builders for the two backbone indexes.

Index *building* is a one-off, data-dependent, pointer-chasing procedure — it
runs in numpy on the host (exactly as the paper builds its C indexes on CPU).
Search, filter training, calibration and serving — the hot paths — consume
the flattened array form (`flat_index.FlatIndex`) and run in JAX.

Two builders are provided, mirroring the paper's instantiations:

* ``build_dstree``  — DSTree-like: recursive binary splits on EAPCA segment
  statistics (split the segment whose mean- or std-range is widest, at the
  median).  DSTree's adaptive re-segmentation is simplified to a fixed
  power-of-two segmentation; the node summarization (per-segment min/max of
  mean/std) and its lower bound are the real DSTree ones.
* ``build_isax``    — iSAX/MESSI-like: a prefix trie over SAX words; nodes
  split by promoting the cardinality of one dimension (round-robin over the
  widest dims), as in iSAX2/MESSI.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from . import summaries
from .flat_index import FlatIndex


@dataclasses.dataclass
class _Node:
    ids: np.ndarray                       # indices into the collection
    depth: int
    # dstree:
    # isax:
    sax_word: Optional[np.ndarray] = None       # (l,) symbols at node card
    sax_bits: Optional[np.ndarray] = None       # (l,) cardinality bits
    children: Optional[List["_Node"]] = None

    @property
    def is_leaf(self) -> bool:
        return self.children is None


# ---------------------------------------------------------------------------
# DSTree-like builder
# ---------------------------------------------------------------------------


def build_dstree(
    series: np.ndarray,
    leaf_capacity: int = 256,
    n_segments: int = 8,
    znorm: bool = True,
) -> FlatIndex:
    series = np.asarray(series, np.float32)
    if znorm:
        series = summaries.znormalize(series)
    n, m = series.shape
    stats = np.asarray(summaries.segment_stats(series, n_segments))  # (n,s,2)

    root = _Node(ids=np.arange(n), depth=0)
    stack = [root]
    while stack:
        node = stack.pop()
        if len(node.ids) <= leaf_capacity:
            continue
        st = stats[node.ids]                                  # (k, s, 2)
        # pick the (segment, statistic) with the widest range: splitting
        # there maximally tightens the children's EAPCA boxes.
        rng = st.max(axis=0) - st.min(axis=0)                 # (s, 2)
        seg, which = np.unravel_index(np.argmax(rng), rng.shape)
        vals = st[:, seg, which]
        pivot = np.median(vals)
        left = vals <= pivot
        # guard: degenerate split (all values equal) → split by halves.
        if left.all() or (~left).all():
            order = np.argsort(vals, kind="stable")
            left = np.zeros(len(vals), bool)
            left[order[: len(order) // 2]] = True
        lo = _Node(ids=node.ids[left], depth=node.depth + 1)
        hi = _Node(ids=node.ids[~left], depth=node.depth + 1)
        node.children = [lo, hi]
        node.ids = np.empty(0, np.int64)
        stack += [lo, hi]

    leaves = _collect_leaves(root)
    return _flatten(series, leaves, kind="dstree", n_segments=n_segments)


# ---------------------------------------------------------------------------
# iSAX/MESSI-like builder
# ---------------------------------------------------------------------------


def build_isax(
    series: np.ndarray,
    leaf_capacity: int = 256,
    word_len: int = 8,
    max_card_bits: int = 8,
    znorm: bool = True,
) -> FlatIndex:
    series = np.asarray(series, np.float32)
    if znorm:
        series = summaries.znormalize(series)
    n, m = series.shape
    paa = np.asarray(summaries.paa(series, word_len))            # (n, l)
    # symbols at the maximum cardinality; a node's symbol at b bits is the
    # top-b bits of the max-card symbol (iSAX cardinality promotion).
    sym_max = np.asarray(summaries.sax_from_paa(paa, max_card_bits))

    def node_word(ids: np.ndarray, bits: np.ndarray) -> np.ndarray:
        # all series in a node share the same prefix per construction
        shift = max_card_bits - bits
        return (sym_max[ids[0]] >> shift).astype(np.int32)

    # root children: cardinality 1 on every dim (2^l possible words)
    root = _Node(ids=np.arange(n), depth=0,
                 sax_word=np.zeros(word_len, np.int32),
                 sax_bits=np.zeros(word_len, np.int64))
    first_bits = np.ones(word_len, np.int64)
    buckets: dict = {}
    for i in range(n):
        w = tuple((sym_max[i] >> (max_card_bits - 1)).tolist())
        buckets.setdefault(w, []).append(i)
    root.children = []
    stack = []
    for w, ids in buckets.items():
        ch = _Node(ids=np.asarray(ids), depth=1,
                   sax_word=np.asarray(w, np.int32), sax_bits=first_bits.copy())
        root.children.append(ch)
        stack.append(ch)

    while stack:
        node = stack.pop()
        if len(node.ids) <= leaf_capacity:
            continue
        # split: promote cardinality of the dim with the fewest bits whose
        # promotion actually separates the series (iSAX2-style round robin).
        order = np.argsort(node.sax_bits, kind="stable")
        split_dim = -1
        for d in order:
            if node.sax_bits[d] >= max_card_bits:
                continue
            b = node.sax_bits[d] + 1
            bit = (sym_max[node.ids, d] >> (max_card_bits - b)) & 1
            if 0 < bit.sum() < len(bit):
                split_dim = int(d)
                break
        if split_dim < 0:      # cannot separate further → oversized leaf
            continue
        b = node.sax_bits[split_dim] + 1
        bit = (sym_max[node.ids, split_dim] >> (max_card_bits - b)) & 1
        node.children = []
        for side in (0, 1):
            ids = node.ids[bit == side]
            bits = node.sax_bits.copy()
            bits[split_dim] = b
            ch = _Node(ids=ids, depth=node.depth + 1,
                       sax_word=node_word(ids, bits), sax_bits=bits)
            node.children.append(ch)
            stack.append(ch)
        node.ids = np.empty(0, np.int64)

    leaves = _collect_leaves(root)
    return _flatten(series, leaves, kind="isax", word_len=word_len)


# ---------------------------------------------------------------------------
# Flattening
# ---------------------------------------------------------------------------


def _collect_leaves(root: _Node) -> List[_Node]:
    out: List[_Node] = []
    stack = [root]
    while stack:
        node = stack.pop()
        if node.is_leaf:
            if len(node.ids):
                out.append(node)
        else:
            stack += node.children
    # deterministic ordering (largest leaves first helps kernel tiling)
    out.sort(key=lambda nd: (-len(nd.ids), int(nd.ids[0])))
    return out


def _flatten(series: np.ndarray, leaves: List[_Node], kind: str,
             n_segments: int = 8, word_len: int = 8) -> FlatIndex:
    n, m = series.shape
    L = len(leaves)
    order = np.concatenate([lf.ids for lf in leaves]).astype(np.int32)
    sizes = np.asarray([len(lf.ids) for lf in leaves], np.int32)
    starts = np.concatenate([[0], np.cumsum(sizes[:-1])]).astype(np.int32)
    max_leaf = int(sizes.max())
    # pad the sorted array so dynamic_slice(start, max_leaf) is always in
    # bounds; padded rows are masked with +inf inside the scan kernel.
    sorted_series = np.concatenate(
        [series[order], np.zeros((max_leaf, m), np.float32)], axis=0
    )

    if kind == "dstree":
        stats = np.asarray(summaries.segment_stats(series, n_segments))
        boxes = np.stack(
            [summaries.eapca_node_box(stats[lf.ids]) for lf in leaves]
        )                                                     # (L, s, 4)
        payload = {"eapca_box": boxes}
        seg_len = np.full(n_segments, -(-m // n_segments), np.int32)
        payload["seg_len"] = seg_len
    elif kind == "isax":
        words = np.stack([lf.sax_word for lf in leaves])       # (L, l)
        bits = np.stack([lf.sax_bits for lf in leaves])        # (L, l)
        edges = summaries.sax_symbol_edges(words, bits)        # (L, l, 2)
        payload = {
            "sax_word": words.astype(np.int32),
            "sax_bits": bits.astype(np.int32),
            "sax_edges": edges,
        }
    else:  # pragma: no cover
        raise ValueError(kind)

    return FlatIndex(
        kind=kind,
        series=sorted_series,
        order=order,
        leaf_start=starts,
        leaf_size=sizes,
        max_leaf_size=max_leaf,
        n_series=n,
        length=m,
        payload={k: np.asarray(v) for k, v in payload.items()},
    )
