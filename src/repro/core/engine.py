"""Batched top-k search engine: prune → compact → MXU candidate pass.

This module is the single entry point for batched leaf-cascade search.  It
consumes the precomputed per-(query, leaf) pruning inputs — summarization
lower bounds ``d_lb`` and conformal-adjusted filter predictions ``d_F``
(−inf ⇒ never prunes) — plus the flat leaf layout, and produces top-k
ids/dists and the paper's pruning counters.  ``search.py`` (single device),
``distributed.py`` (per-shard body under shard_map) and the serving drivers
all route through here instead of owning their own copies of the masked-scan
pattern.

Two strategies over identical semantics:

* ``strategy="scan"`` — the original masked ``lax.scan``: every leaf's
  distances are computed and masked.  Wall-clock is O(all leaves) regardless
  of how well the cascade prunes; kept as the validated fallback and as the
  shard_map-safe form (compaction needs data-dependent shapes, which cannot
  live under jit).

* ``strategy="compact"`` — three phases, so compute shrinks with the pruning
  ratio:

    1. *mask*: scan the single best-lb leaf per query (the leaf the
       sequential cascade always scans first) to seed a best-so-far ``bsf0``,
       then keep only leaves with ``d_lb ≤ bsf0`` and ``d_F ≤ bsf0``.  Since
       the sequential cascade's bsf only decreases after the first leaf,
       these survivors are a superset of the leaves the scan strategy scans.
    2. *compact*: gather the survivors' rows into dense per-query candidate
       slabs.  Queries are bucketed by survivor count (rounded up to powers
       of two) so padding waste is bounded and the jit cache is keyed on a
       bounded set of bucket shapes; each bucket walks its slab in
       fixed-size leaf chunks to bound the gathered working set.
    3. *candidates*: one batched distance pass over the slabs through
       ``kernels.l2_scan`` (``matmul`` impl = the pairwise-L2 kernel's
       ‖q‖²+‖s‖²−2qs decomposition, a batched GEMM on the MXU) and one
       ``lax.top_k`` per (query, leaf), followed by an exact *replay* of the
       bsf cascade over the per-leaf top-k summaries.  The replay makes the
       same prune/scan decisions — and, with the ``direct`` distance impl
       (the off-TPU default), returns bitwise-identical top-k ids/dists and
       counters — as ``strategy="scan"``, because merging a leaf's k
       smallest distances is equivalent to merging all of them, and every
       leaf the sequential cascade scans is available (the phase-1 superset
       guarantee; the probe's leaf-0 values are reused verbatim so the two
       bsf trajectories coincide exactly).  The TPU-default ``matmul`` impl
       trades bitwise parity for MXU throughput: decisions and results then
       match scan to float tolerance only (z-normalized series sit exactly
       where ‖q‖²+‖s‖²−2qs cancels), the same trade the ``l2_scan`` kernel
       itself makes.

Cost model: scan is Q·L·R·m multiply-adds (R = max leaf size); compact is
Q·R·m (probe) + Σ_q C_q·R·m (candidates) + Q·L·k merge work, with C_q the
survivor count — i.e. the heavy term scales with (1 − pruning ratio).
Measured (benchmarks/engine_bench.py, CPU, 50k×128 randwalk, L=512, Q=32,
k=5, experiments/engine_bench.json): scan stays flat at 206–225 ms across
the sweep while compact tracks the pruning ratio — 158 ms at ratio 0.65
(lower bounds only, 1.31×), 133 ms at 0.67 (1.68×), 44 ms at 0.88 (5.1×),
29 ms at 0.97 (7.9×), 26 ms at 0.98 (8.5×).  In the adversarial
all-leaves-survive case (tests/test_engine.py) compact degrades to
scan-plus-probe-overhead instead of winning.

The reported ``searched``/``pruned_*`` counters follow the paper's
searched-leaf accounting of the sequential cascade (both strategies agree
exactly); ``computed`` additionally reports how many leaves the compact
engine actually paid distance compute for (the phase-1 superset).

The same leaf-slab layer serves the *build* side (paper Alg. 1 steps 2–5):
``nn_distance_all_leaves`` / ``nn_distance_own_leaf`` are the batched
training-target passes filter_training routes through (no per-leaf Python
loops), and ``replay_cascade`` is the one copy of the bsf cascade that
conformal calibration replays on precollected matrices.  The compact search
path additionally accepts ``dist_impl="pairwise"``: each bucket's survivor
leaves union into one shared slab scored by the ``l2_scan`` Pallas kernel
all-pairs (ROADMAP follow-up; float-tolerance parity like ``matmul``).

The distributed per-shard body gets the same prune→compact economics from
``compact_bsf_cascade``: a fixed-width variant of the compaction that is
legal *inside* ``shard_map``, where the bucketing above (data-dependent
shapes, host-side counts) is not.  Survivor leaf ids compact into one
static ``max_survivors``-capacity buffer per query (stable argsort
selection), the buffer is scored through the same batched candidate
primitives, and :func:`replay_cascade` replays the exact cascade from the
collective bsf seed — bitwise-identical to ``masked_bsf_scan`` under the
``direct`` impl.  The static-shape trade: capacity is paid whether or not
survivors fill it, and queries whose survivors overflow the capacity fall
back to the masked scan (one ``lax.cond``), keeping semantics exact.
``distributed._make_shard_body`` routes through it by default.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import sanitize
from ..kernels.l2_scan import ops as l2_ops
from ..obs import audit as obs_audit
from ..obs.audit import AuditParts, FilterAudit
from ..obs.trace import CascadeTrace, select as _trace_select, zero_trace

_INF = jnp.float32(jnp.inf)

# gathered candidate working-set target per bucket chunk (bytes of f32 rows);
# chunks are derived from it and rounded to powers of two → bounded jit cache.
# Small enough that the gathered chunk stays cache-resident: the chunk is
# consumed (distance + per-leaf top-k) immediately inside the fori_loop, so
# a larger target only adds memory traffic (measured 3× slower at 128 MiB).
_CHUNK_BYTES = 4 << 20


@dataclasses.dataclass
class EngineResult:
    topk_d: jnp.ndarray          # (Q, k)
    topk_i: jnp.ndarray          # (Q, k) row ids into the flat series (−1 pad)
    n_searched: jnp.ndarray      # (Q,) cascade accounting (paper metric)
    n_pruned_lb: jnp.ndarray     # (Q,)
    n_pruned_filter: jnp.ndarray  # (Q,)
    n_computed: jnp.ndarray      # (Q,) leaves distance-computed (≥ n_searched)
    trace: Optional[CascadeTrace] = None  # run_cascade(trace=True) flight data
    audit: Optional[FilterAudit] = None   # run_cascade(audit=True) leaf health


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


# ---------------------------------------------------------------------------
# strategy="scan" — the original masked sequential cascade (fallback; also
# the only jit-safe form, since compaction needs data-dependent shapes)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("k", "max_leaf", "trace", "audit"))
def _scan_cascade(series, leaf_start, leaf_size, queries, d_lb, d_F,
                  bsf_ub, k, max_leaf, trace=False, audit=False):
    order = jnp.argsort(d_lb, axis=1)
    row_ids = jnp.arange(max_leaf)
    L = d_lb.shape[1]

    def per_query(q, lb_row, dF_row, order_row, ub):
        def step(carry, leaf):
            topk_d, topk_i, n_s, n_plb, n_pf = carry
            # lb-prune against min(bsf, ub): ub is a proven upper bound on
            # the true k-th NN distance (see run_cascade's bsf_ub contract),
            # so a leaf with lb > min(bsf, ub) holds no top-k member —
            # pruning it cannot change the answer, only the searched count.
            # The learned-filter test stays against the witnessed bsf only:
            # conformal offsets are calibrated against the unseeded cascade
            # (where the best-lb leaf is visited at bsf = INF), so tightening
            # d_F's threshold with ub would break the recall contract.
            bsf = topk_d[-1]
            p_lb = lb_row[leaf] > jnp.minimum(bsf, ub)
            p_f = jnp.logical_and(~p_lb, dF_row[leaf] > bsf)
            pruned = p_lb | p_f
            start = leaf_start[leaf]
            slab = jax.lax.dynamic_slice_in_dim(series, start, max_leaf, 0)
            diff = slab - q[None, :]
            d = jnp.sqrt((diff * diff).sum(-1))
            d = jnp.where((row_ids < leaf_size[leaf]) & ~pruned, d, _INF)
            ids = (start + row_ids).astype(jnp.int32)
            alld = jnp.concatenate([topk_d, d])
            alli = jnp.concatenate([topk_i, ids])
            neg_top, arg = jax.lax.top_k(-alld, k)
            return (-neg_top, alli[arg],
                    n_s + (~pruned).astype(jnp.int32),
                    n_plb + p_lb.astype(jnp.int32),
                    n_pf + p_f.astype(jnp.int32)), None

        def step_traced(carry, leaf):
            # mirrors `step` exactly (the bitwise parity test in
            # tests/test_engine.py enforces the mirror), plus three
            # masked-sum counters: box/seed split of the lb prune and the
            # exact distance rows consulted.
            topk_d, topk_i, n_s, n_plb, n_pf, n_box, n_seed, n_rows = carry
            bsf = topk_d[-1]
            p_lb = lb_row[leaf] > jnp.minimum(bsf, ub)
            p_box = lb_row[leaf] > bsf
            p_seed = jnp.logical_and(p_lb, ~p_box)
            p_f = jnp.logical_and(~p_lb, dF_row[leaf] > bsf)
            pruned = p_lb | p_f
            start = leaf_start[leaf]
            slab = jax.lax.dynamic_slice_in_dim(series, start, max_leaf, 0)
            diff = slab - q[None, :]
            d = jnp.sqrt((diff * diff).sum(-1))
            d = jnp.where((row_ids < leaf_size[leaf]) & ~pruned, d, _INF)
            ids = (start + row_ids).astype(jnp.int32)
            alld = jnp.concatenate([topk_d, d])
            alli = jnp.concatenate([topk_i, ids])
            neg_top, arg = jax.lax.top_k(-alld, k)
            rows = jnp.where(pruned, 0, leaf_size[leaf]).astype(jnp.int32)
            return (-neg_top, alli[arg],
                    n_s + (~pruned).astype(jnp.int32),
                    n_plb + p_lb.astype(jnp.int32),
                    n_pf + p_f.astype(jnp.int32),
                    n_box + p_box.astype(jnp.int32),
                    n_seed + p_seed.astype(jnp.int32),
                    n_rows + rows), None

        def step_audit(carry, leaf):
            # mirrors `step_traced` exactly and additionally emits the
            # per-leaf decision planes (visit order) for the FilterAudit
            # reduction: d.min() over the masked slab is the leaf's exact
            # NN distance when scanned — a free byproduct of the distance
            # pass — and +inf when pruned.
            topk_d, topk_i, n_s, n_plb, n_pf, n_box, n_seed, n_rows = carry
            bsf = topk_d[-1]
            p_lb = lb_row[leaf] > jnp.minimum(bsf, ub)
            p_box = lb_row[leaf] > bsf
            p_seed = jnp.logical_and(p_lb, ~p_box)
            p_f = jnp.logical_and(~p_lb, dF_row[leaf] > bsf)
            pruned = p_lb | p_f
            start = leaf_start[leaf]
            slab = jax.lax.dynamic_slice_in_dim(series, start, max_leaf, 0)
            diff = slab - q[None, :]
            d = jnp.sqrt((diff * diff).sum(-1))
            d = jnp.where((row_ids < leaf_size[leaf]) & ~pruned, d, _INF)
            ids = (start + row_ids).astype(jnp.int32)
            alld = jnp.concatenate([topk_d, d])
            alli = jnp.concatenate([topk_i, ids])
            neg_top, arg = jax.lax.top_k(-alld, k)
            rows = jnp.where(pruned, 0, leaf_size[leaf]).astype(jnp.int32)
            ys = (p_box, p_seed, p_f, ~pruned, d.min())
            return (-neg_top, alli[arg],
                    n_s + (~pruned).astype(jnp.int32),
                    n_plb + p_lb.astype(jnp.int32),
                    n_pf + p_f.astype(jnp.int32),
                    n_box + p_box.astype(jnp.int32),
                    n_seed + p_seed.astype(jnp.int32),
                    n_rows + rows), ys

        if audit:
            init = (jnp.full((k,), _INF), jnp.full((k,), -1, jnp.int32),
                    jnp.int32(0), jnp.int32(0), jnp.int32(0),
                    jnp.int32(0), jnp.int32(0), jnp.int32(0))
            out, ys = jax.lax.scan(step_audit, init, order_row)
            vb, vs, vf, vk, vnn = ys              # (L,) in visit order

            def scat(v, fill):
                base = jnp.full((L,), fill, v.dtype)
                return base.at[order_row].set(v)  # order is a permutation

            parts = AuditParts(scat(vb, False), scat(vs, False),
                               scat(vf, False), scat(vk, False),
                               scat(vk, False), scat(vnn, _INF))
            return out + (parts,)
        if trace:
            init = (jnp.full((k,), _INF), jnp.full((k,), -1, jnp.int32),
                    jnp.int32(0), jnp.int32(0), jnp.int32(0),
                    jnp.int32(0), jnp.int32(0), jnp.int32(0))
            out, _ = jax.lax.scan(step_traced, init, order_row)
            return out
        init = (jnp.full((k,), _INF), jnp.full((k,), -1, jnp.int32),
                jnp.int32(0), jnp.int32(0), jnp.int32(0))
        (td, ti, n_s, n_plb, n_pf), _ = jax.lax.scan(step, init, order_row)
        return td, ti, n_s, n_plb, n_pf

    return jax.vmap(per_query)(queries, d_lb, d_F, order, bsf_ub)


# ---------------------------------------------------------------------------
# strategy="compact" — phase 2/3 pieces
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("kk", "max_leaf", "chunk", "dist_impl"))
def _bucket_leaf_topk(series, leaf_start, leaf_size, queries_b, leaf_b,
                      kk, max_leaf, chunk, dist_impl):
    """Per-leaf k-smallest distances for a compacted survivor bucket.

    queries_b: (Qb, m); leaf_b: (Qb, C) survivor leaf ids, C a multiple of
    ``chunk``; invalid slots carry leaf id == L (one past the end) so their
    gathers clamp harmlessly and their scatters drop.  Returns
    (vals (Qb, C, kk), ids (Qb, C, kk)) with +inf/−1 in invalid slots.
    """
    Qb, C = leaf_b.shape
    L = leaf_start.shape[0]
    row_ids = jnp.arange(max_leaf)

    def step(i, acc):
        vals_acc, ids_acc = acc
        lf = jax.lax.dynamic_slice_in_dim(leaf_b, i * chunk, chunk, 1)
        valid = lf < L                                   # (Qb, c)
        starts = leaf_start[jnp.minimum(lf, L - 1)]
        sizes = jnp.where(valid, leaf_size[jnp.minimum(lf, L - 1)], 0)
        rows = starts[..., None] + row_ids               # (Qb, c, R)
        slabs = series[rows]                             # (Qb, c, R, m)
        d = l2_ops.gathered_leaf_l2(queries_b, slabs, dist_impl)
        d = jnp.where(row_ids < sizes[..., None], d, _INF)
        vals, ids = l2_ops.leaf_topk(d, rows, kk)
        ids = jnp.where(jnp.isfinite(vals), ids, -1)
        vals_acc = jax.lax.dynamic_update_slice_in_dim(vals_acc, vals,
                                                       i * chunk, 1)
        ids_acc = jax.lax.dynamic_update_slice_in_dim(ids_acc, ids,
                                                      i * chunk, 1)
        return vals_acc, ids_acc

    init = (jnp.full((Qb, C, kk), _INF), jnp.full((Qb, C, kk), -1, jnp.int32))
    return jax.lax.fori_loop(0, C // chunk, step, init)


@functools.partial(jax.jit, static_argnames=("k", "trace"))
def _replay_cascade(leaf_d, leaf_i, d_lb, d_F, order, k, bsf0=None,
                    leaf_valid=None, bsf_ub=None, trace=False):
    """Jitted body of :func:`replay_cascade` — see the wrapper's docstring.

    Identical decision logic and merge arithmetic to ``_scan_cascade`` — the
    k smallest of (running top-k ∪ a leaf's k smallest) equal the k smallest
    of (running top-k ∪ all the leaf's distances), and ties resolve the same
    way because the running top-k precedes the leaf block in both concats —
    but each step merges k values instead of computing max_leaf·m distances.

    This is the single copy of the bsf cascade's decision logic: the compact
    search strategy runs it over gathered candidate summaries, conformal
    calibration (``conformal.simulate_search``) runs it with k=1 over the
    precollected d_L matrices, and the distributed fixed-width compaction
    (``compact_bsf_cascade``) runs it with k=1 from a collective bsf seed —
    no series data touched.

    bsf0: optional (Q,) best-so-far seed — enters the running top-k as one
    phantom candidate (id −1), matching ``masked_bsf_scan``'s scalar-bsf
    init for k=1.  leaf_valid: optional (L,) mask; invalid (shard-padding)
    leaves are lb-pruned unconditionally, exactly as the masked scan treats
    ``leaf_size == 0``.  bsf_ub: optional (Q,) prune-only upper bound on the
    true k-th NN distance (see ``run_cascade``) — tightens the *lower-bound*
    prune via ``min(bsf, ub)`` without ever entering the learned-filter test
    or the top-k merge.
    """
    invalid = (jnp.zeros(leaf_d.shape[1], bool) if leaf_valid is None
               else ~jnp.asarray(leaf_valid))
    if bsf0 is None:
        bsf0 = jnp.full(leaf_d.shape[0], _INF)
    if bsf_ub is None:
        bsf_ub = jnp.full(leaf_d.shape[0], _INF)

    def per_query(ld, li, lb_row, dF_row, order_row, b0, ub):
        def step(carry, leaf):
            topk_d, topk_i, n_s, n_plb, n_pf = carry
            # ub tightens the lb test only; d_F compares against the
            # witnessed bsf (see _scan_cascade for why).
            bsf = topk_d[-1]
            p_lb = jnp.logical_or(lb_row[leaf] > jnp.minimum(bsf, ub),
                                  invalid[leaf])
            p_f = jnp.logical_and(~p_lb, dF_row[leaf] > bsf)
            pruned = p_lb | p_f
            vals = jnp.where(pruned, _INF, ld[leaf])
            alld = jnp.concatenate([topk_d, vals])
            alli = jnp.concatenate([topk_i, li[leaf]])
            neg_top, arg = jax.lax.top_k(-alld, k)
            return (-neg_top, alli[arg],
                    n_s + (~pruned).astype(jnp.int32),
                    n_plb + p_lb.astype(jnp.int32),
                    n_pf + p_f.astype(jnp.int32)), None

        def step_traced(carry, leaf):
            # mirrors `step` plus the box/seed split of the lb prune
            # (invalid shard-padding leaves count as box-pruned).
            topk_d, topk_i, n_s, n_plb, n_pf, n_box, n_seed = carry
            bsf = topk_d[-1]
            p_lb = jnp.logical_or(lb_row[leaf] > jnp.minimum(bsf, ub),
                                  invalid[leaf])
            p_box = jnp.logical_or(lb_row[leaf] > bsf, invalid[leaf])
            p_seed = jnp.logical_and(p_lb, ~p_box)
            p_f = jnp.logical_and(~p_lb, dF_row[leaf] > bsf)
            pruned = p_lb | p_f
            vals = jnp.where(pruned, _INF, ld[leaf])
            alld = jnp.concatenate([topk_d, vals])
            alli = jnp.concatenate([topk_i, li[leaf]])
            neg_top, arg = jax.lax.top_k(-alld, k)
            return (-neg_top, alli[arg],
                    n_s + (~pruned).astype(jnp.int32),
                    n_plb + p_lb.astype(jnp.int32),
                    n_pf + p_f.astype(jnp.int32),
                    n_box + p_box.astype(jnp.int32),
                    n_seed + p_seed.astype(jnp.int32)), None

        if trace:
            init = (jnp.full((k,), _INF).at[0].set(b0),
                    jnp.full((k,), -1, jnp.int32),
                    jnp.int32(0), jnp.int32(0), jnp.int32(0),
                    jnp.int32(0), jnp.int32(0))
            out, _ = jax.lax.scan(step_traced, init, order_row)
            return out
        init = (jnp.full((k,), _INF).at[0].set(b0),
                jnp.full((k,), -1, jnp.int32),
                jnp.int32(0), jnp.int32(0), jnp.int32(0))
        (td, ti, n_s, n_plb, n_pf), _ = jax.lax.scan(step, init, order_row)
        return td, ti, n_s, n_plb, n_pf

    return jax.vmap(per_query, in_axes=(0, 0, 0, 0, 0, 0, 0))(
        leaf_d, leaf_i, d_lb, d_F, order, bsf0, bsf_ub)


def replay_cascade(leaf_d, leaf_i, d_lb, d_F, order, k, bsf0=None,
                   leaf_valid=None, bsf_ub=None, trace=False):
    """Exact sequential-cascade replay over per-leaf top-k summaries.

    The single copy of the bsf cascade's decision logic (see
    :func:`_replay_cascade` for the merge-equivalence argument): the compact
    search strategy runs it over gathered candidate summaries, conformal
    calibration (``conformal.simulate_search``) runs it with k=1 over the
    precollected d_L matrices, and the distributed fixed-width compaction
    (``compact_bsf_cascade``) runs it with k=1 from a collective bsf seed.
    Under ``REPRO_CHECKIFY=1`` eager calls run checkify-instrumented
    (``repro.sanitize``); traced calls pass straight through.

    ``trace=True`` (static) appends two ``(Q,)`` counters — the box/seed
    split of ``n_pruned_lb`` at the *replay* stage (``repro.obs.trace``
    module docstring explains how this differs from the mask-stage
    attribution ``run_cascade(trace=True)`` reports) — and is jit-legal;
    ``trace=False`` lowers to the byte-identical program.
    """
    return sanitize.call(_replay_cascade, leaf_d, leaf_i, d_lb, d_F, order,
                         k=k, bsf0=bsf0, leaf_valid=leaf_valid,
                         bsf_ub=bsf_ub, trace=trace)


def _pow2_chunk(per_leaf_bytes: int, cap: int) -> int:
    """Power-of-two leaf-chunk width keeping a per-step working set of
    ``chunk · per_leaf_bytes`` around ~_CHUNK_BYTES (capped at ``cap``; the
    caller pads its leaf axis up to a multiple of the result)."""
    chunk = max(_CHUNK_BYTES // max(per_leaf_bytes, 1), 1)
    chunk = 1 << (int(chunk).bit_length() - 1)           # pow2 floor
    return min(chunk, cap)


def _chunk_for(Qb: int, C: int, max_leaf: int, m: int) -> int:
    """Chunk width for per-query gathered slabs ((Qb, chunk, R, m) f32)."""
    return _pow2_chunk(Qb * max_leaf * m * 4, _next_pow2(C))


def _union_chunk_for(Qb: int, U: int, max_leaf: int, m: int) -> int:
    """Chunk width for the shared union slab: one (chunk·R, m) slab plus a
    (Qb, chunk·R) distance block per step."""
    return _pow2_chunk((max_leaf * m + Qb * max_leaf) * 4, _next_pow2(U))


@functools.partial(jax.jit, static_argnames=("kk", "max_leaf", "chunk"))
def _union_leaf_topk(series, leaf_start, leaf_size, queries_b, leaf_u,
                     kk, max_leaf, chunk):
    """Per-leaf k-smallest distances over a *shared* survivor-leaf union.

    queries_b: (Qb, m); leaf_u: (U,) the union of the bucket's survivor leaf
    ids (padded with L), U a multiple of ``chunk``.  Every query is scored
    against every union leaf through one all-pairs ``l2_scan`` call per chunk
    — the Pallas kernel path on TPU — trading the per-query gather of
    ``_bucket_leaf_topk`` for kernel-tiled MXU sweeps over one shared slab.
    Returns (vals (Qb, U, kk), ids (Qb, U, kk)) with +inf/−1 padding.
    """
    Qb = queries_b.shape[0]
    U = leaf_u.shape[0]

    def step(i, acc):
        vals_acc, ids_acc = acc
        lu = jax.lax.dynamic_slice_in_dim(leaf_u, i * chunk, chunk, 0)
        slabs, rows, valid = l2_ops.gather_leaf_slabs(
            series, leaf_start, leaf_size, lu, max_leaf)
        d = l2_ops.shared_slab_l2(queries_b, slabs, "pairwise")  # (Qb, c, R)
        d = jnp.where(valid[None, :, :], d, _INF)
        vals, ids = l2_ops.leaf_topk(
            d, jnp.broadcast_to(rows[None], d.shape), kk)
        ids = jnp.where(jnp.isfinite(vals), ids, -1)
        vals_acc = jax.lax.dynamic_update_slice_in_dim(vals_acc, vals,
                                                       i * chunk, 1)
        ids_acc = jax.lax.dynamic_update_slice_in_dim(ids_acc, ids,
                                                      i * chunk, 1)
        return vals_acc, ids_acc

    init = (jnp.full((Qb, U, kk), _INF), jnp.full((Qb, U, kk), -1, jnp.int32))
    return jax.lax.fori_loop(0, U // chunk, step, init)


@jax.jit
def _compact_trace_stats(mask, d_lb, bsf0, bsf0m, leaf_size, leaf0):
    """The compact path's whole mask-stage CascadeTrace, as ONE program.

    The compact cascade is host-orchestrated, so writing these ~20 tiny
    ops eagerly dispatches each one separately — a constant ~ms tax that
    blows the obs bench's <5% traced-overhead budget.  Fused here they
    cost one dispatch next to the (Q, L) mask math they mirror.
    """
    not_m = ~mask
    p_box = not_m & (d_lb > bsf0[:, None])
    p_seed = not_m & ~p_box & (d_lb > bsf0m[:, None])
    p_filt = not_m & ~p_box & ~p_seed
    sizes = leaf_size.astype(jnp.int32)
    # distance rows actually paid: the phase-1 probe pass plus every
    # gathered candidate row (the probe leaf is gathered again in its
    # bucket, then overwritten — both passes are real compute).
    dist_rows = (sizes[leaf0[:, 0]]
                 + jnp.where(mask, sizes[None, :], 0).sum(axis=1))
    Q = mask.shape[0]
    return CascadeTrace(
        pruned_box=p_box.sum(axis=1).astype(jnp.int32),
        pruned_seed=p_seed.sum(axis=1).astype(jnp.int32),
        pruned_filter=p_filt.sum(axis=1).astype(jnp.int32),
        probed=jnp.ones((Q,), jnp.int32),
        survivors=(mask.sum(axis=1) - 1).astype(jnp.int32),
        overflow=jnp.zeros((Q,), jnp.int32),
        distances=dist_rows)


@jax.jit
def _compact_audit_parts(mask, d_lb, bsf0, bsf0m, leaf_nn):
    """The compact path's per-(query, leaf) audit planes, as ONE program.

    Same mask-stage partition as ``_compact_trace_stats`` (and the same
    one-dispatch reasoning); ``kept`` is the survivor mask itself (the
    probe leaf included — its rows were paid twice, probe + gather), and
    ``scored`` is every leaf with a finite gathered summary — equal to
    ``kept`` for the per-query gather impls, a superset under the
    pairwise union (co-resident leaves are scored for free).
    """
    not_m = ~mask
    p_box = not_m & (d_lb > bsf0[:, None])
    p_seed = not_m & ~p_box & (d_lb > bsf0m[:, None])
    p_filt = not_m & ~p_box & ~p_seed
    return AuditParts(p_box, p_seed, p_filt, mask,
                      jnp.isfinite(leaf_nn), leaf_nn)


def _compact_cascade(series, leaf_start, leaf_size, queries, d_lb, d_F,
                     bsf_ub, k, max_leaf, dist_impl, trace=False,
                     audit=False):
    Q, m = queries.shape
    L = leaf_start.shape[0]
    kk = min(k, max_leaf)
    order = jnp.argsort(d_lb, axis=1)                    # (Q, L)

    # -- phase 1: probe the best-lb leaf, mask survivors --------------------
    # (the probe is per-query-gathered either way; under dist_impl="pairwise"
    # it uses the same ‖q‖²+‖s‖²−2qs algebra as the shared-slab kernel, and
    # its values are written verbatim below so the replay stays consistent)
    probe_impl = "matmul" if dist_impl == "pairwise" else dist_impl
    leaf0 = order[:, :1]                                 # (Q, 1)
    p_vals, p_ids = sanitize.call(
        _bucket_leaf_topk, series, leaf_start, leaf_size, queries, leaf0,
        kk=kk, max_leaf=max_leaf, chunk=1, dist_impl=probe_impl)
    bsf0 = p_vals[:, 0, k - 1] if k <= kk else jnp.full((Q,), _INF)
    # the replay's effective lb threshold never exceeds min(bsf0, ub) after
    # the first merge, so masking lb against it keeps the phase-1 superset
    # guarantee while letting a tight warm-start bound shrink the survivor
    # set (and with it the gathered candidate compute) before any distance
    # work is paid.  d_F masks against bsf0 alone — the replay's filter test
    # uses the witnessed bsf (≤ bsf0 after the first merge), never ub.
    bsf0m = jnp.minimum(bsf0, bsf_ub)
    mask = (d_lb <= bsf0m[:, None]) & (d_F <= bsf0[:, None])
    mask = mask.at[jnp.arange(Q), leaf0[:, 0]].set(True)

    if trace:
        # mask-stage attribution: partition the non-survivors by the first
        # bound that excluded them (the probe leaf is in `mask`, so it is
        # excluded from the partition and lands in `probed` instead).
        # Partition is exact by construction: ~mask ⇒ d_lb > bsf0m or
        # d_F > bsf0; box takes d_lb > bsf0, seed takes bsf0 ≥ d_lb > bsf0m
        # (excluded only by the warm-start bound), filter takes the rest.
        aux = _compact_trace_stats(mask, d_lb, bsf0, bsf0m, leaf_size, leaf0)
        dist_rows = aux.distances

    # -- phase 2: bucket queries by survivor count, compact leaf lists ------
    counts = np.asarray(mask.sum(axis=1))
    computed = counts.astype(np.int32).copy()            # per-query paid leaves
    # leaf row L is a scratch row: invalid/padded slots aim their scatters at
    # it (in-bounds by construction, so index sanitizers stay quiet) and it
    # is sliced off before the replay.
    leaf_d = jnp.full((Q, L + 1, kk), _INF)
    leaf_i = jnp.full((Q, L + 1, kk), -1, jnp.int32)
    # survivors first, in ascending-lb order (argsort of bool is stable)
    mask_ord = jnp.take_along_axis(mask, order, axis=1)
    sel_all = jnp.argsort(~mask_ord, axis=1)

    buckets: dict[int, list[int]] = {}
    for qi, c in enumerate(counts):
        buckets.setdefault(min(_next_pow2(max(int(c), 1)), L), []).append(qi)

    for C, qis in sorted(buckets.items()):
        Qb = _next_pow2(len(qis))
        qidx = jnp.asarray((qis + [qis[0]] * (Qb - len(qis)))[:Qb])
        pad_q = jnp.arange(Qb) >= len(qis)
        sel = sel_all[qidx][:, :C]                       # (Qb, C)
        valid = jnp.take_along_axis(mask_ord[qidx], sel, axis=1)
        valid = valid & ~pad_q[:, None]
        leaf = jnp.where(valid,
                         jnp.take_along_axis(order[qidx], sel, axis=1), L)
        if dist_impl == "pairwise":
            # union the bucket's survivor leaves into one shared slab and
            # run the all-pairs l2_scan kernel over it; leaves that are not
            # a given query's survivors come along for free but their
            # summaries are never consulted (the replay prunes them — their
            # d_lb/d_F exceeded that query's bsf0, and bsf only decreases).
            leaf_np = np.asarray(leaf)
            uni = np.unique(leaf_np[leaf_np < L])
            if uni.size == 0:
                continue                                 # all-padding bucket
            # every bucket query pays distance compute for the whole union
            computed[qis] = uni.size
            if trace:
                qis_j = jnp.asarray(qis)
                sizes = leaf_size.astype(jnp.int32)
                uni_rows = sizes[jnp.asarray(uni)].sum()
                dist_rows = dist_rows.at[qis_j].set(
                    sizes[leaf0[qis_j, 0]] + uni_rows)
            chunk = _union_chunk_for(Qb, uni.size, max_leaf, m)
            Up = max(_next_pow2(uni.size), chunk)
            leaf_u = jnp.asarray(np.pad(uni, (0, Up - uni.size),
                                        constant_values=L))
            vals, ids = sanitize.call(
                _union_leaf_topk, series, leaf_start, leaf_size,
                queries[qidx], leaf_u, kk=kk, max_leaf=max_leaf, chunk=chunk)
            # padded queries must not scatter: aim their writes at leaf L
            leaf_sc = jnp.where(pad_q[:, None], L, leaf_u[None, :])
        else:
            chunk = _chunk_for(Qb, C, max_leaf, m)
            Cp = -(-C // chunk) * chunk                  # pad C to chunks
            if Cp > C:                                   # invalid-slot pad
                leaf = jnp.pad(leaf, ((0, 0), (0, Cp - C)), constant_values=L)
            vals, ids = sanitize.call(
                _bucket_leaf_topk, series, leaf_start, leaf_size,
                queries[qidx], leaf,
                kk=kk, max_leaf=max_leaf, chunk=chunk, dist_impl=dist_impl)
            leaf_sc = leaf
        # scatter into the (Q, L+1, kk) summaries; leaf==L slots land in the
        # scratch row
        leaf_d = leaf_d.at[qidx[:, None, None], leaf_sc[:, :, None],
                           jnp.arange(kk)[None, None, :]].set(vals)
        leaf_i = leaf_i.at[qidx[:, None, None], leaf_sc[:, :, None],
                           jnp.arange(kk)[None, None, :]].set(ids)

    leaf_d, leaf_i = leaf_d[:, :L], leaf_i[:, :L]        # drop the scratch row

    # reuse the probe's leaf-0 values verbatim: the replay's bsf after the
    # first merge then equals bsf0 bitwise, which is what makes the phase-1
    # survivor mask a true superset of the replayed cascade's scans.
    leaf_d = leaf_d.at[jnp.arange(Q)[:, None, None], leaf0[:, :, None],
                       jnp.arange(kk)[None, None, :]].set(p_vals)
    leaf_i = leaf_i.at[jnp.arange(Q)[:, None, None], leaf0[:, :, None],
                       jnp.arange(kk)[None, None, :]].set(p_ids)

    # -- phase 3: exact cascade replay over the per-leaf summaries ----------
    td, ti, n_s, n_plb, n_pf = replay_cascade(
        leaf_d, leaf_i, d_lb, d_F, order, k=k, bsf_ub=bsf_ub)
    out = (td, ti, n_s, n_plb, n_pf, jnp.asarray(computed))
    if trace:
        if dist_rows is not aux.distances:       # pairwise union accounting
            aux = aux._replace(distances=dist_rows)
        out = out + (aux,)
    if audit:
        # leaf_d already has the scratch row dropped and the probe leaf's
        # values written verbatim, so column 0 is each scored leaf's exact
        # NN distance (+inf where the leaf was never gathered).
        out = out + (_compact_audit_parts(mask, d_lb, bsf0, bsf0m,
                                          leaf_d[:, :, 0]),)
    return out


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def run_cascade(
    series: jnp.ndarray,           # (n + max_leaf, m) leaf-sorted, padded
    leaf_start: jnp.ndarray,       # (L,)
    leaf_size: jnp.ndarray,        # (L,)
    queries: jnp.ndarray,          # (Q, m)
    d_lb: jnp.ndarray,             # (Q, L) summarization lower bounds
    d_F: jnp.ndarray,              # (Q, L) adjusted predictions; −inf = keep
    *,
    k: int,
    max_leaf: int,
    strategy: str = "auto",
    dist_impl: Optional[str] = None,
    bsf_ub: Optional[jnp.ndarray] = None,
    trace: bool = False,
    audit: bool = False,
) -> EngineResult:
    """Batched top-k leaf-cascade search over precomputed pruning inputs.

    strategy: "compact" (default via "auto") computes distances only for
    cascade survivors; "scan" is the masked sequential fallback.  With
    ``dist_impl="direct"`` (the off-TPU default) both strategies return
    bitwise-identical results; on TPU the default is "matmul" (the
    pairwise-L2 kernel's decomposition, MXU-tiled), which matches scan only
    to float tolerance — pass dist_impl="direct" there if exact replay
    parity matters more than throughput.  See the module docstring for the
    cost model.
    dist_impl: "direct" | "matmul" | "pairwise" | None (backend default) —
    forwarded to the compact candidate pass.  "pairwise" unions each
    bucket's survivor leaves into one shared slab and runs the ``l2_scan``
    Pallas kernel all-pairs over it (kernel-tiled MXU use, float-tolerance
    parity like "matmul"; off-TPU it lowers to the same matmul algebra);
    "direct"/"matmul" gather per-query candidate slabs instead.
    bsf_ub: optional (Q,) per-query *prune-only* upper bound on the true
    k-th NN distance (e.g. the serving runtime's triangle-inequality
    warm-start bound, ``serving.warmstart``).  It tightens the *lower-bound*
    prune via ``min(bsf, ub)`` but never enters the learned-filter test
    (whose conformal offsets are calibrated against the unseeded bsf
    trajectory — a warm threshold there collapses recall) or the top-k
    merge as a candidate.  In exact mode the returned ids/dists are
    therefore bitwise those of an unseeded run — only ``searched``/
    ``computed`` (and wall-clock on the compact strategy) shrink; in
    filtered mode the conformal recall contract is preserved because a leaf
    with lb > ub holds no true top-k member.  +inf entries are the no-op
    seed.
    trace: static flag; True additionally returns a per-query
    :class:`~repro.obs.trace.CascadeTrace` on ``EngineResult.trace``
    (which bound pruned which leaf, survivors, exact distance rows paid —
    see ``repro.obs.trace`` for the attribution semantics and the
    accounting identity).  Results are bitwise-identical either way, and
    ``trace=False`` lowers to the byte-identical program (the flag is a
    Python-level branch on extra masked-sum counters only).
    audit: static flag; True additionally returns a per-leaf
    :class:`~repro.obs.audit.FilterAudit` on ``EngineResult.audit`` —
    prune counts by bound, work saved, and prediction-residual statistics
    (``true_leaf_nn − d_F``) for the leaves the engine scored exactly, at
    zero extra distance computations (see ``repro.obs.audit`` for the
    residual semantics and the per-leaf accounting identity).  Same
    discipline as ``trace``: results are bitwise-identical either way and
    ``audit=False`` lowers to the byte-identical program.
    """
    if strategy == "auto":
        strategy = "compact"
    ub = (jnp.full(queries.shape[0], _INF) if bsf_ub is None
          else jnp.asarray(bsf_ub, jnp.float32))
    aux = None
    parts = None
    if strategy == "scan":
        if audit:
            (td, ti, n_s, n_plb, n_pf, n_box, n_seed, n_rows,
             parts) = sanitize.call(
                _scan_cascade, series, leaf_start, leaf_size, queries,
                d_lb, d_F, ub, k=k, max_leaf=max_leaf, trace=trace,
                audit=True)
            if trace:
                zeros = jnp.zeros(queries.shape[0], jnp.int32)
                aux = CascadeTrace(n_box, n_seed, n_pf, zeros, n_s, zeros,
                                   n_rows)
        elif trace:
            (td, ti, n_s, n_plb, n_pf, n_box, n_seed,
             n_rows) = sanitize.call(
                _scan_cascade, series, leaf_start, leaf_size, queries,
                d_lb, d_F, ub, k=k, max_leaf=max_leaf, trace=True)
            zeros = jnp.zeros(queries.shape[0], jnp.int32)
            aux = CascadeTrace(n_box, n_seed, n_pf, zeros, n_s, zeros,
                               n_rows)
        else:
            td, ti, n_s, n_plb, n_pf = sanitize.call(
                _scan_cascade, series, leaf_start, leaf_size, queries,
                d_lb, d_F, ub, k=k, max_leaf=max_leaf)
        n_c = jnp.full(queries.shape[0], leaf_start.shape[0], jnp.int32)
    elif strategy == "compact":
        out = _compact_cascade(
            series, leaf_start, leaf_size, queries, d_lb, d_F, ub,
            k=k, max_leaf=max_leaf, dist_impl=dist_impl, trace=trace,
            audit=audit)
        td, ti, n_s, n_plb, n_pf, n_c = out[:6]
        rest = list(out[6:])
        if trace:
            aux = rest.pop(0)
        if audit:
            parts = rest.pop(0)
    else:
        raise ValueError(f"unknown engine strategy {strategy!r}")
    fa = None
    if audit:
        fa = obs_audit.reduce_parts(parts, jnp.asarray(d_F, jnp.float32),
                                    leaf_size)
    return EngineResult(td, ti, n_s, n_plb, n_pf, n_c, aux, fa)


# ---------------------------------------------------------------------------
# leaf-slab build passes (filter_training's training-data collection)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("max_leaf", "chunk", "dist_impl"))
def _all_leaves_min(series, leaf_start, leaf_size, queries,
                    max_leaf, chunk, dist_impl):
    Q = queries.shape[0]
    L = leaf_start.shape[0]
    Lp = -(-L // chunk) * chunk
    leaf_ids = jnp.arange(Lp)                            # ids ≥ L are padding

    def step(i, out):
        lu = jax.lax.dynamic_slice_in_dim(leaf_ids, i * chunk, chunk, 0)
        slabs, _, valid = l2_ops.gather_leaf_slabs(
            series, leaf_start, leaf_size, lu, max_leaf)
        d = l2_ops.shared_slab_l2(queries, slabs, dist_impl)  # (Q, c, R)
        dmin = jnp.where(valid[None, :, :], d, _INF).min(-1)  # (Q, c)
        return jax.lax.dynamic_update_slice_in_dim(out, dmin, i * chunk, 1)

    out = jax.lax.fori_loop(0, Lp // chunk, step, jnp.full((Q, Lp), _INF))
    return out[:, :L]


def nn_distance_all_leaves(
    series: jnp.ndarray,
    leaf_start: jnp.ndarray,
    leaf_size: jnp.ndarray,
    queries: jnp.ndarray,          # (Q, m)
    *,
    max_leaf: int,
    dist_impl: Optional[str] = None,
    chunk: Optional[int] = None,
) -> jnp.ndarray:
    """Min distance from every query to every leaf → (Q, L).

    The build side's "first pass" (paper Alg. 1 target collection), as one
    jitted sweep over the padded leaf-slab layer: leaves stream through in
    cache-resident chunks (same budget as the compact engine's candidate
    buckets), each scored by ``shared_slab_l2`` — the ``pairwise`` Pallas
    kernel on TPU, its matmul decomposition elsewhere — and masked-min
    reduced.  No per-leaf Python iteration, no per-leaf retracing.
    """
    Q, m = queries.shape
    L = leaf_start.shape[0]
    dist_impl = dist_impl or l2_ops.default_slab_impl()
    if chunk is None:
        chunk = _pow2_chunk((Q * max_leaf + max_leaf * m) * 4,
                            _next_pow2(L))
    return sanitize.call(_all_leaves_min, series, leaf_start, leaf_size,
                         queries, max_leaf=max_leaf, chunk=chunk,
                         dist_impl=dist_impl)


@functools.partial(jax.jit,
                   static_argnames=("max_leaf", "chunk", "dist_impl"))
def _own_leaf_min(series, leaf_start, leaf_size, local_queries, leaf_ids,
                  max_leaf, chunk, dist_impl):
    F, nq, m = local_queries.shape
    L = leaf_start.shape[0]
    Fp = -(-F // chunk) * chunk
    ids_p = jnp.pad(jnp.asarray(leaf_ids), (0, Fp - F), constant_values=L)
    q_p = jnp.pad(local_queries, ((0, Fp - F), (0, 0), (0, 0)))

    def step(i, out):
        ids = jax.lax.dynamic_slice_in_dim(ids_p, i * chunk, chunk, 0)
        qs = jax.lax.dynamic_slice_in_dim(q_p, i * chunk, chunk, 0)
        slabs, _, valid = l2_ops.gather_leaf_slabs(
            series, leaf_start, leaf_size, ids, max_leaf)
        d = l2_ops.slab_l2(qs, slabs, dist_impl)              # (c, nq, R)
        dmin, _ = l2_ops.slab_masked_min(d, valid)            # (c, nq)
        return jax.lax.dynamic_update_slice_in_dim(out, dmin, i * chunk, 0)

    out = jax.lax.fori_loop(0, Fp // chunk, step, jnp.full((Fp, nq), _INF))
    return out[:F]


def nn_distance_own_leaf(
    series: jnp.ndarray,
    leaf_start: jnp.ndarray,
    leaf_size: jnp.ndarray,
    local_queries: jnp.ndarray,    # (F, nq, m) per-leaf query batches
    leaf_ids: jnp.ndarray,         # (F,)
    *,
    max_leaf: int,
    dist_impl: Optional[str] = None,
    chunk: Optional[int] = None,
) -> jnp.ndarray:
    """Min distance of each leaf's own query batch to that leaf → (F, nq).

    The build side's local-query target pass: one jitted sweep where every
    selected leaf's slab is gathered once and scored against its own noisy
    queries via the vmapped slab primitives (``slab_l2`` — the batched
    ``slab_l2_kernel`` Pallas path on TPU).  Replaces the seed's per-leaf
    ``dynamic_slice`` loop, which retraced and dispatched once per filter.
    """
    F, nq, m = local_queries.shape
    dist_impl = dist_impl or l2_ops.default_slab_impl()
    if chunk is None:
        chunk = _pow2_chunk((nq * max_leaf + max_leaf * m + nq * m) * 4,
                            _next_pow2(max(F, 1)))
    return sanitize.call(_own_leaf_min, series, leaf_start, leaf_size,
                         local_queries, jnp.asarray(leaf_ids),
                         max_leaf=max_leaf, chunk=chunk, dist_impl=dist_impl)


# ---------------------------------------------------------------------------
# shard_map-safe pieces shared with distributed.py
# ---------------------------------------------------------------------------


def probe_best_leaf(series, leaf_start, leaf_size, lb, queries, max_leaf):
    """Min distance to each query's best-lb leaf → (Q,) bsf seed.

    jit/shard_map-safe (static shapes); the collective analogue of the
    engine's phase-1 probe, used by the distributed two-phase exchange.
    Zero-size (shard-padding) leaves are skipped defensively: their lb is
    forced to +inf before the argmin, so the probe never lands on an empty
    leaf and wastes the seed on +inf — regardless of whether the caller
    already masked ``lb``.
    """
    lb = jnp.where(leaf_size[None, :] > 0, lb, _INF)
    best_leaf = lb.argmin(axis=1)
    row_ids = jnp.arange(max_leaf)

    def probe(q, leaf):
        slab = jax.lax.dynamic_slice_in_dim(
            series, leaf_start[leaf], max_leaf, 0)
        dd = jnp.sqrt(((slab - q[None]) ** 2).sum(-1))
        return jnp.where(row_ids < leaf_size[leaf], dd, _INF).min()

    return jax.vmap(probe)(queries, best_leaf)


def masked_bsf_scan(series, leaf_start, leaf_size, lb, d_F, queries,
                    max_leaf, bsf0, bsf_ub=None, trace=False, audit=False):
    """Best-so-far cascade over all leaves from a seed bsf → (bsf, n_s).

    The 1-NN, distance-only form of ``strategy="scan"``; leaves with size 0
    are treated as lb-pruned (shard padding).  jit/shard_map-safe — this is
    the per-shard body ``distributed._local_search`` routes through.

    ``bsf_ub``: optional (Q,) prune-only bound (``run_cascade``'s warm-start
    contract) — it tightens the lb test only, never the filter test.  Unlike
    ``bsf0`` it never enters the bsf carry — the returned bsf is always a
    real (witnessed) distance or the seed, never the bound.

    ``trace=True`` (a Python-level flag — still jit/shard_map-safe)
    appends a ``(n_box, n_seed, n_filter, n_rows)`` tuple of ``(Q,)``
    step-level counters (box/seed split of the lb prune, filter prunes,
    distance rows consulted); padding leaves count as box-pruned.

    ``audit=True`` (also Python-level, shard_map-safe) returns
    ``(bsf, n_s, trace_tuple, parts)`` regardless of ``trace`` — the same
    step-level counters plus the per-(query, leaf)
    :class:`~repro.obs.audit.AuditParts` decision planes in leaf order,
    for the :func:`repro.obs.audit.reduce_parts` leafwise reduction.
    """
    row_ids = jnp.arange(max_leaf)
    order = jnp.argsort(lb, axis=1)
    L = lb.shape[1]
    if bsf_ub is None:
        bsf_ub = jnp.full(queries.shape[0], _INF)

    def per_query(q, lb_row, dF_row, order_row, bsf_init, ub):
        def step(carry, leaf):
            bsf, n_s = carry
            valid = leaf_size[leaf] > 0
            p_lb = jnp.logical_or(lb_row[leaf] > jnp.minimum(bsf, ub),
                                  ~valid)
            p_f = jnp.logical_and(~p_lb, dF_row[leaf] > bsf)
            pruned = p_lb | p_f
            slab = jax.lax.dynamic_slice_in_dim(
                series, leaf_start[leaf], max_leaf, 0)
            diff = slab - q[None, :]
            d = jnp.sqrt((diff * diff).sum(-1))
            d = jnp.where((row_ids < leaf_size[leaf]) & ~pruned, d, _INF)
            bsf = jnp.minimum(bsf, d.min())
            return (bsf, n_s + (~pruned).astype(jnp.int32)), None

        def step_traced(carry, leaf):
            # mirrors `step` plus masked-sum trace counters.
            bsf, n_s, n_box, n_seed, n_pf, n_rows = carry
            valid = leaf_size[leaf] > 0
            p_lb = jnp.logical_or(lb_row[leaf] > jnp.minimum(bsf, ub),
                                  ~valid)
            p_box = jnp.logical_or(lb_row[leaf] > bsf, ~valid)
            p_seed = jnp.logical_and(p_lb, ~p_box)
            p_f = jnp.logical_and(~p_lb, dF_row[leaf] > bsf)
            pruned = p_lb | p_f
            slab = jax.lax.dynamic_slice_in_dim(
                series, leaf_start[leaf], max_leaf, 0)
            diff = slab - q[None, :]
            d = jnp.sqrt((diff * diff).sum(-1))
            d = jnp.where((row_ids < leaf_size[leaf]) & ~pruned, d, _INF)
            bsf = jnp.minimum(bsf, d.min())
            rows = jnp.where(pruned, 0, leaf_size[leaf]).astype(jnp.int32)
            return (bsf, n_s + (~pruned).astype(jnp.int32),
                    n_box + p_box.astype(jnp.int32),
                    n_seed + p_seed.astype(jnp.int32),
                    n_pf + p_f.astype(jnp.int32),
                    n_rows + rows), None

        def step_audit(carry, leaf):
            # mirrors `step_traced` and emits the per-leaf decision planes
            # (visit order); d.min() is the leaf's exact NN distance when
            # scanned, +inf when pruned or padding.
            bsf, n_s, n_box, n_seed, n_pf, n_rows = carry
            valid = leaf_size[leaf] > 0
            p_lb = jnp.logical_or(lb_row[leaf] > jnp.minimum(bsf, ub),
                                  ~valid)
            p_box = jnp.logical_or(lb_row[leaf] > bsf, ~valid)
            p_seed = jnp.logical_and(p_lb, ~p_box)
            p_f = jnp.logical_and(~p_lb, dF_row[leaf] > bsf)
            pruned = p_lb | p_f
            slab = jax.lax.dynamic_slice_in_dim(
                series, leaf_start[leaf], max_leaf, 0)
            diff = slab - q[None, :]
            d = jnp.sqrt((diff * diff).sum(-1))
            d = jnp.where((row_ids < leaf_size[leaf]) & ~pruned, d, _INF)
            bsf = jnp.minimum(bsf, d.min())
            rows = jnp.where(pruned, 0, leaf_size[leaf]).astype(jnp.int32)
            ys = (p_box, p_seed, p_f, ~pruned, d.min())
            return (bsf, n_s + (~pruned).astype(jnp.int32),
                    n_box + p_box.astype(jnp.int32),
                    n_seed + p_seed.astype(jnp.int32),
                    n_pf + p_f.astype(jnp.int32),
                    n_rows + rows), ys

        if audit:
            init = (bsf_init, jnp.int32(0), jnp.int32(0), jnp.int32(0),
                    jnp.int32(0), jnp.int32(0))
            (bsf, n_s, n_box, n_seed, n_pf, n_rows), ys = jax.lax.scan(
                step_audit, init, order_row)
            vb, vs, vf, vk, vnn = ys              # (L,) in visit order

            def scat(v):
                return jnp.zeros((L,), v.dtype).at[order_row].set(v)

            return (bsf, n_s, n_box, n_seed, n_pf, n_rows,
                    scat(vb), scat(vs), scat(vf), scat(vk), scat(vnn))
        if trace:
            init = (bsf_init, jnp.int32(0), jnp.int32(0), jnp.int32(0),
                    jnp.int32(0), jnp.int32(0))
            (bsf, n_s, n_box, n_seed, n_pf, n_rows), _ = jax.lax.scan(
                step_traced, init, order_row)
            return bsf, n_s, n_box, n_seed, n_pf, n_rows
        (bsf, n_s), _ = jax.lax.scan(step, (bsf_init, jnp.int32(0)),
                                     order_row)
        return bsf, n_s

    out = jax.vmap(per_query)(queries, lb, d_F, order, bsf0, bsf_ub)
    if audit:
        bsf, n_s, n_box, n_seed, n_pf, n_rows, pb, ps, pf_, kept, nn = out
        parts = AuditParts(pb, ps, pf_, kept, kept, nn)
        return bsf, n_s, (n_box, n_seed, n_pf, n_rows), parts
    if trace:
        bsf, n_s, n_box, n_seed, n_pf, n_rows = out
        return bsf, n_s, (n_box, n_seed, n_pf, n_rows)
    return out


def default_max_survivors(n_leaves: int) -> int:
    """Default fixed survivor capacity for ``compact_bsf_cascade``.

    An eighth of the shard's leaf slots, rounded up to a power of two: small
    enough that the candidate pass beats the masked scan by ~8× at high
    pruning ratios, large enough that well-calibrated cascades rarely
    overflow into the scan fallback.  :func:`tuned_max_survivors` replaces
    this static guess with a percentile of observed survivor counts.
    """
    return min(_next_pow2(max(n_leaves // 8, 1)), _next_pow2(n_leaves))


def tuned_max_survivors(survivor_counts, n_leaves: int,
                        pct: float = 99.0, min_samples: int = 0) -> int:
    """Survivor capacity from observed per-query survivor-count statistics.

    The ``pct``-th percentile of the observed counts, rounded up to a power
    of two (the rounding is the drift headroom), clamped to
    [1, next_pow2(n_leaves)] like the static default.  At matched traffic
    the overflow-fallback frequency is then bounded by ~(100 − pct)% by
    construction instead of hoping the P/8 default fits the workload
    (tests/test_serving.py pins the bound on a drifting distribution).  The
    serving runtime feeds this from its rolling survivor-count window
    (``serving.telemetry.Telemetry.suggest_max_survivors``); with no
    observations yet it degrades to :func:`default_max_survivors`.

    ``min_samples``: below this many observations the ``pct``-th percentile
    of the window is statistically meaningless (e.g. the p99 of 5 samples is
    just their max-ish), and a handful of easy early queries would lock in
    an unstable *low* capacity that overflow-falls-back on the first hard
    one.  Cold-start calls therefore floor the estimate at the configured
    :func:`default_max_survivors` until the window has filled — the
    estimate can tighten traffic upward early, never downward.
    """
    counts = np.asarray(survivor_counts)
    if counts.size == 0:
        return default_max_survivors(n_leaves)
    cap = int(np.ceil(np.percentile(counts, pct)))
    cap = min(_next_pow2(max(cap, 1)), _next_pow2(n_leaves))
    if counts.size < max(int(min_samples), 0):
        cap = max(cap, default_max_survivors(n_leaves))
    return cap


def compact_bsf_cascade(series, leaf_start, leaf_size, lb, d_F, queries,
                        max_leaf, bsf0, *, max_survivors=None,
                        dist_impl=None, bsf_ub=None, trace=False,
                        audit=False):
    """Fixed-width survivor compaction form of ``masked_bsf_scan``.

    Same contract — 1-NN bsf cascade from a seed ``bsf0`` over all leaves,
    returning (bsf (Q,), n_searched (Q,)) — but distance compute is paid
    only for a fixed-capacity buffer of cascade survivors, so the shapes
    stay fully static and the whole thing is legal *inside* ``shard_map``
    (where the single-device engine's data-dependent bucketing is not):

      1. mask survivors (``lb ≤ bsf0``, ``d_F ≤ bsf0``, ``leaf_size > 0``;
         since the cascade's bsf only decreases from ``bsf0``, survivors are
         a superset of the leaves the masked scan actually scans);
      2. compact survivor leaf ids, ascending-lb first, into a static
         ``max_survivors``-wide buffer via stable argsorts (jit-safe), with
         id ``P`` as the harmless-gather sentinel;
      3. score the buffer through the batched ``l2_scan`` candidate
         primitives and replay the exact cascade over the per-leaf minima
         via :func:`replay_cascade` (k=1, seeded with ``bsf0``, padding
         leaves lb-pruned) — bitwise-identical decisions, counters and bsf
         to the masked scan under ``dist_impl="direct"`` given identical
         inputs (tests/test_engine.py pins this; across *differently fused
         programs* the usual XLA caveat applies — a prune threshold within
         an ulp of the bsf may resolve differently, see
         tests/test_distributed.py).

    Queries whose survivor count exceeds the capacity fall back to the
    masked scan (one ``lax.cond`` over the batch), so semantics stay exact
    at any ``max_survivors``; the default capacity is
    :func:`default_max_survivors` of the leaf-slot count.

    ``trace=True`` (a Python-level flag, shard_map-legal) appends a
    per-query :class:`~repro.obs.trace.CascadeTrace`: mask-stage
    box/seed/filter attribution (shard-padding leaves count as
    box-pruned), ``survivors`` entering the candidate pass, the
    ``overflow`` fallback flag, and distance rows paid; overflow queries
    carry the scan fallback's step-level counters instead.  Results are
    bitwise-identical either way; ``trace=False`` lowers to the
    byte-identical program.

    ``audit=True`` (same discipline) additionally appends the
    per-(query, leaf) :class:`~repro.obs.audit.AuditParts` decision planes
    — mask-stage attribution with ``kept`` = the survivor mask and
    ``leaf_nn`` from the candidate pass's per-leaf minima; overflow
    queries carry the masked-scan fallback's step-level planes instead
    (selected per query before any leafwise reduction).  The return is
    ``(bsf, n_s[, trace][, parts])`` in flag order.
    """
    Q, m = queries.shape
    P = leaf_start.shape[0]
    if max_survivors is None:
        max_survivors = default_max_survivors(P)
    # leafi: ignore[LF001]: max_survivors is a host int (caller arg or leaf-count default) — capacity must be static
    C = max(min(int(max_survivors), P), 1)
    dist_impl = dist_impl or l2_ops.default_gathered_impl()
    if bsf_ub is None:
        bsf_ub = jnp.full(Q, _INF)

    valid = leaf_size > 0
    lb = jnp.where(valid[None, :], lb, _INF)
    # prune-only bound: the lb mask uses min(bsf0, ub) — matching the
    # replay's effective lb threshold after the seed merge — while d_F masks
    # against bsf0 alone, because the replay's filter test compares against
    # the witnessed bsf (≤ bsf0), never the warm bound (superset preserved).
    bsf0m = jnp.minimum(bsf0, bsf_ub)
    survive = (lb <= bsf0m[:, None]) & (d_F <= bsf0[:, None]) \
        & valid[None, :]
    n_surv = survive.sum(axis=1).astype(jnp.int32)

    # survivors first, in ascending-lb order (stable argsort of the inverted
    # mask over lb-ordered slots — the same compaction the single-device
    # engine does per bucket, at one static width)
    order = jnp.argsort(lb, axis=1)                      # (Q, P)
    mask_ord = jnp.take_along_axis(survive, order, axis=1)
    sel = jnp.argsort(~mask_ord, axis=1)[:, :C]
    slot_ok = jnp.take_along_axis(mask_ord, sel, axis=1)
    leaf_b = jnp.where(slot_ok, jnp.take_along_axis(order, sel, axis=1), P)

    chunk = _chunk_for(Q, C, max_leaf, m)
    Cp = -(-C // chunk) * chunk                          # pad C to chunks
    if Cp > C:
        leaf_b = jnp.pad(leaf_b, ((0, 0), (0, Cp - C)), constant_values=P)
    vals, _ = sanitize.call(_bucket_leaf_topk, series, leaf_start,
                            leaf_size, queries, leaf_b, kk=1,
                            max_leaf=max_leaf, chunk=chunk,
                            dist_impl=dist_impl)
    # per-leaf min-distance summaries; sentinel (== P) writes land in a
    # scratch row that is sliced off — in-bounds by construction, so index
    # sanitizers stay quiet.
    leaf_min = jnp.full((Q, P + 1), _INF)
    leaf_min = leaf_min.at[jnp.arange(Q)[:, None], leaf_b].set(
        vals[:, :, 0])[:, :P]

    td, _, n_s, _, _ = replay_cascade(
        leaf_min[..., None], jnp.full((Q, P, 1), -1, jnp.int32),
        lb, d_F, order, k=1, bsf0=bsf0, leaf_valid=valid, bsf_ub=bsf_ub)
    bsf_c, ns_c = td[:, 0], n_s

    # overflow queries (survivors > capacity) would replay against missing
    # summaries — route the whole batch through the masked scan and select
    # per query; the cond keeps the scan off the hot path when nobody
    # overflows.
    overflow = n_surv > C
    if not (trace or audit):
        bsf_s, ns_s = jax.lax.cond(
            overflow.any(),
            lambda: masked_bsf_scan(series, leaf_start, leaf_size, lb, d_F,
                                    queries, max_leaf, bsf0, bsf_ub),
            lambda: (jnp.full((Q,), _INF), jnp.zeros((Q,), jnp.int32)))
        return (jnp.where(overflow, bsf_s, bsf_c),
                jnp.where(overflow, ns_s, ns_c))

    zq = jnp.zeros((Q,), jnp.int32)
    if audit:
        bsf_s, ns_s, scan_tr, scan_parts = jax.lax.cond(
            overflow.any(),
            lambda: masked_bsf_scan(series, leaf_start, leaf_size, lb, d_F,
                                    queries, max_leaf, bsf0, bsf_ub,
                                    audit=True),
            lambda: (jnp.full((Q,), _INF), jnp.zeros((Q,), jnp.int32),
                     (zq, zq, zq, zq), obs_audit.zero_parts(Q, P)))
    else:
        bsf_s, ns_s, scan_tr = jax.lax.cond(
            overflow.any(),
            lambda: masked_bsf_scan(series, leaf_start, leaf_size, lb, d_F,
                                    queries, max_leaf, bsf0, bsf_ub,
                                    trace=True),
            lambda: (jnp.full((Q,), _INF), jnp.zeros((Q,), jnp.int32),
                     (zq, zq, zq, zq)))

    # mask-stage attribution of the non-survivors (exact partition —
    # ~survive ⇒ invalid, lb > bsf0m, or d_F > bsf0; invalid/padding leaves
    # land in box because their lb was forced to +inf above).
    not_s = ~survive
    p_box = not_s & ((lb > bsf0[:, None]) | ~valid[None, :])
    p_seed = not_s & ~p_box & (lb > bsf0m[:, None])
    p_filt = not_s & ~p_box & ~p_seed
    rets = (jnp.where(overflow, bsf_s, bsf_c),
            jnp.where(overflow, ns_s, ns_c))
    if trace:
        sizes = leaf_size.astype(jnp.int32)
        compact_rows = jnp.where(survive, sizes[None, :], 0).sum(axis=1)
        s_box, s_seed, s_pf, s_rows = scan_tr
        compact_tr = CascadeTrace(
            pruned_box=p_box.sum(axis=1).astype(jnp.int32),
            pruned_seed=p_seed.sum(axis=1).astype(jnp.int32),
            pruned_filter=p_filt.sum(axis=1).astype(jnp.int32),
            probed=zq, survivors=n_surv, overflow=zq,
            distances=compact_rows)
        scan_as_tr = CascadeTrace(
            pruned_box=s_box, pruned_seed=s_seed, pruned_filter=s_pf,
            probed=zq, survivors=ns_s, overflow=jnp.ones((Q,), jnp.int32),
            distances=s_rows)
        rets = rets + (_trace_select(overflow, scan_as_tr, compact_tr),)
    if audit:
        # leaf_min holds each survivor's exact NN distance (+inf for
        # never-gathered leaves), so it doubles as the audit's leaf_nn.
        compact_parts = AuditParts(p_box, p_seed, p_filt, survive,
                                   jnp.isfinite(leaf_min), leaf_min)
        rets = rets + (obs_audit.select_parts(overflow, scan_parts,
                                              compact_parts),)
    return rets
