"""LeaFi-enhanced index building (paper Alg. 1), end to end.

    1. build the backbone tree (DSTree- or iSAX-flavored)        [tree.py]
    2. select leaves for filter insertion                        [selection.py]
    3. generate global + local training data, collect targets    [filter_training.py]
    4. train all filters (vmapped SGD)                           [filter_training.py]
    5. fit conformal auto-tuners on the calibration split        [conformal.py]

Steps 3–5 — the build-cost hot path the paper identifies (training-data
generation dominates build overhead) — all run on the engine's leaf-slab
batch layer: target collection is two jitted chunked sweeps over padded
leaf slabs (:func:`engine.nn_distance_all_leaves` /
:func:`engine.nn_distance_own_leaf`, the Pallas all-pairs kernel on TPU),
and calibration replays the same bsf cascade the search engine uses
(:func:`engine.replay_cascade` via ``conformal.simulate_search``).  No step
iterates leaves in Python; ``benchmarks/build_bench.py`` tracks the gap to
the seed per-leaf reference path.

The returned LeaFiIndex is a pytree: it jits, shards, and checkpoints.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import conformal, filter_training, filters, search, selection, tree
from .flat_index import FlatIndex
from ..obs import span


@dataclasses.dataclass
class LeaFiConfig:
    backbone: str = "dstree"          # "dstree" | "isax"
    leaf_capacity: int = 256
    n_segments: int = 8               # dstree EAPCA segments
    word_len: int = 8                 # isax word length
    # training data sizes; the paper uses n_q = 2000 with n_g/n_l = 3
    n_global: int = 600
    n_local: int = 200
    calib_fraction: float = 0.3       # calibration split of the global set
    # selection (Alg. 3); t_F/t_S default from the paper's Deep measurement
    a: float = 2.0
    t_filter_over_t_series: float = 279.0
    filter_memory_budget_bytes: int = 6 << 30
    hidden: Optional[int] = None
    # filter backbone ("mlp" | "cnn" | "rnn"; build-side training is
    # MLP-only, see build_leafi) and weight payload dtype for inference
    # ("float32" | "bfloat16" | "int8" — the fused kernel's variants)
    filter_type: str = "mlp"
    weight_dtype: str = "float32"
    train: filter_training.TrainConfig = dataclasses.field(
        default_factory=filter_training.TrainConfig)
    seed: int = 0


@dataclasses.dataclass
class CalibSplit:
    """The conformal calibration split, kept so tuners can be *refit*.

    Quantizing filter weights shifts every prediction; the auto-tuner
    offsets must be refit on the shifted predictions or the quality→offset
    mapping silently drifts (§4.4).  Storing the split's queries and
    replay inputs makes :func:`requantize_leafi` a pure post-build step.
    """
    queries: np.ndarray               # (n_cal, m)
    d_lb: np.ndarray                  # (n_cal, L) summarization lower bounds
    d_L: np.ndarray                   # (n_cal, L) node-wise NN distances


@dataclasses.dataclass
class LeaFiIndex:
    index: FlatIndex
    filter_params: Optional[Dict[str, jnp.ndarray]]
    leaf_ids: np.ndarray                      # leaves carrying filters
    tuner: Optional[conformal.AutoTuner]
    config: LeaFiConfig
    build_report: Dict[str, float]
    calib: Optional[CalibSplit] = None

    # -- query API ----------------------------------------------------------
    def search(self, queries, k: int = 1,
               quality_target: Optional[float] = 0.99,
               use_filters: bool = True, **kw) -> search.SearchResult:
        """quality_target=None or use_filters=False ⇒ exact search."""
        kw.setdefault("filter_type", getattr(self.config, "filter_type",
                                             "mlp"))
        return search.search_batched(
            self.index, queries, k=k, filter_params=self.filter_params,
            leaf_ids=self.leaf_ids, tuner=self.tuner,
            quality_target=quality_target,
            use_filters=use_filters and quality_target is not None, **kw)

    def search_exact(self, queries, k: int = 1) -> search.SearchResult:
        return self.search(queries, k=k, use_filters=False,
                           quality_target=None)


def build_leafi(series: np.ndarray, config: LeaFiConfig = LeaFiConfig(),
                key: jax.Array | None = None) -> LeaFiIndex:
    """Alg. 1: LeaFi-enhanced index building."""
    if config.filter_type != "mlp":
        raise NotImplementedError(
            "build-side filter training is MLP-only (the paper's default); "
            "the CNN/RNN ablation backbones are reachable from search "
            "(filters.APPLY) with externally trained parameters")
    key = key if key is not None else jax.random.PRNGKey(config.seed)
    report: Dict[str, float] = {}

    # 0. backbone index
    t0 = time.perf_counter()
    with span("build.index", cat="build", backbone=config.backbone):
        if config.backbone == "dstree":
            index = tree.build_dstree(series, config.leaf_capacity,
                                      config.n_segments)
        elif config.backbone == "isax":
            index = tree.build_isax(series, config.leaf_capacity,
                                    config.word_len)
        else:
            raise ValueError(config.backbone)
    report["t_index_build"] = time.perf_counter() - t0

    # 1. SelectLeafNode (Alg. 3) — t_F/t_S from config (measured on real
    #    hardware by benchmarks/model_type.py; th = a · t_F / t_S).
    hidden = config.hidden or index.length
    fbytes = filters.mlp_param_bytes(index.length, hidden,
                                     config.weight_dtype)
    leaf_ids = selection.select_leaves(
        np.asarray(index.leaf_size),
        t_filter=config.t_filter_over_t_series, t_series=1.0, a=config.a,
        filter_bytes=fbytes,
        memory_budget_bytes=config.filter_memory_budget_bytes)
    report["n_filters"] = float(len(leaf_ids))
    report["n_leaves"] = float(index.n_leaves)

    if len(leaf_ids) == 0:
        return LeaFiIndex(index, None, leaf_ids, None, config,
                          report)

    # 2-3. training data (global + local, two-pass collection)
    t0 = time.perf_counter()
    kdata, ktrain = jax.random.split(key)
    with span("build.collect", cat="build", n_filters=len(leaf_ids),
              n_global=config.n_global, n_local=config.n_local):
        data = filter_training.collect_training_data(
            index, leaf_ids, config.n_global, config.n_local, kdata)
    report["t_collect"] = time.perf_counter() - t0

    # 4. TrainFilters — vmapped SGD on the proper-training split
    n_cal = max(int(config.n_global * config.calib_fraction), 8)
    train_data = filter_training.TrainingData(
        global_queries=data.global_queries[:-n_cal],
        global_d_L=data.global_d_L[:-n_cal],
        global_d_lb=data.global_d_lb[:-n_cal],
        local_queries=data.local_queries,
        local_d_L=data.local_d_L,
        leaf_ids=data.leaf_ids)
    t0 = time.perf_counter()
    cfg_train = dataclasses.replace(config.train, hidden=config.hidden)
    with span("build.train", cat="build", n_filters=len(leaf_ids)):
        params, train_report = filter_training.train_filters(
            index, train_data, cfg_train, ktrain)
    report["t_train"] = time.perf_counter() - t0
    report["val_rmse_z"] = float(train_report["val_rmse_z"].mean())

    # 4b. optional weight compression — quantize BEFORE calibration, so the
    # conformal offsets are fit on the predictions search will actually see
    # and absorb the quantization error into the quality→offset mapping.
    params = filters.quantize_mlp(params, config.weight_dtype)

    # 5. FitAutoTuners on the calibration split (Alg. 4)
    t0 = time.perf_counter()
    with span("build.calibrate", cat="build", n_cal=n_cal):
        calib = CalibSplit(queries=np.asarray(data.global_queries[-n_cal:]),
                           d_lb=np.asarray(data.global_d_lb[-n_cal:]),
                           d_L=np.asarray(data.global_d_L[-n_cal:]))
        d_pred_cal = search.predictions_for_all_leaves(
            index, params, leaf_ids, jnp.asarray(calib.queries), offsets=None,
            filter_type=config.filter_type)
        # unfiltered leaves must never filter-prune in the simulation: -inf
        tuner, cal_report = conformal.fit_autotuners(
            d_lb=calib.d_lb,
            d_pred=np.asarray(d_pred_cal),
            d_L=calib.d_L,
            leaf_ids=leaf_ids)
    report["t_calibrate"] = time.perf_counter() - t0
    report["calib_best_quality"] = float(cal_report["rank_quality"].max())

    return LeaFiIndex(index, params, leaf_ids, tuner, config, report, calib)


def requantize_leafi(lfi: LeaFiIndex, weight_dtype: str) -> LeaFiIndex:
    """Swap a built index's filter weights to another payload dtype.

    Quantizes (or restores to float32) the filter stack and *refits* the
    conformal auto-tuners on the stored calibration split, so the per-filter
    offsets absorb the quantization error instead of letting the quality
    targets drift.  The backbone arrays are shared, not copied.
    """
    cfg = dataclasses.replace(lfi.config, weight_dtype=weight_dtype)
    if lfi.filter_params is None:
        return dataclasses.replace(lfi, config=cfg)
    calib = getattr(lfi, "calib", None)
    if calib is None:
        raise ValueError(
            "index carries no calibration split (built by an older "
            "pipeline?) — rebuild with build_leafi to enable requantization")
    params = filters.quantize_mlp(lfi.filter_params, weight_dtype)
    d_pred = search.predictions_for_all_leaves(
        lfi.index, params, lfi.leaf_ids, jnp.asarray(calib.queries),
        offsets=None,
        filter_type=getattr(lfi.config, "filter_type", "mlp"))
    tuner, _ = conformal.fit_autotuners(
        d_lb=calib.d_lb, d_pred=np.asarray(d_pred), d_L=calib.d_L,
        leaf_ids=lfi.leaf_ids)
    return dataclasses.replace(lfi, filter_params=params, tuner=tuner,
                               config=cfg)
