"""Training-data generation (paper §4.3) and vmapped filter training.

Two-fold query generation:
* *global* queries — noisy uniform samples of the whole collection, searched
  against every leaf;
* *local*  queries — noisy samples of each selected leaf, searched only
  against their own leaf.

Both collection passes run on the engine's leaf-slab batch layer
(:mod:`repro.core.engine`): local queries are sampled by one vmapped RNG
sweep and both target passes are single jitted chunked sweeps over padded
(F, R, m) leaf slabs — no per-leaf Python iteration, no per-leaf retracing.
The seed's per-leaf forms are kept as ``_reference_*`` oracles; the parity
suite (tests/test_build_pipeline.py) pins the batched paths to them, and
``benchmarks/build_bench.py`` measures the gap.

Training runs every filter simultaneously: parameters are stacked on a
leading F axis and the SGD step is vmapped over it — the TPU-native
equivalent of the paper's 16 CUDA streams.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import engine, filters, summaries
from .flat_index import FlatIndex
from . import bounds as bounds_mod
from ..kernels.l2_scan import ops as l2_ops
from ..obs import span


# ---------------------------------------------------------------------------
# Query generation (paper §5.1 protocol: uniform samples + gaussian noise)
# ---------------------------------------------------------------------------


def make_noisy_queries(series: np.ndarray, n_queries: int, key: jax.Array,
                       noise_low: float = 0.1, noise_high: float = 0.4
                       ) -> np.ndarray:
    """Sample series uniformly, add N(0, noise²) with noise ~ U[low, high]."""
    kidx, klvl, knoise = jax.random.split(key, 3)
    n = series.shape[0]
    idx = jax.random.randint(kidx, (n_queries,), 0, n)
    lvl = jax.random.uniform(klvl, (n_queries, 1), minval=noise_low,
                             maxval=noise_high)
    base = jnp.asarray(series)[idx]
    noisy = base + lvl * jax.random.normal(knoise, base.shape)
    return np.asarray(summaries.znormalize(np.asarray(noisy)))


@functools.partial(jax.jit, static_argnames=("n_per_leaf", "m"))
def _sample_local_rng(sizes, keys, n_per_leaf, m, noise_low, noise_high):
    """One vmapped sweep of the per-leaf RNG recipe → (rows, lvl, noise).

    Per filter: split its key exactly as the reference loop does, draw row
    indices within the leaf, one noise level per query, gaussian noise — the
    per-key PRNG streams are identical to the sequential version, so every
    draw matches it bitwise.
    """

    def one(key, size):
        kidx, knoise, klvl = jax.random.split(key, 3)
        rows = jax.random.randint(kidx, (n_per_leaf,), 0, size)
        lvl = jax.random.uniform(klvl, (n_per_leaf, 1), minval=noise_low,
                                 maxval=noise_high)
        noise = jax.random.normal(knoise, (n_per_leaf, m))
        return rows, lvl, noise

    return jax.vmap(one)(keys, sizes)


def make_local_queries(index: FlatIndex, leaf_ids: np.ndarray, n_per_leaf: int,
                       key: jax.Array, noise_low: float = 0.1,
                       noise_high: float = 0.4) -> np.ndarray:
    """(F, n_per_leaf, m) noisy samples drawn from each selected leaf.

    Batched: one jitted vmapped RNG sweep plus one vectorized gather/add
    replace the seed's per-leaf host loop (kept as
    :func:`_reference_local_queries`).  The RNG key schedule is unchanged
    and the noisy-sum stays in numpy (same elementwise rounding, no XLA FMA
    refusion), so the output is bitwise-identical to the reference.
    """
    leaf_ids = np.asarray(leaf_ids)
    keys = jax.random.split(key, len(leaf_ids))
    sizes = jnp.asarray(index.leaf_size)[leaf_ids]
    rows, lvl, noise = _sample_local_rng(
        sizes, keys, n_per_leaf, index.length,
        jnp.float32(noise_low), jnp.float32(noise_high))
    rows = np.asarray(rows) + np.asarray(index.leaf_start)[leaf_ids][:, None]
    noisy = np.asarray(index.series)[rows] \
        + np.asarray(lvl) * np.asarray(noise)
    return summaries.znormalize(noisy)


# ---------------------------------------------------------------------------
# Target collection ("two-pass" search, array form)
# ---------------------------------------------------------------------------


def nodewise_nn_distances(index: FlatIndex, queries: jnp.ndarray,
                          dist_impl: Optional[str] = None) -> jnp.ndarray:
    """d_L for every (query, leaf): (Q, L).

    The paper's first collection pass — every leaf searched for every query
    — as one jitted sweep over the engine's leaf-slab layer: leaves stream
    through in cache-resident chunks, scored all-pairs (the ``l2_scan``
    Pallas kernel on TPU, its matmul decomposition elsewhere) and masked-min
    reduced per leaf.
    """
    queries = jnp.atleast_2d(jnp.asarray(queries))
    return engine.nn_distance_all_leaves(
        jnp.asarray(index.series), jnp.asarray(index.leaf_start),
        jnp.asarray(index.leaf_size), queries,
        max_leaf=index.max_leaf_size, dist_impl=dist_impl)


def local_nn_distances(index: FlatIndex, local_queries: np.ndarray,
                       leaf_ids: np.ndarray,
                       dist_impl: Optional[str] = None) -> np.ndarray:
    """d_L of each local query against its own leaf only: (F, n_loc).

    One jitted chunked sweep over the gathered (F, R, m) leaf slabs
    (:func:`engine.nn_distance_own_leaf`) instead of a per-leaf
    ``dynamic_slice`` loop.
    """
    return np.asarray(engine.nn_distance_own_leaf(
        jnp.asarray(index.series), jnp.asarray(index.leaf_start),
        jnp.asarray(index.leaf_size), jnp.asarray(local_queries),
        np.asarray(leaf_ids), max_leaf=index.max_leaf_size,
        dist_impl=dist_impl))


# ---------------------------------------------------------------------------
# Seed per-leaf reference paths — the oracles the batched collection is
# pinned against (tests/test_build_pipeline.py, benchmarks/build_bench.py).
# ---------------------------------------------------------------------------


def _reference_local_queries(index: FlatIndex, leaf_ids: np.ndarray,
                             n_per_leaf: int, key: jax.Array,
                             noise_low: float = 0.1,
                             noise_high: float = 0.4) -> np.ndarray:
    """Seed per-leaf loop for :func:`make_local_queries` (bitwise oracle)."""
    out = np.empty((len(leaf_ids), n_per_leaf, index.length), np.float32)
    keys = jax.random.split(key, len(leaf_ids))
    series = np.asarray(index.series)
    starts, sizes = np.asarray(index.leaf_start), np.asarray(index.leaf_size)
    for i, lf in enumerate(leaf_ids):
        kidx, knoise, klvl = jax.random.split(keys[i], 3)
        rows = np.asarray(
            jax.random.randint(kidx, (n_per_leaf,), 0, int(sizes[lf]))
        ) + int(starts[lf])
        lvl = np.asarray(jax.random.uniform(
            klvl, (n_per_leaf, 1), minval=noise_low, maxval=noise_high))
        noisy = series[rows] + lvl * np.asarray(
            jax.random.normal(knoise, (n_per_leaf, index.length)))
        out[i] = summaries.znormalize(noisy)
    return out


def _reference_nodewise_nn_distances(index: FlatIndex, queries: jnp.ndarray,
                                     block: int = 4096) -> jnp.ndarray:
    """Seed blocked pairwise pass + segment-min for nodewise targets."""
    queries = jnp.atleast_2d(jnp.asarray(queries))
    n, L = index.n_series, index.n_leaves
    series = jnp.asarray(index.series)[:n]
    sizes = np.asarray(index.leaf_size)
    leaf_of_row = jnp.asarray(np.repeat(np.arange(L), sizes), jnp.int32)

    mins = []
    for s in range(0, n, block):
        e = min(s + block, n)
        d = l2_ops.pairwise_l2(queries, series[s:e])          # (Q, b)
        mins.append(
            jax.ops.segment_min(d.T, leaf_of_row[s:e], num_segments=L)
        )                                                     # (L, Q)
    return jnp.stack(mins).min(axis=0).T                      # (Q, L)


def _reference_local_nn_distances(index: FlatIndex,
                                  local_queries: np.ndarray,
                                  leaf_ids: np.ndarray) -> np.ndarray:
    """Seed per-leaf ``dynamic_slice`` loop for the local targets."""
    series = jnp.asarray(index.series)
    starts = np.asarray(index.leaf_start)
    sizes = np.asarray(index.leaf_size)
    out = np.empty(local_queries.shape[:2], np.float32)
    for i, lf in enumerate(leaf_ids):
        s, z = int(starts[lf]), int(sizes[lf])
        slab = jax.lax.dynamic_slice_in_dim(series, s, index.max_leaf_size, 0)
        valid = jnp.arange(index.max_leaf_size) < z
        dmin, _ = l2_ops.masked_min_l2(jnp.asarray(local_queries[i]), slab, valid)
        out[i] = np.asarray(dmin)
    return out


@dataclasses.dataclass
class TrainingData:
    """Everything Alg. 1 collects before filter training."""
    global_queries: np.ndarray        # (n_g, m)
    global_d_L: np.ndarray            # (n_g, L)  node-wise NN distances
    global_d_lb: np.ndarray           # (n_g, L)  summarization lower bounds
    local_queries: np.ndarray         # (F, n_l, m)
    local_d_L: np.ndarray             # (F, n_l)
    leaf_ids: np.ndarray              # (F,) leaves with filters


def collect_training_data(index: FlatIndex, leaf_ids: np.ndarray,
                          n_global: int, n_local: int, key: jax.Array,
                          noise_low: float = 0.1, noise_high: float = 0.4,
                          dist_impl: Optional[str] = None) -> TrainingData:
    """Alg. 1 steps 2–3 on the engine's leaf-slab layer (batched passes)."""
    kg, kl = jax.random.split(key)
    with span("collect.global", cat="build", n_global=n_global):
        gq = make_noisy_queries(np.asarray(index.series[: index.n_series]),
                                n_global, kg, noise_low, noise_high)
        d_L = np.asarray(nodewise_nn_distances(index, jnp.asarray(gq),
                                               dist_impl))
        d_lb = np.asarray(bounds_mod.lower_bounds(index, jnp.asarray(gq)))
    with span("collect.local", cat="build", n_local=n_local,
              n_filters=len(leaf_ids)):
        lq = make_local_queries(index, leaf_ids, n_local, kl,
                                noise_low, noise_high)
        ld = local_nn_distances(index, lq, leaf_ids, dist_impl)
    return TrainingData(gq, d_L, d_lb, lq, ld, np.asarray(leaf_ids))


def _reference_collect_training_data(index: FlatIndex, leaf_ids: np.ndarray,
                                     n_global: int, n_local: int,
                                     key: jax.Array,
                                     noise_low: float = 0.1,
                                     noise_high: float = 0.4) -> TrainingData:
    """Seed per-leaf collection, kept as the parity/benchmark baseline."""
    kg, kl = jax.random.split(key)
    gq = make_noisy_queries(np.asarray(index.series[: index.n_series]),
                            n_global, kg, noise_low, noise_high)
    d_L = np.asarray(_reference_nodewise_nn_distances(index, jnp.asarray(gq)))
    d_lb = np.asarray(bounds_mod.lower_bounds(index, jnp.asarray(gq)))
    lq = _reference_local_queries(index, leaf_ids, n_local, kl,
                                  noise_low, noise_high)
    ld = _reference_local_nn_distances(index, lq, leaf_ids)
    return TrainingData(gq, d_L, d_lb, lq, ld, np.asarray(leaf_ids))


# ---------------------------------------------------------------------------
# vmapped SGD training
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    epochs: int = 300
    batch: int = 128
    lr: float = 1e-2
    momentum: float = 0.9
    val_fraction: float = 0.2          # paper: train/val split 4:1
    hidden: int | None = None
    seed: int = 0


def _sgd_step(params, grads, vel, lr, momentum):
    new_vel = jax.tree.map(lambda v, g: momentum * v + g, vel, grads)
    new_params = jax.tree.map(lambda p, v: p - lr * v, params, new_vel)
    return new_params, new_vel


@functools.partial(jax.jit, static_argnames=("cfg",))
def _train_filters_jit(params, xg, yg, xl, yl, val_mask_g, val_mask_l, cfg):
    """All-filters SGD.  Shapes:
    xg (n_g, m) shared; yg (F, n_g); xl (F, n_l, m); yl (F, n_l).
    Targets are standardized per filter before entry.
    Carries best-validation parameters (the paper's plateau/early-stop
    criterion, expressed scan-compatibly).
    """
    F, n_g = yg.shape
    n_l = yl.shape[1]
    n_steps = cfg.epochs * max((n_g + n_l) // cfg.batch, 1)
    w_g = n_g / (n_g + n_l)

    trainable = ("w1", "b1", "w2", "b2")

    def loss_fn(tp, key):
        kg, kl = jax.random.split(key)
        ig = jax.random.randint(kg, (cfg.batch,), 0, n_g)
        il = jax.random.randint(kl, (max(cfg.batch // 4, 1),), 0, n_l)
        pred_g = filters.apply_mlp_raw(tp, xg[ig])             # (F, bg)
        err_g = (pred_g - yg[:, ig]) ** 2 * (1 - val_mask_g[None, ig])

        def local_pred(tp_f, x_f):
            h = jax.nn.relu(x_f @ tp_f["w1"] + tp_f["b1"])
            return h @ tp_f["w2"] + tp_f["b2"]

        pred_l = jax.vmap(local_pred)(tp, xl[:, il])           # (F, bl)
        err_l = (pred_l - yl[:, il]) ** 2 * (1 - val_mask_l[None, il])
        return w_g * err_g.mean() + (1 - w_g) * err_l.mean()

    def val_loss(tp):
        pred_g = filters.apply_mlp_raw(tp, xg)
        err = ((pred_g - yg) ** 2 * val_mask_g[None, :]).sum(1)
        return err / jnp.maximum(val_mask_g.sum(), 1)          # (F,)

    tparams = {k: params[k] for k in trainable}
    vel = jax.tree.map(jnp.zeros_like, tparams)
    best = tparams
    best_val = jnp.full((F,), jnp.inf)

    eval_every = max(n_steps // 20, 1)

    def step(carry, step_key):
        tp, vel, best, best_val, i = carry
        # step-decayed lr: /10 at 60% and 85% of the budget (paper: divide
        # lr by 10 when validation plateaus; schedule form is deterministic)
        lr = cfg.lr * jnp.where(i < 0.6 * n_steps, 1.0,
                                jnp.where(i < 0.85 * n_steps, 0.1, 0.01))
        grads = jax.grad(loss_fn)(tp, step_key)
        tp, vel = _sgd_step(tp, grads, vel, lr, cfg.momentum)

        def do_eval(args):
            tp, best, best_val = args
            vl = val_loss(tp)                                  # (F,)
            improved = vl < best_val
            new_best = jax.tree.map(
                lambda b, c: jnp.where(
                    improved.reshape((F,) + (1,) * (c.ndim - 1)), c, b),
                best, tp)
            return new_best, jnp.minimum(vl, best_val)

        best, best_val = jax.lax.cond(
            i % eval_every == 0, do_eval, lambda a: (a[1], a[2]),
            (tp, best, best_val))
        return (tp, vel, best, best_val, i + 1), None

    keys = jax.random.split(jax.random.PRNGKey(cfg.seed), n_steps)
    (tp, _, best, best_val, _), _ = jax.lax.scan(
        step, (tparams, vel, best, best_val, 0), keys)
    return best, best_val


def train_filters(index: FlatIndex, data: TrainingData,
                  cfg: TrainConfig = TrainConfig(),
                  key: jax.Array | None = None
                  ) -> Tuple[Dict[str, jnp.ndarray], Dict[str, np.ndarray]]:
    """Train one MLP filter per selected leaf; returns (params, report)."""
    key = key if key is not None else jax.random.PRNGKey(cfg.seed)
    F = len(data.leaf_ids)
    m = index.length
    params = filters.init_mlp(key, F, m, cfg.hidden)

    yg = jnp.asarray(data.global_d_L[:, data.leaf_ids].T)      # (F, n_g)
    yl = jnp.asarray(data.local_d_L)                           # (F, n_l)
    # per-filter target standardization over the filter's own target mix
    y_all = jnp.concatenate([yg, yl], axis=1)
    y_mean = y_all.mean(axis=1)
    y_std = y_all.std(axis=1) + 1e-6
    params["y_mean"], params["y_std"] = y_mean, y_std
    ygz = (yg - y_mean[:, None]) / y_std[:, None]
    ylz = (yl - y_mean[:, None]) / y_std[:, None]

    n_g, n_l = yg.shape[1], yl.shape[1]
    rng = np.random.default_rng(cfg.seed)
    vg = np.zeros(n_g, np.float32)
    vg[rng.choice(n_g, int(n_g * cfg.val_fraction), replace=False)] = 1
    vl = np.zeros(n_l, np.float32)
    vl[rng.choice(n_l, max(int(n_l * cfg.val_fraction), 1), replace=False)] = 1

    with span("train.sgd", cat="build", n_filters=F, epochs=cfg.epochs):
        best, best_val = _train_filters_jit(
            params, jnp.asarray(data.global_queries), ygz,
            jnp.asarray(data.local_queries), ylz,
            jnp.asarray(vg), jnp.asarray(vl), cfg)
    params.update(best)
    report = {"val_rmse_z": np.asarray(jnp.sqrt(best_val))}
    return params, report
