"""Summarization-based lower bounds (jnp oracle forms).

The Pallas kernels in ``repro.kernels.{sax_lb,eapca_lb}`` implement the same
math with explicit VMEM tiling; these functions are the reference semantics
and the CPU execution path.

Both bounds satisfy the invariant  lb(q, leaf) ≤ min_{s ∈ leaf} d(q, s),
which the property tests (tests/test_bounds.py) verify with hypothesis.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import summaries
from .flat_index import FlatIndex


def eapca_lower_bound(query_stats: jnp.ndarray, boxes: jnp.ndarray,
                      seg_len: jnp.ndarray) -> jnp.ndarray:
    """DSTree EAPCA box lower bound.

    For each segment s of length w with node box [μ−, μ+]×[σ−, σ+] and query
    segment stats (μq, σq):

        Σ_{t∈s} (q_t − x_t)²  =  w·(μq − μx)² + Σ ((q̃_t) − (x̃_t))²
                              ≥  w·(μq − μx)² + (‖q̃‖ − ‖x̃‖)²
                              =  w·[(μq − μx)² + (σq − σx)²]

    and minimizing over the box replaces each Δ by its distance to the
    interval.  query_stats: (..., s, 2); boxes: (L, s, 4); seg_len: (s,).
    Returns (..., L) lower bounds (euclidean, not squared).
    """
    mu_q = query_stats[..., None, :, 0]          # (..., 1, s)
    sd_q = query_stats[..., None, :, 1]
    mu_lo, mu_hi = boxes[..., 0], boxes[..., 1]  # (L, s)
    sd_lo, sd_hi = boxes[..., 2], boxes[..., 3]
    d_mu = jnp.maximum(jnp.maximum(mu_lo - mu_q, mu_q - mu_hi), 0.0)
    d_sd = jnp.maximum(jnp.maximum(sd_lo - sd_q, sd_q - sd_hi), 0.0)
    lb2 = (seg_len * (d_mu * d_mu + d_sd * d_sd)).sum(axis=-1)
    return jnp.sqrt(lb2)


def sax_lower_bound(query_paa: jnp.ndarray, edges: jnp.ndarray,
                    length: int) -> jnp.ndarray:
    """iSAX lower bound from precomputed symbol boxes.

    query_paa: (..., l); edges: (L, l, 2) [lower, upper] breakpoint edges.
    MINDIST(q, word)² = (m/l) Σ_d box_dist(q_d, [lo_d, hi_d])².
    Returns (..., L).
    """
    q = query_paa[..., None, :]                  # (..., 1, l)
    lo, hi = edges[..., 0], edges[..., 1]        # (L, l)
    d = jnp.maximum(jnp.maximum(lo - q, q - hi), 0.0)
    # ±inf edges at the extremes produce d=0 there; inf*0 guards:
    d = jnp.where(jnp.isfinite(d), d, 0.0)
    wl = edges.shape[-2]
    lb2 = (length / wl) * (d * d).sum(axis=-1)
    return jnp.sqrt(lb2)


def lower_bounds(index: FlatIndex, queries: jnp.ndarray) -> jnp.ndarray:
    """All-leaves lower bounds for a batch of queries → (Q, L)."""
    queries = jnp.atleast_2d(queries)
    if index.kind == "dstree":
        boxes = jnp.asarray(index.payload["eapca_box"])
        seg_len = jnp.asarray(index.payload["seg_len"]).astype(jnp.float32)
        qstats = summaries.segment_stats(queries, boxes.shape[1])
        return eapca_lower_bound(qstats, boxes, seg_len)
    elif index.kind == "isax":
        edges = jnp.asarray(index.payload["sax_edges"])
        qpaa = summaries.paa(queries, edges.shape[1])
        return sax_lower_bound(qpaa, edges, index.length)
    raise ValueError(index.kind)
