"""Leaf-node selection (paper §4.2).

The general formalization is a 0/1 knapsack (Eq. 1): item = filter for leaf
i, value = expected search-time reduction b_i (Eq. 2), weight = filter memory
footprint, capacity = accelerator memory budget.  Under the paper's
uniform-probability assumption (p_lb, p_F equal across leaves) it collapses
to the greedy rule of Alg. 3: take leaves larger than th = a·t_F/t_S,
largest first, until memory runs out.

Both solvers are implemented; tests verify the greedy solution is optimal
for the simplified (uniform-weight, size-monotone-value) instance.
"""
from __future__ import annotations

import numpy as np


def size_threshold(t_filter: float, t_series: float, a: float = 2.0) -> float:
    """th = a · t_F / t_S  (Eq. 4).  a = 1/p_F; the paper uses a = 2."""
    return a * t_filter / max(t_series, 1e-30)


def expected_benefit(leaf_sizes: np.ndarray, p_lb: np.ndarray | float,
                     p_f: np.ndarray | float, t_series: float,
                     t_filter: float) -> np.ndarray:
    """b_i = (1 − p_lb)·(p_F·t_S·|N_i| − t_F)  (Eq. 2)."""
    leaf_sizes = np.asarray(leaf_sizes, np.float64)
    return (1.0 - np.asarray(p_lb)) * (
        np.asarray(p_f) * t_series * leaf_sizes - t_filter
    )


def greedy_select(leaf_sizes: np.ndarray, threshold: float,
                  max_filters: int | None = None) -> np.ndarray:
    """Alg. 3: leaves with |N_i| > th, largest first, until the budget.

    Returns the selected leaf ids (sorted by decreasing size).
    """
    leaf_sizes = np.asarray(leaf_sizes)
    order = np.argsort(-leaf_sizes, kind="stable")
    eligible = order[leaf_sizes[order] > threshold]
    if max_filters is not None:
        eligible = eligible[:max_filters]
    return eligible


def knapsack_select(values: np.ndarray, weights: np.ndarray,
                    capacity: int) -> np.ndarray:
    """Exact 0/1 knapsack DP (Eq. 1) over integer weights.

    O(n·capacity); used for the general heterogeneous-filter case and as the
    test oracle for the greedy rule.  Returns selected indices.
    """
    values = np.asarray(values, np.float64)
    weights = np.asarray(weights, np.int64)
    n = len(values)
    # items with non-positive value can never help (weights are positive)
    usable = np.where(values > 0)[0]
    best = np.zeros(capacity + 1)
    choice = np.zeros((len(usable), capacity + 1), bool)
    for row, i in enumerate(usable):
        w, v = int(weights[i]), values[i]
        if w > capacity:
            continue
        cand = best[: capacity + 1 - w] + v
        take = cand > best[w:]
        best[w:] = np.where(take, cand, best[w:])
        choice[row, w:] = take
    # backtrack
    picked = []
    c = capacity
    for row in range(len(usable) - 1, -1, -1):
        if choice[row, c]:
            picked.append(usable[row])
            c -= int(weights[usable[row]])
    return np.asarray(sorted(picked), np.int64)


def select_leaves(
    leaf_sizes: np.ndarray,
    *,
    t_filter: float,
    t_series: float,
    a: float = 2.0,
    filter_bytes: int,
    memory_budget_bytes: int,
) -> np.ndarray:
    """End-to-end Alg. 3: threshold + memory cap → selected leaf ids."""
    th = size_threshold(t_filter, t_series, a)
    max_filters = int(memory_budget_bytes // max(filter_bytes, 1))
    return greedy_select(leaf_sizes, th, max_filters)
