"""Comparison approaches from the paper's evaluation (§5.1).

All baselines (and LeaFi itself) are *simulated* from precollected
(d_lb, d_L) matrices plus a visiting order, exactly as the paper measures
them: the searched-leaf count is the hardware-agnostic search-time surrogate
(paper Fig. 1a, footnote 1).  The simulators share one core loop so that the
comparison is apples-to-apples.

* exact        — summarization-LB pruning only (the backbone index).
* ε-search     — prune when d_lb > d_bsf/(1+ε)  [16].
* δε-search    — ε-search + early stop once bsf ≤ the δ-quantile estimate of
                 the NN distance distribution  [16].
* ProS         — early stop when a learned model, fed best-so-far features at
                 checkpoints, predicts the NN has been found  [14, 22].
* LT (FLT)     — learned early-termination: predict the stop position from
                 bsf-trajectory features, expanded by a tuned multiplier [33].
* LR           — optimal leaf reordering: the NN's leaf is visited first [26].
* LeaFi        — the paper's learned-filter cascade.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np


@dataclasses.dataclass
class SimResult:
    searched: np.ndarray          # (Q,) leaves scanned
    bsf: np.ndarray               # (Q,) final answer distance
    recall: np.ndarray            # (Q,) 0/1 recall-at-1
    n_leaves: int

    @property
    def pruning_ratio(self):
        return 1.0 - self.searched / self.n_leaves

    def summary(self) -> Dict[str, float]:
        return {
            "recall": float(self.recall.mean()),
            "searched": float(self.searched.mean()),
            "pruning_ratio": float(self.pruning_ratio.mean()),
        }


def _finish(searched, bsf, d_L):
    d_nn = d_L.min(axis=1)
    recall = (bsf <= d_nn * (1 + 1e-5) + 1e-6).astype(np.float32)
    return SimResult(searched=searched, bsf=bsf, recall=recall,
                     n_leaves=d_L.shape[1])


def _core_sim(d_lb: np.ndarray, d_L: np.ndarray,
              order: np.ndarray,
              lb_scale: float = 1.0,
              d_F: Optional[np.ndarray] = None,
              stop_rule: Optional[Callable] = None) -> SimResult:
    """Shared sequential simulator.

    stop_rule(qi, step, bsf, searched) → True terminates query qi's search.
    """
    Q, L = d_lb.shape
    searched = np.zeros(Q, np.int64)
    bsf = np.full(Q, np.inf, np.float32)
    for qi in range(Q):
        for step in range(L):
            leaf = order[qi, step]
            if stop_rule is not None and stop_rule(qi, step, bsf[qi],
                                                   searched[qi]):
                break
            if d_lb[qi, leaf] * lb_scale > bsf[qi]:
                continue
            if d_F is not None and d_F[qi, leaf] > bsf[qi]:
                continue
            searched[qi] += 1
            if d_L[qi, leaf] < bsf[qi]:
                bsf[qi] = d_L[qi, leaf]
    return _finish(searched, bsf, d_L)


def _lb_order(d_lb):
    return np.argsort(d_lb, axis=1)


# ---------------------------------------------------------------------------


def exact_search(d_lb, d_L) -> SimResult:
    return _core_sim(d_lb, d_L, _lb_order(d_lb))


def leafi_search(d_lb, d_L, d_F) -> SimResult:
    return _core_sim(d_lb, d_L, _lb_order(d_lb), d_F=d_F)


def epsilon_search(d_lb, d_L, epsilon: float) -> SimResult:
    return _core_sim(d_lb, d_L, _lb_order(d_lb), lb_scale=1.0 + epsilon)


def tune_epsilon(d_lb_val, d_L_val, target: float = 0.99,
                 grid=np.linspace(1, 7, 13)) -> float:
    """Grid-search the max ε with ≥ target recall on the validation set."""
    best = 0.0
    for eps in grid:
        if epsilon_search(d_lb_val, d_L_val, float(eps)).recall.mean() >= target:
            best = float(eps)
    return best if best > 0 else 1.0


def delta_epsilon_search(d_lb, d_L, nn_quantile: float) -> SimResult:
    """Stop once bsf ≤ the δ-quantile estimate of the NN distance."""

    def stop(qi, step, bsf, searched):
        return bsf <= nn_quantile

    return _core_sim(d_lb, d_L, _lb_order(d_lb), stop_rule=stop)


def tune_delta(d_lb_val, d_L_val, target: float = 0.99,
               deltas=(0.9, 0.95, 0.99, 0.999)) -> float:
    """Pick the smallest δ with ≥ target recall (paper tunes on validation).

    The stop threshold is the (1−δ)-quantile of validation NN distances: a
    high δ ⇒ low threshold ⇒ conservative stopping.
    """
    d_nn = d_L_val.min(axis=1)
    chosen = None
    for delta in sorted(deltas):
        thr = float(np.quantile(d_nn, 1 - delta))
        if delta_epsilon_search(d_lb_val, d_L_val, thr).recall.mean() >= target:
            chosen = thr
            break
    if chosen is None:
        chosen = float(np.quantile(d_nn, 1 - 0.999))
    return chosen


# -- ProS: logistic model over bsf checkpoints ------------------------------


def _pros_features(d_lb, d_L, order, checkpoints):
    """bsf value after visiting `c` leaves, for each checkpoint c."""
    Q, L = d_lb.shape
    feats = np.zeros((Q, len(checkpoints)), np.float32)
    for qi in range(Q):
        bsf = np.inf
        visited = 0
        ci = 0
        for step in range(L):
            leaf = order[qi, step]
            if d_lb[qi, leaf] <= bsf:
                bsf = min(bsf, d_L[qi, leaf])
                visited += 1
            while ci < len(checkpoints) and visited >= checkpoints[ci]:
                feats[qi, ci] = bsf
                ci += 1
            if ci == len(checkpoints):
                break
        while ci < len(checkpoints):
            feats[qi, ci] = bsf
            ci += 1
    return feats


@dataclasses.dataclass
class ProsModel:
    checkpoints: tuple
    w: np.ndarray
    b: np.ndarray


def train_pros(d_lb_val, d_L_val, checkpoints=(16, 64, 256, 512, 1024, 2048),
               steps: int = 500, lr: float = 0.5) -> ProsModel:
    """Per-checkpoint logistic models: P(NN already found | bsf trajectory)."""
    L = d_lb_val.shape[1]
    checkpoints = tuple(c for c in checkpoints if c < L) or (max(L // 4, 1),)
    order = _lb_order(d_lb_val)
    feats = _pros_features(d_lb_val, d_L_val, order, checkpoints)
    d_nn = d_L_val.min(axis=1)
    # label: has the NN been found by checkpoint c?
    y = (feats <= d_nn[:, None] * (1 + 1e-5) + 1e-6).astype(np.float32)
    x = np.log1p(feats)
    w = np.zeros(len(checkpoints))
    b = np.zeros(len(checkpoints))
    for _ in range(steps):
        z = x * w + b
        p = 1 / (1 + np.exp(-z))
        g = p - y
        w -= lr * (g * x).mean(axis=0)
        b -= lr * g.mean(axis=0)
    return ProsModel(checkpoints, w, b)


def pros_search(d_lb, d_L, model: ProsModel, threshold: float = 0.5
                ) -> SimResult:
    def stop(qi, step, bsf, searched):
        for ci, c in enumerate(model.checkpoints):
            if searched == c:
                z = np.log1p(bsf) * model.w[ci] + model.b[ci]
                return 1 / (1 + np.exp(-z)) > threshold
        return False

    return _core_sim(d_lb, d_L, _lb_order(d_lb), stop_rule=stop)


# -- LT / FLT: predicted stop position × multiplier -------------------------


@dataclasses.dataclass
class LTModel:
    w: np.ndarray
    b: float
    multiplier: float
    checkpoints: tuple


def train_lt(d_lb_val, d_L_val, target: float = 0.99,
             checkpoints=(1, 2, 4, 8, 16)) -> LTModel:
    """Ridge-regress the position at which the NN is found from early-bsf
    features; tune the multiplier for ≥ target recall (paper adj. (4))."""
    L = d_lb_val.shape[1]
    checkpoints = tuple(c for c in checkpoints if c < L) or (1,)
    order = _lb_order(d_lb_val)
    feats = np.log1p(_pros_features(d_lb_val, d_L_val, order, checkpoints))
    # position (in searched-leaf count) at which NN is found:
    Q = d_lb_val.shape[0]
    pos = np.zeros(Q, np.float32)
    d_nn = d_L_val.min(axis=1)
    for qi in range(Q):
        bsf = np.inf
        searched = 0
        for step in range(L):
            leaf = order[qi, step]
            if d_lb_val[qi, leaf] <= bsf:
                searched += 1
                bsf = min(bsf, d_L_val[qi, leaf])
                if bsf <= d_nn[qi] * (1 + 1e-5) + 1e-6:
                    break
        pos[qi] = searched
    X = np.concatenate([feats, np.ones((Q, 1), np.float32)], axis=1)
    beta = np.linalg.lstsq(X.T @ X + 1e-3 * np.eye(X.shape[1]),
                           X.T @ np.log1p(pos), rcond=None)[0]
    w, b = beta[:-1], float(beta[-1])

    best_mult = 20.0
    for mult in range(1, 21):
        model = LTModel(w, b, float(mult), checkpoints)
        if lt_search(d_lb_val, d_L_val, model).recall.mean() >= target:
            best_mult = float(mult)
            break
    return LTModel(w, b, best_mult, checkpoints)


def lt_search(d_lb, d_L, model: LTModel) -> SimResult:
    order = _lb_order(d_lb)
    feats = np.log1p(_pros_features(d_lb, d_L, order, model.checkpoints))
    stop_at = model.multiplier * np.expm1(feats @ model.w + model.b)
    stop_at = np.maximum(stop_at, max(model.checkpoints))

    def stop(qi, step, bsf, searched):
        return searched >= stop_at[qi]

    return _core_sim(d_lb, d_L, order, stop_rule=stop)


# -- LR: optimal reordering --------------------------------------------------


def lr_optimal_search(d_lb, d_L) -> SimResult:
    """Visit the NN's leaf first (the best any reordering can do), then the
    rest in LB order — exact search semantics afterwards."""
    Q, L = d_lb.shape
    base = _lb_order(d_lb)
    nn_leaf = d_L.argmin(axis=1)
    order = np.zeros_like(base)
    for qi in range(Q):
        rest = base[qi][base[qi] != nn_leaf[qi]]
        order[qi, 0] = nn_leaf[qi]
        order[qi, 1:] = rest
    return _core_sim(d_lb, d_L, order)
