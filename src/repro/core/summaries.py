"""Series summarizations: PAA, SAX and EAPCA.

These are the building blocks of the two backbone indexes the paper
instantiates LeaFi on: iSAX/MESSI (SAX words over PAA) and DSTree (EAPCA
per-segment mean/std).  Everything here is shape-polymorphic jnp so it can be
reused inside jitted search, vmapped over queries, or called with numpy
arrays at index-build time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# PAA
# ---------------------------------------------------------------------------


def paa(series: jnp.ndarray, n_segments: int) -> jnp.ndarray:
    """Piecewise aggregate approximation.

    series: (..., m) with m divisible by ``n_segments`` (we pad otherwise).
    returns (..., n_segments) segment means.
    """
    m = series.shape[-1]
    seg = -(-m // n_segments)  # ceil
    pad = seg * n_segments - m
    if pad:
        # repeat-edge padding keeps segment means unbiased enough; the exact
        # choice only shifts the summarization, never the LB validity (the
        # bound is computed against identically-summarized data).
        series = jnp.concatenate(
            [series, jnp.repeat(series[..., -1:], pad, axis=-1)], axis=-1
        )
    shaped = series.reshape(*series.shape[:-1], n_segments, seg)
    return shaped.mean(axis=-1)


def segment_stats(series: jnp.ndarray, n_segments: int) -> jnp.ndarray:
    """EAPCA statistics: per-segment (mean, std).

    series: (..., m) → (..., n_segments, 2).
    """
    m = series.shape[-1]
    seg = -(-m // n_segments)
    pad = seg * n_segments - m
    if pad:
        series = jnp.concatenate(
            [series, jnp.repeat(series[..., -1:], pad, axis=-1)], axis=-1
        )
    shaped = series.reshape(*series.shape[:-1], n_segments, seg)
    mean = shaped.mean(axis=-1)
    std = shaped.std(axis=-1)
    return jnp.stack([mean, std], axis=-1)


# ---------------------------------------------------------------------------
# SAX
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def sax_breakpoints(card_bits: int) -> np.ndarray:
    """Gaussian equi-probable breakpoints for cardinality 2**card_bits.

    Returns the (2**card_bits - 1,) interior breakpoints.  Computed with the
    inverse normal CDF (jax.scipy.special.ndtri) as in the iSAX papers.
    """
    card = 1 << card_bits
    qs = np.arange(1, card) / card
    return np.asarray(jax.scipy.special.ndtri(jnp.asarray(qs)))


def sax_from_paa(paa_vals: jnp.ndarray, card_bits: int) -> jnp.ndarray:
    """Quantize PAA values into SAX symbols ∈ [0, 2**card_bits)."""
    bps = jnp.asarray(sax_breakpoints(card_bits))
    return jnp.searchsorted(bps, paa_vals).astype(jnp.int32)


def sax_symbol_edges(symbols: np.ndarray, card_bits: np.ndarray,
                     max_bits: int = 8) -> np.ndarray:
    """Convert SAX symbols at per-dim cardinalities into value-space boxes.

    symbols:   (..., l) int — symbol index *at its own cardinality*.
    card_bits: (..., l) int — bits of cardinality per dim (0 ⇒ whole axis).
    returns (..., l, 2) float32 [lower, upper] edges, ±inf at the extremes.

    Precomputing edges at build time turns query-time SAX lower bounds into a
    pure box-distance computation (no breakpoint table lookups inside the
    kernel), which is the form the ``sax_lb`` Pallas kernel consumes.
    """
    symbols = np.asarray(symbols)
    card_bits = np.broadcast_to(np.asarray(card_bits), symbols.shape)
    lo = np.full(symbols.shape, -np.inf, np.float32)
    hi = np.full(symbols.shape, np.inf, np.float32)
    for b in np.unique(card_bits):
        if b == 0:
            continue
        bps = sax_breakpoints(int(b))
        mask = card_bits == b
        sym = symbols[mask]
        lo_b = np.where(sym > 0, bps[np.clip(sym - 1, 0, None)], -np.inf)
        hi_b = np.where(sym < (1 << int(b)) - 1,
                        bps[np.clip(sym, None, len(bps) - 1)], np.inf)
        lo[mask] = lo_b
        hi[mask] = hi_b
    return np.stack([lo, hi], axis=-1).astype(np.float32)


# ---------------------------------------------------------------------------
# Node aggregates
# ---------------------------------------------------------------------------


def eapca_node_box(stats: np.ndarray) -> np.ndarray:
    """Aggregate per-series EAPCA stats of one node into its summarization.

    stats: (n_node, s, 2) → (s, 4) [mean_min, mean_max, std_min, std_max].
    """
    stats = np.asarray(stats)
    return np.stack(
        [
            stats[..., 0].min(axis=0),
            stats[..., 0].max(axis=0),
            stats[..., 1].min(axis=0),
            stats[..., 1].max(axis=0),
        ],
        axis=-1,
    ).astype(np.float32)


def znormalize(series: np.ndarray, eps: float = 1e-8) -> np.ndarray:
    """Per-series z-normalization (standard in the data-series literature)."""
    series = np.asarray(series, np.float32)
    mu = series.mean(axis=-1, keepdims=True)
    sd = series.std(axis=-1, keepdims=True)
    return (series - mu) / (sd + eps)
