"""Learned filter models.

The paper instantiates filters as per-leaf MLPs (one hidden layer, width =
series length), and ablates CNN (2 conv layers) and RNN (2 LSTM blocks)
variants (Table 1).  All variants here are *stacked*: parameters carry a
leading filter axis F so that every filter trains and infers in one fused
vmap/kernel call instead of the paper's per-leaf GPU invocations.

Predictions are de-standardized with per-filter target statistics: filters
regress z-scored node-wise NN distances, which keeps one SGD recipe stable
across datasets whose distance scales differ by orders of magnitude.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..kernels.common import use_interpret as _use_interpret
from ..kernels.filter_mlp import ops as mlp_ops
from ..kernels.filter_mlp import ref as mlp_ref

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# MLP (the paper's default filter)
# ---------------------------------------------------------------------------


def init_mlp(key: jax.Array, n_filters: int, length: int,
             hidden: int | None = None, dtype=jnp.float32) -> Params:
    hidden = hidden or length
    k1, k2 = jax.random.split(key)
    scale1 = jnp.sqrt(2.0 / length)
    scale2 = jnp.sqrt(2.0 / hidden)
    return {
        "w1": (jax.random.normal(k1, (n_filters, length, hidden)) * scale1).astype(dtype),
        "b1": jnp.zeros((n_filters, hidden), dtype),
        "w2": (jax.random.normal(k2, (n_filters, hidden)) * scale2).astype(dtype),
        "b2": jnp.zeros((n_filters,), dtype),
        # per-filter target standardization (fitted at training time)
        "y_mean": jnp.zeros((n_filters,), jnp.float32),
        "y_std": jnp.ones((n_filters,), jnp.float32),
    }


def apply_mlp(params: Params, queries: jnp.ndarray,
              use_kernel: bool = True) -> jnp.ndarray:
    """(Q, m) → (F, Q) de-standardized distance predictions."""
    return apply_mlp_offset(params, queries, None, use_kernel)


def apply_mlp_offset(params: Params, queries: jnp.ndarray,
                     offsets: jnp.ndarray | None = None,
                     use_kernel: bool = True) -> jnp.ndarray:
    """(Q, m) → (F, Q) de-standardized predictions minus per-filter offsets.

    On TPU (use_kernel=True) this is ONE launch of the fused filter-block
    megakernel — matmuls, de-standardization and conformal offsets together,
    with in-kernel dequant for bf16/int8 weight payloads.  Off-TPU (or with
    use_kernel=False) the unfused composition runs: the same jitted/oracle
    ``filter_predict`` as before plus eager epilogue ops, which keeps results
    bitwise-identical to the pre-fusion search path.
    """
    w1, w2 = params["w1"], params["w2"]
    s1, s2 = params.get("w1_scale"), params.get("w2_scale")
    if use_kernel and not _use_interpret():
        return mlp_ops.filter_predict_fused(
            w1, params["b1"], w2, params["b2"],
            params["y_mean"], params["y_std"], queries, offsets, s1, s2)
    w1f, w2f = mlp_ref.dequantize_weights(w1, w2, s1, s2)
    fn = mlp_ops.filter_predict if use_kernel else mlp_ref.filter_predict
    z = fn(w1f, params["b1"], w2f, params["b2"], queries)
    out = z * params["y_std"][:, None] + params["y_mean"][:, None]
    if offsets is not None:
        out = out - offsets[:, None]
    return out


def apply_mlp_raw(params: Params, queries: jnp.ndarray) -> jnp.ndarray:
    """Raw (standardized-space) predictions — used inside the training loss."""
    return mlp_ref.filter_predict(
        params["w1"], params["b1"], params["w2"], params["b2"], queries
    )


def quantize_mlp(params: Params, weight_dtype: str = "float32") -> Params:
    """Compress a trained MLP stack's weight matrices to bf16 or int8.

    int8 uses ``optim.compress``'s symmetric max-abs/127 scheme at per-filter
    granularity — one scale per filter per layer, stored as ``w1_scale`` /
    ``w2_scale`` (F,) float32 — which is exactly what the fused kernel folds
    back in after its matmuls.  Biases and the y_mean/y_std stats stay
    float32: they are O(h) per filter and their precision anchors the
    de-standardized output scale.  float32 is a (de-quantizing) no-op so the
    build path can call this unconditionally.
    """
    out = {k: v for k, v in params.items()
           if k not in ("w1_scale", "w2_scale")}
    w1 = params["w1"]
    w2 = params["w2"]
    if w1.dtype != jnp.float32:
        w1, w2 = mlp_ref.dequantize_weights(
            w1, w2, params.get("w1_scale"), params.get("w2_scale"))
    if weight_dtype == "float32":
        out["w1"], out["w2"] = w1, w2
    elif weight_dtype == "bfloat16":
        out["w1"] = w1.astype(jnp.bfloat16)
        out["w2"] = w2.astype(jnp.bfloat16)
    elif weight_dtype == "int8":
        s1 = jnp.abs(w1).max(axis=(1, 2)) / 127.0 + 1e-12
        s2 = jnp.abs(w2).max(axis=1) / 127.0 + 1e-12
        out["w1"] = jnp.clip(
            jnp.round(w1 / s1[:, None, None]), -127, 127).astype(jnp.int8)
        out["w2"] = jnp.clip(
            jnp.round(w2 / s2[:, None]), -127, 127).astype(jnp.int8)
        out["w1_scale"] = s1.astype(jnp.float32)
        out["w2_scale"] = s2.astype(jnp.float32)
    else:
        raise ValueError(f"unknown weight_dtype {weight_dtype!r}")
    return out


def mlp_weight_dtype(params: Params) -> str:
    """Weight payload dtype of an MLP stack ("float32"/"bfloat16"/"int8")."""
    return {jnp.dtype(jnp.float32): "float32",
            jnp.dtype(jnp.bfloat16): "bfloat16",
            jnp.dtype(jnp.int8): "int8"}[jnp.dtype(params["w1"].dtype)]


#: weight-matrix bytes per element by payload dtype (biases/stats stay f32)
WEIGHT_BYTES_PER_EL = {"float32": 4, "bfloat16": 2, "int8": 1}


def mlp_param_bytes(length: int, hidden: int | None = None,
                    weight_dtype: str = "float32") -> int:
    """Per-filter memory footprint w (the knapsack item weight, Eq. 1).

    Counted from the literal parameter set: w1 (length·hidden) and w2
    (hidden) at the payload dtype's width; b1 (hidden), b2 (1) and the
    y_mean/y_std stats (2) always float32; int8 adds two float32 per-filter
    scales.  (The pre-quantization formula lumped everything at 4 B/el and
    skipped the stats.)
    """
    hidden = hidden or length
    wb = WEIGHT_BYTES_PER_EL[weight_dtype]
    n_weight = length * hidden + hidden            # w1 + w2
    n_f32 = hidden + 1 + 2                         # b1 + b2 + y_mean/y_std
    n_scales = 2 if weight_dtype == "int8" else 0
    return wb * n_weight + 4 * (n_f32 + n_scales)


# ---------------------------------------------------------------------------
# CNN / RNN variants (Table 1 & Fig. 12 ablation)
# ---------------------------------------------------------------------------


def init_cnn(key: jax.Array, n_filters: int, length: int,
             channels: int | None = None, ksize: int = 3) -> Params:
    channels = channels or length
    k1, k2, k3 = jax.random.split(key, 3)
    s1 = jnp.sqrt(2.0 / ksize)
    s2 = jnp.sqrt(2.0 / (ksize * channels))
    return {
        "c1": jax.random.normal(k1, (n_filters, ksize, 1, channels)) * s1,
        "c2": jax.random.normal(k2, (n_filters, ksize, channels, channels)) * s2,
        "w": jax.random.normal(k3, (n_filters, channels)) * jnp.sqrt(1.0 / channels),
        "b": jnp.zeros((n_filters,)),
        "y_mean": jnp.zeros((n_filters,), jnp.float32),
        "y_std": jnp.ones((n_filters,), jnp.float32),
    }


def apply_cnn(params: Params, queries: jnp.ndarray,
              use_kernel: bool = True) -> jnp.ndarray:
    """2-conv-layer filter (paper Table 1): (Q, m) → (F, Q).

    ``use_kernel`` is accepted (and ignored — no Pallas path yet) so the
    ``APPLY`` dispatch table has one call signature across filter types.
    """
    del use_kernel
    x = queries[:, :, None]                                   # (Q, m, 1)

    def one(c1, c2, w, b):
        h = jax.lax.conv_general_dilated(
            x, c1, (1,), "SAME", dimension_numbers=("NWC", "WIO", "NWC"))
        h = jax.nn.relu(h)
        h = jax.lax.conv_general_dilated(
            h, c2, (1,), "SAME", dimension_numbers=("NWC", "WIO", "NWC"))
        h = jax.nn.relu(h).mean(axis=1)                       # (Q, C) GAP
        return h @ w + b

    z = jax.vmap(one)(params["c1"], params["c2"], params["w"], params["b"])
    return z * params["y_std"][:, None] + params["y_mean"][:, None]


def init_rnn(key: jax.Array, n_filters: int, length: int,
             hidden: int = 64) -> Params:
    ks = jax.random.split(key, 5)
    s = jnp.sqrt(1.0 / hidden)
    return {
        "wi1": jax.random.normal(ks[0], (n_filters, 1, 4 * hidden)) * s,
        "wh1": jax.random.normal(ks[1], (n_filters, hidden, 4 * hidden)) * s,
        "wi2": jax.random.normal(ks[2], (n_filters, hidden, 4 * hidden)) * s,
        "wh2": jax.random.normal(ks[3], (n_filters, hidden, 4 * hidden)) * s,
        "w": jax.random.normal(ks[4], (n_filters, hidden)) * s,
        "b": jnp.zeros((n_filters,)),
        "y_mean": jnp.zeros((n_filters,), jnp.float32),
        "y_std": jnp.ones((n_filters,), jnp.float32),
    }


def _lstm_layer(x, wi, wh):
    """x (Q, T, d_in) → (Q, T, h) minimal LSTM (no biases)."""
    h_dim = wh.shape[0]
    Q = x.shape[0]

    def step(carry, xt):
        h, c = carry
        gates = xt @ wi + h @ wh
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((Q, h_dim)), jnp.zeros((Q, h_dim)))
    _, hs = jax.lax.scan(step, init, jnp.swapaxes(x, 0, 1))
    return jnp.swapaxes(hs, 0, 1)


def apply_rnn(params: Params, queries: jnp.ndarray,
              use_kernel: bool = True) -> jnp.ndarray:
    """2-LSTM-block filter (paper Table 1): (Q, m) → (F, Q).

    ``use_kernel`` is accepted and ignored, as in :func:`apply_cnn`.
    """
    del use_kernel
    x = queries[:, :, None]

    def one(wi1, wh1, wi2, wh2, w, b):
        h = _lstm_layer(x, wi1, wh1)
        h = _lstm_layer(h, wi2, wh2)
        return h[:, -1, :] @ w + b

    z = jax.vmap(one)(params["wi1"], params["wh1"], params["wi2"],
                      params["wh2"], params["w"], params["b"])
    return z * params["y_std"][:, None] + params["y_mean"][:, None]


APPLY = {"mlp": apply_mlp, "cnn": apply_cnn, "rnn": apply_rnn}
INIT = {"mlp": init_mlp, "cnn": init_cnn, "rnn": init_rnn}
