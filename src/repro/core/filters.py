"""Learned filter models.

The paper instantiates filters as per-leaf MLPs (one hidden layer, width =
series length), and ablates CNN (2 conv layers) and RNN (2 LSTM blocks)
variants (Table 1).  All variants here are *stacked*: parameters carry a
leading filter axis F so that every filter trains and infers in one fused
vmap/kernel call instead of the paper's per-leaf GPU invocations.

Predictions are de-standardized with per-filter target statistics: filters
regress z-scored node-wise NN distances, which keeps one SGD recipe stable
across datasets whose distance scales differ by orders of magnitude.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..kernels.filter_mlp import ops as mlp_ops
from ..kernels.filter_mlp import ref as mlp_ref

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# MLP (the paper's default filter)
# ---------------------------------------------------------------------------


def init_mlp(key: jax.Array, n_filters: int, length: int,
             hidden: int | None = None, dtype=jnp.float32) -> Params:
    hidden = hidden or length
    k1, k2 = jax.random.split(key)
    scale1 = jnp.sqrt(2.0 / length)
    scale2 = jnp.sqrt(2.0 / hidden)
    return {
        "w1": (jax.random.normal(k1, (n_filters, length, hidden)) * scale1).astype(dtype),
        "b1": jnp.zeros((n_filters, hidden), dtype),
        "w2": (jax.random.normal(k2, (n_filters, hidden)) * scale2).astype(dtype),
        "b2": jnp.zeros((n_filters,), dtype),
        # per-filter target standardization (fitted at training time)
        "y_mean": jnp.zeros((n_filters,), jnp.float32),
        "y_std": jnp.ones((n_filters,), jnp.float32),
    }


def apply_mlp(params: Params, queries: jnp.ndarray,
              use_kernel: bool = True) -> jnp.ndarray:
    """(Q, m) → (F, Q) de-standardized distance predictions."""
    fn = mlp_ops.filter_predict if use_kernel else mlp_ref.filter_predict
    z = fn(params["w1"], params["b1"], params["w2"], params["b2"], queries)
    return z * params["y_std"][:, None] + params["y_mean"][:, None]


def apply_mlp_raw(params: Params, queries: jnp.ndarray) -> jnp.ndarray:
    """Raw (standardized-space) predictions — used inside the training loss."""
    return mlp_ref.filter_predict(
        params["w1"], params["b1"], params["w2"], params["b2"], queries
    )


def mlp_param_bytes(length: int, hidden: int | None = None,
                    bytes_per_el: int = 4) -> int:
    """Per-filter memory footprint w (the knapsack item weight, Eq. 1)."""
    hidden = hidden or length
    return bytes_per_el * (length * hidden + hidden + hidden + 1)


# ---------------------------------------------------------------------------
# CNN / RNN variants (Table 1 & Fig. 12 ablation)
# ---------------------------------------------------------------------------


def init_cnn(key: jax.Array, n_filters: int, length: int,
             channels: int | None = None, ksize: int = 3) -> Params:
    channels = channels or length
    k1, k2, k3 = jax.random.split(key, 3)
    s1 = jnp.sqrt(2.0 / ksize)
    s2 = jnp.sqrt(2.0 / (ksize * channels))
    return {
        "c1": jax.random.normal(k1, (n_filters, ksize, 1, channels)) * s1,
        "c2": jax.random.normal(k2, (n_filters, ksize, channels, channels)) * s2,
        "w": jax.random.normal(k3, (n_filters, channels)) * jnp.sqrt(1.0 / channels),
        "b": jnp.zeros((n_filters,)),
        "y_mean": jnp.zeros((n_filters,), jnp.float32),
        "y_std": jnp.ones((n_filters,), jnp.float32),
    }


def apply_cnn(params: Params, queries: jnp.ndarray) -> jnp.ndarray:
    """2-conv-layer filter (paper Table 1): (Q, m) → (F, Q)."""
    x = queries[:, :, None]                                   # (Q, m, 1)

    def one(c1, c2, w, b):
        h = jax.lax.conv_general_dilated(
            x, c1, (1,), "SAME", dimension_numbers=("NWC", "WIO", "NWC"))
        h = jax.nn.relu(h)
        h = jax.lax.conv_general_dilated(
            h, c2, (1,), "SAME", dimension_numbers=("NWC", "WIO", "NWC"))
        h = jax.nn.relu(h).mean(axis=1)                       # (Q, C) GAP
        return h @ w + b

    z = jax.vmap(one)(params["c1"], params["c2"], params["w"], params["b"])
    return z * params["y_std"][:, None] + params["y_mean"][:, None]


def init_rnn(key: jax.Array, n_filters: int, length: int,
             hidden: int = 64) -> Params:
    ks = jax.random.split(key, 5)
    s = jnp.sqrt(1.0 / hidden)
    return {
        "wi1": jax.random.normal(ks[0], (n_filters, 1, 4 * hidden)) * s,
        "wh1": jax.random.normal(ks[1], (n_filters, hidden, 4 * hidden)) * s,
        "wi2": jax.random.normal(ks[2], (n_filters, hidden, 4 * hidden)) * s,
        "wh2": jax.random.normal(ks[3], (n_filters, hidden, 4 * hidden)) * s,
        "w": jax.random.normal(ks[4], (n_filters, hidden)) * s,
        "b": jnp.zeros((n_filters,)),
        "y_mean": jnp.zeros((n_filters,), jnp.float32),
        "y_std": jnp.ones((n_filters,), jnp.float32),
    }


def _lstm_layer(x, wi, wh):
    """x (Q, T, d_in) → (Q, T, h) minimal LSTM (no biases)."""
    h_dim = wh.shape[0]
    Q = x.shape[0]

    def step(carry, xt):
        h, c = carry
        gates = xt @ wi + h @ wh
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((Q, h_dim)), jnp.zeros((Q, h_dim)))
    _, hs = jax.lax.scan(step, init, jnp.swapaxes(x, 0, 1))
    return jnp.swapaxes(hs, 0, 1)


def apply_rnn(params: Params, queries: jnp.ndarray) -> jnp.ndarray:
    """2-LSTM-block filter (paper Table 1): (Q, m) → (F, Q)."""
    x = queries[:, :, None]

    def one(wi1, wh1, wi2, wh2, w, b):
        h = _lstm_layer(x, wi1, wh1)
        h = _lstm_layer(h, wi2, wh2)
        return h[:, -1, :] @ w + b

    z = jax.vmap(one)(params["wi1"], params["wh1"], params["wi2"],
                      params["wh2"], params["w"], params["b"])
    return z * params["y_std"][:, None] + params["y_mean"][:, None]


APPLY = {"mlp": apply_mlp, "cnn": apply_cnn, "rnn": apply_rnn}
INIT = {"mlp": init_mlp, "cnn": init_cnn, "rnn": init_rnn}
