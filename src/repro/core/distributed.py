"""Distributed LeaFi search: leaf-partitioned, shard_map-based.

The paper's system is single-node (CPU threads + one GPU).  At pod scale the
index must shard: leaves are partitioned across devices along the ``model``
mesh axis (round-robin by size for balance, as in DPiSAX/Odyssey), queries
batch along ``data``.  Search is a two-phase exchange:

  Phase 1 — every shard scans its single most-promising local leaf (smallest
            local lower bound); one psum-min establishes a global best-so-far.
            This is the collective analogue of the paper's "a tight bsf early
            makes the cascade effective".
  Phase 2 — every shard runs the LeaFi pruning cascade (summarization LB,
            then calibrated filter prediction) against the *global* bsf over
            its local leaves, scanning only survivors; a final psum-min picks
            the answer (and an argmin exchange resolves the owner).

Collectives used: two ``psum(min)`` on (Q,)-vectors and one final pair —
bytes exchanged are O(Q), independent of collection size, so the exchange
is *communication*-scalable.  The per-shard body is also *compute*-scalable:
by default it runs ``engine.compact_bsf_cascade``, the fixed-width survivor
compaction (static shapes, legal inside shard_map), so each shard pays
distance compute only for a bounded survivor buffer instead of every local
leaf — the distributed analogue of the single-device engine's
prune→compact→candidates plan, with the masked scan kept as the
bitwise-parity fallback (``strategy="scan"``) and as the exact overflow
path.  This file is also what ``launch/dryrun.py --arch leafi-serve``
lowers on the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import conformal, engine
from ..obs import audit as obs_audit
from ..obs.audit import FilterAudit
from ..obs.trace import CascadeTrace
from .build import LeaFiIndex

_INF = jnp.float32(jnp.inf)


@dataclasses.dataclass
class ShardedLeaFi:
    """Device-partitioned LeaFi index (leaf-sharded along ``model``)."""
    # per-shard stacked arrays; leading axis = n_shards
    series: jnp.ndarray           # (S, rows_max, m)
    leaf_start: jnp.ndarray       # (S, P)
    leaf_size: jnp.ndarray        # (S, P)   0 ⇒ padding leaf
    lb_lo: jnp.ndarray            # (S, P, d)  box lower edges (pre-scaled)
    lb_hi: jnp.ndarray            # (S, P, d)
    # stacked filter params (+inf-free; has_filter masks unfiltered leaves)
    w1: jnp.ndarray               # (S, P, m, h)
    b1: jnp.ndarray               # (S, P, h)
    w2: jnp.ndarray               # (S, P, h)
    b2: jnp.ndarray               # (S, P)
    y_mean: jnp.ndarray           # (S, P)
    y_std: jnp.ndarray            # (S, P)
    offsets: jnp.ndarray          # (S, P) conformal offsets at build target
    has_filter: jnp.ndarray       # (S, P) bool
    max_leaf: int
    length: int
    kind: str
    qscale: np.ndarray            # (d,) query coordinate pre-scale (box LB)
    # local slot → global leaf id (padding slots carry n_leaves); lets the
    # per-query-offset shard body gather each query's (Q, L) conformal
    # offset row onto this shard's (Q, P) local slots.
    leaf_global: Optional[jnp.ndarray] = None   # (S, P) int32

    def query_coords(self, queries: jnp.ndarray) -> jnp.ndarray:
        """Map raw queries to pre-scaled box coordinates (see kernels.box_lb)."""
        from . import summaries
        if self.kind == "dstree":
            s = self.lb_lo.shape[-1] // 2
            st = summaries.segment_stats(queries, s)
            q = jnp.concatenate([st[..., 0], st[..., 1]], -1)
        else:
            wl = self.lb_lo.shape[-1]
            q = summaries.paa(queries, wl)
        return q * jnp.asarray(self.qscale)


def make_search_mesh(n_data: int, n_model: int,
                     data_axis: str = "data", model_axis: str = "model"):
    """A (data, model) mesh for the distributed search, across jax versions.

    jax >= 0.5 wants explicit axis types on ``make_mesh``; older versions
    don't have ``AxisType``.  One shared guard instead of three copies
    (tests, benchmarks, serving).
    """
    shape, names = (n_data, n_model), (data_axis, model_axis)
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, names,
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    return jax.make_mesh(shape, names)


def shard_leafi(lfi: LeaFiIndex, n_shards: int,
                quality_target: Optional[float] = 0.99) -> ShardedLeaFi:
    """Partition a built LeaFiIndex into n_shards leaf groups."""
    from . import summaries
    index = lfi.index
    L = index.n_leaves
    sizes = np.asarray(index.leaf_size)
    order = np.argsort(-sizes, kind="stable")
    # round-robin by size → balanced rows per shard
    shard_of = np.empty(L, np.int64)
    for rank, leaf in enumerate(order):
        shard_of[leaf] = rank % n_shards
    P_max = max((shard_of == s).sum() for s in range(n_shards))

    # pre-scaled box edges (shared form for both backbones; cf. kernels.box_lb)
    if index.kind == "dstree":
        box = np.asarray(index.payload["eapca_box"])
        w = np.sqrt(np.asarray(index.payload["seg_len"], np.float32))
        lo = np.concatenate([box[..., 0] * w, box[..., 2] * w], -1)
        hi = np.concatenate([box[..., 1] * w, box[..., 3] * w], -1)
        qscale = np.concatenate([w, w])
    else:
        edges = np.asarray(index.payload["sax_edges"])
        wl = edges.shape[1]
        scale = np.sqrt(index.length / wl)
        lo, hi = edges[..., 0] * scale, edges[..., 1] * scale
        qscale = np.full(wl, scale, np.float32)

    m = index.length
    h = lfi.filter_params["w1"].shape[-1] if lfi.filter_params else m
    F_of_leaf = {int(lf): i for i, lf in enumerate(lfi.leaf_ids)}
    offsets_global = conformal.scatter_offsets(
        lfi.tuner, lfi.leaf_ids, L, quality_target) \
        if lfi.tuner is not None else np.zeros(L, np.float32)

    series_np = np.asarray(index.series)
    starts_np = np.asarray(index.leaf_start)
    rows_max = 0
    per_shard_rows = []
    for s in range(n_shards):
        leaves = np.where(shard_of == s)[0]
        per_shard_rows.append(int(sizes[leaves].sum()))
    rows_max = max(per_shard_rows) + index.max_leaf_size  # slack for slicing

    S = n_shards
    out = ShardedLeaFi(
        series=np.zeros((S, rows_max, m), np.float32),
        leaf_start=np.zeros((S, P_max), np.int32),
        leaf_size=np.zeros((S, P_max), np.int32),
        lb_lo=np.full((S, P_max, lo.shape[-1]), -np.inf, np.float32),
        lb_hi=np.full((S, P_max, lo.shape[-1]), np.inf, np.float32),
        w1=np.zeros((S, P_max, m, h), np.float32),
        b1=np.zeros((S, P_max, h), np.float32),
        w2=np.zeros((S, P_max, h), np.float32),
        b2=np.zeros((S, P_max), np.float32),
        y_mean=np.zeros((S, P_max), np.float32),
        y_std=np.ones((S, P_max), np.float32),
        offsets=np.zeros((S, P_max), np.float32),
        has_filter=np.zeros((S, P_max), bool),
        max_leaf=index.max_leaf_size, length=m, kind=index.kind,
        qscale=qscale.astype(np.float32),
        leaf_global=np.full((S, P_max), L, np.int32),
    )
    for s in range(n_shards):
        leaves = np.where(shard_of == s)[0]
        cursor = 0
        for j, lf in enumerate(leaves):
            out.leaf_global[s, j] = int(lf)
            sz = int(sizes[lf])
            st = int(starts_np[lf])
            out.series[s, cursor:cursor + sz] = series_np[st:st + sz]
            out.leaf_start[s, j] = cursor
            out.leaf_size[s, j] = sz
            out.lb_lo[s, j] = lo[lf]
            out.lb_hi[s, j] = hi[lf]
            if lfi.filter_params is not None and int(lf) in F_of_leaf:
                fi = F_of_leaf[int(lf)]
                out.w1[s, j] = np.asarray(lfi.filter_params["w1"][fi])
                out.b1[s, j] = np.asarray(lfi.filter_params["b1"][fi])
                out.w2[s, j] = np.asarray(lfi.filter_params["w2"][fi])
                out.b2[s, j] = float(lfi.filter_params["b2"][fi])
                out.y_mean[s, j] = float(lfi.filter_params["y_mean"][fi])
                out.y_std[s, j] = float(lfi.filter_params["y_std"][fi])
                out.offsets[s, j] = offsets_global[lf]
                out.has_filter[s, j] = True
            cursor += sz
    # jnp-ify
    for f in dataclasses.fields(out):
        v = getattr(out, f.name)
        if isinstance(v, np.ndarray) and f.name != "qscale":
            setattr(out, f.name, jnp.asarray(v))
    return out


# ---------------------------------------------------------------------------
# the shard-local search body (runs under shard_map; axis name = 'model')
# ---------------------------------------------------------------------------


def _shard_pruning_inputs(lo, hi, w1, b1, w2, b2, y_mean, y_std, offsets,
                          has_filter, leaf_size, queries, qcoords):
    """Per-shard (Q, P) pruning inputs: box lower bounds + filter preds.

    Padding leaves (size 0) carry (−inf, +inf) box edges, which the
    ``isfinite`` squash collapses to a lower bound of 0 — low enough to win
    the phase-1 probe's argmin and silently waste the bsf seed on an empty
    leaf.  Their lb is therefore forced to +inf here, so they sort last,
    never survive, and never probe.

    ``offsets`` is either one (P,) per-slot conformal offset vector shared
    by every query (the baked single-quality-target form) or (Q, P)
    per-query rows — the serving runtime's mixed-target micro-batch form,
    gathered from global (Q, L) offset rows via ``ShardedLeaFi.leaf_global``.
    """
    d = jnp.maximum(jnp.maximum(lo[None] - qcoords[:, None],
                                qcoords[:, None] - hi[None]), 0.0)
    d = jnp.where(jnp.isfinite(d), d, 0.0)
    lb = jnp.sqrt((d * d).sum(-1))
    lb = jnp.where(leaf_size[None, :] > 0, lb, _INF)

    # local filter predictions: einsum over stacked per-leaf MLPs
    hdd = jax.nn.relu(jnp.einsum("qm,pmh->pqh", queries, w1)
                      + b1[:, None, :])
    pred = jnp.einsum("pqh,ph->pq", hdd, w2) + b2[:, None]
    pred = pred * y_std[:, None] + y_mean[:, None]
    off = offsets if offsets.ndim == 2 else offsets[None, :]   # (1|Q, P)
    d_F = jnp.where(has_filter[None, :], pred.T - off, -_INF)
    return lb, d_F                                       # both (Q, P)


def _local_search(sh_series, sh_start, sh_size, lb, d_F, queries, max_leaf,
                  bsf0, strategy="compact", max_survivors=None,
                  dist_impl=None, bsf_ub=None, trace=False, audit=False):
    """Cascade over this shard's leaves given a starting global bsf.

    Routes through the common engine's shard_map-safe forms:
    ``"compact"`` (default) is the fixed-width survivor compaction — static
    shapes, distance compute only for the survivor buffer, masked-scan
    fallback for overflow queries; ``"scan"`` is the original masked scan,
    kept as the parity fallback (bitwise-identical under the ``direct``
    distance impl).

    ``bsf_ub`` is the serving runtime's prune-only warm-start bound: it
    tightens prune decisions but never enters ``bsf0`` or the returned bsf
    (both must stay witnessed distances — a pmin over unwitnessed bounds
    would corrupt the global answer).

    ``trace=True`` (Python-level, shard_map-legal) appends a per-query
    shard-local :class:`~repro.obs.trace.CascadeTrace` (``probed`` stays 0
    here — the shard body accounts for its phase-1 probe itself).

    ``audit=True`` additionally appends the shard-local per-(query, leaf)
    :class:`~repro.obs.audit.AuditParts` planes — the return is
    ``(bsf, n_s[, trace][, parts])`` in flag order.
    """
    if strategy == "scan":
        if audit:
            bsf, n_s, (n_box, n_seed, n_pf,
                       n_rows), parts = engine.masked_bsf_scan(
                sh_series, sh_start, sh_size, lb, d_F, queries, max_leaf,
                bsf0, bsf_ub=bsf_ub, audit=True)
            if trace:
                zq = jnp.zeros_like(n_s)
                return (bsf, n_s,
                        CascadeTrace(n_box, n_seed, n_pf, zq, n_s, zq,
                                     n_rows), parts)
            return bsf, n_s, parts
        if trace:
            bsf, n_s, (n_box, n_seed, n_pf, n_rows) = engine.masked_bsf_scan(
                sh_series, sh_start, sh_size, lb, d_F, queries, max_leaf,
                bsf0, bsf_ub=bsf_ub, trace=True)
            zq = jnp.zeros_like(n_s)
            return bsf, n_s, CascadeTrace(n_box, n_seed, n_pf, zq, n_s, zq,
                                          n_rows)
        return engine.masked_bsf_scan(sh_series, sh_start, sh_size, lb, d_F,
                                      queries, max_leaf, bsf0, bsf_ub=bsf_ub)
    if strategy == "compact":
        return engine.compact_bsf_cascade(
            sh_series, sh_start, sh_size, lb, d_F, queries, max_leaf, bsf0,
            max_survivors=max_survivors, dist_impl=dist_impl, bsf_ub=bsf_ub,
            trace=trace, audit=audit)
    raise ValueError(f"unknown distributed shard strategy {strategy!r}")


def search_input_specs(n_shards: int, leaves_per_shard: int,
                       rows_per_shard: int, m: int, h: int, n_queries: int,
                       coord_dim: int):
    """ShapeDtypeStructs for dry-running the distributed search at scale.

    Sized like the paper's production setting by default from the caller
    (25M series × len 256, ~16k leaves, MESSI-style 10k leaf capacity).
    Order matches the jitted search signature (idx arrays…, queries, qcoords).
    """
    import jax as _jax
    sd = _jax.ShapeDtypeStruct
    S, P = n_shards, leaves_per_shard
    f32, i32 = jnp.float32, jnp.int32
    return (
        sd((S, rows_per_shard, m), f32),     # series
        sd((S, P), i32), sd((S, P), i32),    # leaf_start, leaf_size
        sd((S, P, coord_dim), f32), sd((S, P, coord_dim), f32),  # lb lo/hi
        sd((S, P, m, h), f32), sd((S, P, h), f32),               # w1, b1
        sd((S, P, h), f32), sd((S, P), f32),                     # w2, b2
        sd((S, P), f32), sd((S, P), f32),                        # y stats
        sd((S, P), f32), sd((S, P), jnp.bool_),                  # offsets, mask
        sd((n_queries, m), f32),                                 # queries
        sd((n_queries, coord_dim), f32),                         # qcoords
    )


def _make_shard_body(max_leaf: int, model_axis: str,
                     strategy: str = "compact",
                     max_survivors: Optional[int] = None,
                     dist_impl: Optional[str] = None,
                     per_query_offsets: bool = False,
                     trace: bool = False,
                     audit: bool = False,
                     data_axes=("data",)):
    """The per-shard two-phase search body (runs under shard_map).

    Phase 1 probes each query's most promising local leaf (engine probe) and
    establishes a global bsf via pmin; phase 2 runs the engine's bsf cascade
    against it — the fixed-width survivor compaction by default, the masked
    scan with ``strategy="scan"`` — and reduces the answer.  Shared by
    ``build_search_fn`` (dry-run lowering) and ``make_distributed_search``.

    With ``per_query_offsets=True`` the body takes three extra inputs —
    ``leaf_global`` (the (S, P) local-slot → global-leaf map), per-query
    (Q, L) conformal offset rows, and a (Q,) prune-only ``bsf_ub`` warm
    bound — so one compiled program serves micro-batches mixing quality
    targets, with the per-leaf offsets gathered onto each shard's local
    slots.  Padding slots gather row L (every (Q, L+…) gather is clamped to
    the last real leaf) but ``has_filter=False`` already disables them.

    With ``trace=True`` the body returns a third output — the per-query
    :class:`~repro.obs.trace.CascadeTrace` psum'd over the model axis:
    pruned-leaf attribution and survivors aggregate across shards,
    ``probed`` counts one phase-1 probe per shard, and ``distances``
    includes each shard's probe rows.  Global accounting over S shards of P
    leaf slots: ``Σ pruned = S·P − survivors`` (the probe leaves are also
    cascade-accounted per shard) with ``probed == S``.

    With ``audit=True`` the body returns one more output — the per-leaf
    :class:`~repro.obs.audit.FilterAudit` for this shard's ``P`` local
    slots, psum'd over ``data_axes`` (queries shard there, so the data-axis
    collective restores full-batch per-leaf counts; ``resid_min`` pmins).
    The model axis is deliberately *not* reduced: each model shard owns
    distinct leaves, so its ``(1, P)`` rows concatenate into the global
    ``(S, P)`` shard-slot layout the host folds with
    :func:`repro.obs.audit.scatter_global` + ``ShardedLeaFi.leaf_global``.
    The phase-1 probe pass is not audited (see ``repro.obs.audit``).
    """

    def _traced_reduce(bsf, n_s, tr, lb, size):
        # each shard's phase-1 probe pays one leaf pass: argmin over the
        # padding-masked lb (same choice probe_best_leaf makes).
        probe_rows = size[lb.argmin(axis=1)].astype(jnp.int32)
        tr = tr._replace(probed=tr.probed + 1,
                         distances=tr.distances + probe_rows)
        tr = jax.tree.map(lambda x: jax.lax.psum(x, model_axis), tr)
        return jax.tree.map(lambda x: x[None], tr)

    def _audit_reduce(parts, d_F, size):
        fa = obs_audit.reduce_parts(parts, d_F, size)
        if data_axes:
            fa = FilterAudit(*(
                jax.lax.pmin(x, data_axes) if name == "resid_min"
                else jax.lax.psum(x, data_axes)
                for name, x in zip(FilterAudit._fields, fa)))
        return jax.tree.map(lambda x: x[None], fa)

    def _phase2(series, start, size, lb, d_F, queries, bsf0, bsf_ub=None):
        out = _local_search(series, start, size, lb, d_F, queries,
                            max_leaf, bsf0, strategy=strategy,
                            max_survivors=max_survivors,
                            dist_impl=dist_impl, bsf_ub=bsf_ub,
                            trace=trace, audit=audit)
        bsf, n_s = out[0], out[1]
        rest = list(out[2:])
        nn = jax.lax.pmin(bsf, model_axis)                      # collective 2
        total_searched = jax.lax.psum(n_s, model_axis)
        rets = (nn[None], total_searched[None])
        if trace:
            rets = rets + (_traced_reduce(bsf, n_s, rest.pop(0), lb, size),)
        if audit:
            rets = rets + (_audit_reduce(rest.pop(0), d_F, size),)
        return rets

    def search_fn(series, start, size, lo, hi, w1, b1, w2, b2, y_mean,
                  y_std, offsets, has_filter, queries, qcoords):
        # inside shard_map: leading shard axis is size 1 → squeeze
        series, start, size = series[0], start[0], size[0]
        lo, hi = lo[0], hi[0]
        w1, b1, w2, b2 = w1[0], b1[0], w2[0], b2[0]
        y_mean, y_std = y_mean[0], y_std[0]
        offsets, has_filter = offsets[0], has_filter[0]

        # (Q, P) lower bounds (padding leaves forced to +inf) + filter preds
        lb, d_F = _shard_pruning_inputs(lo, hi, w1, b1, w2, b2, y_mean,
                                        y_std, offsets, has_filter, size,
                                        queries, qcoords)

        # phase 1: scan the single most promising local leaf
        bsf_local = engine.probe_best_leaf(series, start, size, lb,
                                           queries, max_leaf)
        bsf0 = jax.lax.pmin(bsf_local, model_axis)              # collective 1

        # phase 2: full cascade against the global bsf
        return _phase2(series, start, size, lb, d_F, queries, bsf0)

    def search_fn_pq(series, start, size, lo, hi, w1, b1, w2, b2, y_mean,
                     y_std, offsets, has_filter, leaf_global, queries,
                     qcoords, qoffsets, bsf_ub):
        # inside shard_map: leading shard axis is size 1 → squeeze
        series, start, size = series[0], start[0], size[0]
        lo, hi = lo[0], hi[0]
        w1, b1, w2, b2 = w1[0], b1[0], w2[0], b2[0]
        y_mean, y_std = y_mean[0], y_std[0]
        has_filter, leaf_global = has_filter[0], leaf_global[0]
        del offsets   # baked single-target offsets unused in per-query mode

        # gather each query's (Q, L) offset row onto local slots → (Q, P);
        # padding slots (leaf_global == L) clamp to the last real row and
        # are masked off by has_filter anyway.
        L = qoffsets.shape[1]
        slot = jnp.minimum(leaf_global, L - 1)
        qoff = qoffsets[:, slot]                                # (Q, P)

        lb, d_F = _shard_pruning_inputs(lo, hi, w1, b1, w2, b2, y_mean,
                                        y_std, qoff, has_filter, size,
                                        queries, qcoords)

        bsf_local = engine.probe_best_leaf(series, start, size, lb,
                                           queries, max_leaf)
        bsf0 = jax.lax.pmin(bsf_local, model_axis)              # collective 1

        # warm bound tightens prune decisions only — never folded into bsf0
        # (the pmin'd bsf must stay a witnessed distance on every shard).
        return _phase2(series, start, size, lb, d_F, queries, bsf0,
                       bsf_ub=bsf_ub)

    return search_fn_pq if per_query_offsets else search_fn


def build_search_fn(mesh: Mesh, max_leaf: int, data_axes=("data",),
                    model_axis: str = "model", strategy: str = "compact",
                    max_survivors: Optional[int] = None,
                    dist_impl: Optional[str] = None):
    """The shard_map'ped search as a jit-able function of explicit args."""
    search_fn = _make_shard_body(max_leaf, model_axis, strategy,
                                 max_survivors, dist_impl)
    spec_idx = P(model_axis)
    spec_q = P(data_axes)
    smapped = shard_map(
        search_fn, mesh=mesh,
        in_specs=(spec_idx,) * 13 + (spec_q, spec_q),
        out_specs=(P(model_axis, *data_axes), P(model_axis, *data_axes)),
        check_rep=False)
    from jax.sharding import NamedSharding
    in_sh = tuple(NamedSharding(mesh, spec_idx) for _ in range(13)) \
        + (NamedSharding(mesh, spec_q), NamedSharding(mesh, spec_q))
    return jax.jit(smapped, in_shardings=in_sh), spec_idx, spec_q


def make_distributed_search(mesh: Mesh, sharded: ShardedLeaFi,
                            data_axes=("data",), model_axis: str = "model",
                            strategy: str = "compact",
                            max_survivors: Optional[int] = None,
                            dist_impl: Optional[str] = None,
                            per_query_offsets: bool = False,
                            donate: bool = False,
                            trace: bool = False,
                            audit: bool = False):
    """Build the jitted multi-chip search step over ``mesh``.

    Returns fn(queries (Q, m)) → (nn_dist (Q,), total_searched (Q,)), where
    ``total_searched`` is the ``psum``-reduced **total** searched-leaf count
    across all shards per query (replicated per shard by the collective; the
    caller reads one replica) — i.e. it sums to the same accounting as
    running the per-shard cascades on a single device.  Queries shard over
    ``data_axes``; the index over ``model_axis``.

    strategy: ``"compact"`` (default) = fixed-width survivor compaction per
    shard (``engine.compact_bsf_cascade``; ``max_survivors`` caps the static
    buffer, ``dist_impl`` picks the candidate distance algebra);
    ``"scan"`` = the masked-scan parity fallback.

    per_query_offsets: the serving-runtime signature —
    fn(queries (Q, m), qoffsets (Q, L), bsf_ub (Q,)) — where each query
    carries its own per-leaf conformal offset row (mixed quality targets in
    one compiled program; gathered per shard via ``sharded.leaf_global``)
    and ``bsf_ub`` is the prune-only warm-start bound (+inf rows = no-op).

    donate: donate the per-call query/offset/bound buffers to the compiled
    program (per-query mode only) so steady-state pipelined serving re-uses
    their device allocations instead of growing the arena.  Skipped on CPU,
    where XLA ignores donation and warns.

    trace: the returned fn additionally yields a per-query
    :class:`~repro.obs.trace.CascadeTrace` psum'd across shards (see
    ``_make_shard_body``); the nn/searched outputs are bitwise those of
    the untraced program.

    audit: the returned fn additionally yields a per-leaf
    :class:`~repro.obs.audit.FilterAudit` in the ``(S, P)`` shard-slot
    layout — psum'd over the data axes inside the body, concatenated
    across the model axis (each model shard owns distinct leaves).  Fold
    to global ``(L,)`` leaf order with
    ``obs.audit.scatter_global(fa, sharded.leaf_global, n_leaves)``.
    Output order is ``(nn, searched[, trace][, audit])`` in flag order.
    """
    max_leaf = sharded.max_leaf
    spec_idx = P(model_axis)
    spec_q = P(data_axes)
    search_fn = _make_shard_body(max_leaf, model_axis, strategy,
                                 max_survivors, dist_impl,
                                 per_query_offsets=per_query_offsets,
                                 trace=trace, audit=audit,
                                 data_axes=data_axes)
    spec_out = P(model_axis, *data_axes)
    out_specs = (spec_out, spec_out)
    if trace:
        out_specs = out_specs + (CascadeTrace(*((spec_out,) * 7)),)
    if audit:
        # audit fields shard over the model axis only: the leading (1,)
        # per-shard row concatenates into the (S, P) layout, and the
        # data-axis psum already replicated the values across data shards.
        out_specs = out_specs + (FilterAudit(
            *((P(model_axis),) * len(FilterAudit._fields))),)

    idx_args = (sharded.series, sharded.leaf_start, sharded.leaf_size,
                sharded.lb_lo, sharded.lb_hi, sharded.w1, sharded.b1,
                sharded.w2, sharded.b2, sharded.y_mean, sharded.y_std,
                sharded.offsets, sharded.has_filter)

    if per_query_offsets:
        if sharded.leaf_global is None:
            raise ValueError("per_query_offsets needs ShardedLeaFi.leaf_global"
                             " (re-shard with the current shard_leafi)")
        idx_pq = idx_args + (sharded.leaf_global,)
        # qoffsets shard over queries like the batch; the L axis replicates
        smapped = shard_map(
            search_fn, mesh=mesh,
            in_specs=(spec_idx,) * len(idx_pq)
            + (spec_q, spec_q, P(data_axes, None), spec_q),
            out_specs=out_specs,
            check_rep=False,
        )

        def run_pq(queries, qoffsets, bsf_ub):
            sh = ShardedLeaFi(*idx_args, max_leaf=max_leaf,
                              length=sharded.length, kind=sharded.kind,
                              qscale=sharded.qscale)
            qcoords = sh.query_coords(queries)
            out = smapped(*idx_pq, queries, qcoords, qoffsets, bsf_ub)
            rets = (out[0][0], out[1][0])
            rest = list(out[2:])
            if trace:
                rets = rets + (jax.tree.map(lambda x: x[0], rest.pop(0)),)
            if audit:
                rets = rets + (rest.pop(0),)    # (S, P) layout — no unwrap
            return rets

        donate_kw = {}
        if donate and jax.default_backend() != "cpu":
            donate_kw["donate_argnums"] = (0, 1, 2)
        run = jax.jit(run_pq, **donate_kw)
        return run, idx_pq, spec_idx, spec_q

    smapped = shard_map(
        search_fn, mesh=mesh,
        in_specs=(spec_idx,) * len(idx_args) + (spec_q, spec_q),
        out_specs=out_specs,
        check_rep=False,
    )

    @jax.jit
    def run(queries):
        sh = ShardedLeaFi(*idx_args, max_leaf=max_leaf,
                          length=sharded.length, kind=sharded.kind,
                          qscale=sharded.qscale)
        qcoords = sh.query_coords(queries)
        out = smapped(*idx_args, queries, qcoords)
        # collectives replicate both outputs across the model axis; row 0 is
        # the global nn and the all-shard total searched count per query
        rets = (out[0][0], out[1][0])
        rest = list(out[2:])
        if trace:
            rets = rets + (jax.tree.map(lambda x: x[0], rest.pop(0)),)
        if audit:
            rets = rets + (rest.pop(0),)        # (S, P) layout — no unwrap
        return rets

    return run, idx_args, spec_idx, spec_q
