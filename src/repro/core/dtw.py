"""Dynamic Time Warping support (paper §3: "LeaFi works for any distance
measure supported by the backbone index, including Euclidean and DTW").

Provides the pieces a DTW-backed LeaFi index needs:
* ``dtw`` — Sakoe-Chiba-banded DTW distance (jnp, jit/vmap-able; the band is
  the standard constraint in the data-series literature).
* ``keogh_envelope`` / ``lb_keogh`` — the LB_Keogh lower bound: the same
  role the EAPCA/SAX bounds play for Euclidean search.  The cascade of
  Alg. 2 is metric-agnostic — only d_lb and the leaf scan change; the
  learned filters regress node-wise DTW distances with zero code change
  (they never look at the metric, only at (query, target) pairs).
* ``lb_keogh_leaves`` — the node-level form over per-leaf aggregated
  envelopes; structurally a box distance, so the box_lb kernel serves it.

The invariants tests/test_dtw.py verifies with hypothesis:
    lb_keogh(q, x, r) ≤ dtw(q, x, r) ≤ euclidean(q, x).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_INF = jnp.float32(1e30)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw(q: jnp.ndarray, x: jnp.ndarray, band: int = 8) -> jnp.ndarray:
    """Banded DTW distance between two equal-length series (m,).

    Full-width masked DP, lax.scan over rows; the in-row (left-neighbor)
    dependency is its own small scan.  O(m²) cells, fine at series lengths
    (≤ a few hundred) — a banded-frame kernel is the TPU follow-up.
    """
    m = q.shape[0]
    j = jnp.arange(m)
    cost = (q[:, None] - x[None, :]) ** 2
    in_band = jnp.abs(j[:, None] - j[None, :]) <= band
    cost = jnp.where(in_band, cost, _INF)

    def row_step(carry, crow):
        prev, lead = carry
        diag = jnp.concatenate([lead[None], prev[:-1]])    # D[i-1, j-1]
        base = jnp.minimum(prev, diag)                     # min(up, diag)

        def left_scan(run, cb):
            c, b = cb
            v = jnp.minimum(c + jnp.minimum(b, run), _INF)
            return v, v

        _, row = jax.lax.scan(left_scan, _INF, (crow, base))
        return (row, _INF), None

    # virtual row -1: D[-1,-1] = 0 (the `lead`), everything else +inf
    init = (jnp.full((m,), _INF), jnp.float32(0.0))
    (last, _), _ = jax.lax.scan(row_step, init, cost)
    return jnp.sqrt(last[-1])


def keogh_envelope(q: jnp.ndarray, band: int = 8):
    """Lower/upper envelope of q under the band: U_i = max q[i−r..i+r]."""
    m = q.shape[0]
    idx = jnp.arange(m)[:, None] + jnp.arange(-band, band + 1)[None, :]
    window = q[jnp.clip(idx, 0, m - 1)]
    valid = (idx >= 0) & (idx < m)
    U = jnp.where(valid, window, -_INF).max(axis=1)
    L = jnp.where(valid, window, _INF).min(axis=1)
    return L, U


@functools.partial(jax.jit, static_argnames=("band",))
def lb_keogh(q: jnp.ndarray, x: jnp.ndarray, band: int = 8) -> jnp.ndarray:
    """LB_Keogh(q, x): distance from x to q's envelope — a DTW lower bound."""
    L, U = keogh_envelope(q, band)
    d = jnp.maximum(jnp.maximum(x - U, L - x), 0.0)
    return jnp.sqrt((d * d).sum())


def lb_keogh_leaves(query: jnp.ndarray, env_lo: jnp.ndarray,
                    env_hi: jnp.ndarray) -> jnp.ndarray:
    """Node-level LB_Keogh: envelopes aggregated per leaf (min L / max U of
    the leaf's series) → (L_leaves,) lower bounds for the Alg. 2 cascade.

    Note the direction flip vs the point-to-point form: at node level the
    *query* is compared against the leaf's envelope box, which is exactly
    the Euclidean box-bound shape — the box_lb kernel computes it.
    """
    d = jnp.maximum(jnp.maximum(env_lo - query[None, :],
                                query[None, :] - env_hi), 0.0)
    return jnp.sqrt((d * d).sum(-1))
