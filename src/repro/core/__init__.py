"""LeaFi core: learned filters for tree-based data-series indexes.

Public API:
    build_leafi(series, LeaFiConfig)  → LeaFiIndex  (paper Alg. 1)
    LeaFiIndex.search(queries, quality_target=0.99) (paper Alg. 2)
    LeaFiIndex.search_exact(queries)                 (filters disabled)
"""
from .build import LeaFiConfig, LeaFiIndex, build_leafi          # noqa: F401
from .engine import EngineResult, run_cascade                    # noqa: F401
from .flat_index import FlatIndex                                # noqa: F401
from .search import SearchResult, search_batched, search_early   # noqa: F401
from .tree import build_dstree, build_isax                       # noqa: F401
