"""LeaFi-enhanced search (paper Alg. 2), TPU-native forms.

Two execution styles over the same semantics:

* ``search_batched`` — throughput form.  Lower bounds and filter predictions
  for *all* leaves are computed up front (hoisting them out of the visit loop
  is exact — neither depends on d_bsf), then the bsf-ordered pruning cascade
  runs through :mod:`repro.core.engine`.  The default ``strategy="compact"``
  computes distances only for cascade survivors (prune → compact → batched
  MXU candidate pass), so wall-clock shrinks with the pruning ratio;
  ``strategy="scan"`` is the validated masked-``lax.scan`` fallback that
  computes every leaf.  Both report the paper's hardware-agnostic cost
  metric (searched-leaf count) exactly and return identical results —
  bitwise with the ``direct`` distance impl (the off-TPU default), to float
  tolerance with the TPU-default ``matmul`` impl (see the engine module).

* ``search_early`` — latency form for a single query: a while_loop that
  terminates at the first lower bound exceeding d_bsf (visiting in LB order
  makes every later leaf prunable too), with filter-pruned leaf scans
  genuinely skipped via lax.cond.  This is the direct analogue of the
  paper's CPU search loop and gives real wall-clock pruning savings
  on-device.

Setting ``quality_target=None`` (or use_filters=False) disables the filter
cascade: the search is then exact, reproducing the paper's guarantee that a
LeaFi-enhanced index can always answer exactly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import bounds as bounds_mod
from . import conformal, engine, filters
from .flat_index import FlatIndex

_INF = jnp.float32(jnp.inf)


@dataclasses.dataclass
class SearchResult:
    dists: np.ndarray            # (Q, k)
    ids: np.ndarray              # (Q, k) original series ids
    searched: np.ndarray         # (Q,) leaves actually scanned
    pruned_lb: np.ndarray        # (Q,) leaves pruned by summarization LB
    pruned_filter: np.ndarray    # (Q,) leaves pruned by learned filters
    n_leaves: int
    # leaves the engine paid distance compute for (== n_leaves on the scan
    # strategy; the phase-1 survivor superset on the compact strategy, the
    # bucket's survivor union under dist_impl="pairwise")
    computed: Optional[np.ndarray] = None
    # search_batched(trace=True): host-side dict of the engine's per-query
    # CascadeTrace fields (repro.obs.trace.to_numpy), else None
    trace: Optional[dict] = None
    # search_batched(audit=True): host-side dict of the engine's per-leaf
    # FilterAudit fields (repro.obs.audit.to_numpy), else None
    audit: Optional[dict] = None

    @property
    def pruning_ratio(self) -> np.ndarray:
        return 1.0 - self.searched / self.n_leaves


@dataclasses.dataclass
class PendingSearch:
    """A dispatched batched search whose device work may still be running.

    JAX arrays are futures: :func:`search_batched_async` returns as soon as
    the engine's programs are enqueued, holding device arrays here, and the
    host blocks only when :meth:`result` materializes them to numpy.  The
    serving runtime's pipelined loop dispatches batch N+1 while batch N's
    arrays are still cooking on device; :meth:`result` then harvests in
    dispatch order.  (The compact strategy's survivor bucketing syncs the
    host once per dispatch — the probe/mask prefix — so its overlap window
    is the candidate pass + replay; the scan strategy dispatches fully
    async.)
    """
    raw: engine.EngineResult
    order: np.ndarray
    n_series: int
    n_leaves: int

    def block_until_ready(self) -> "PendingSearch":
        jax.block_until_ready(self.raw.topk_d)
        return self

    def result(self) -> SearchResult:
        """Materialize to a :class:`SearchResult` (blocks on the device)."""
        from ..obs import audit as obs_audit
        from ..obs import trace as obs_trace
        r = self.raw
        ids_sorted = np.asarray(r.topk_i)
        valid = ids_sorted >= 0
        orig = np.where(valid, self.order[
            np.clip(ids_sorted, 0, self.n_series - 1)], -1)
        return SearchResult(
            dists=np.asarray(r.topk_d), ids=orig,
            searched=np.asarray(r.n_searched),
            pruned_lb=np.asarray(r.n_pruned_lb),
            pruned_filter=np.asarray(r.n_pruned_filter),
            n_leaves=self.n_leaves, computed=np.asarray(r.n_computed),
            trace=(None if r.trace is None else obs_trace.to_numpy(r.trace)),
            audit=(None if r.audit is None
                   else obs_audit.to_numpy(r.audit)))


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------


def predictions_for_all_leaves(index: FlatIndex, filter_params,
                               leaf_ids: np.ndarray,
                               queries: jnp.ndarray,
                               offsets: np.ndarray | None,
                               use_kernel: bool = True,
                               filter_type: str = "mlp") -> jnp.ndarray:
    """(Q, L) conformal-adjusted filter lower bounds; −inf ⇒ never prunes.

    The cascade prunes a leaf when ``d_F > bsf``, so −inf is the neutral
    element for leaves without a filter: the check can never fire.  Filtered
    leaves get their (offset-adjusted) predictions scattered onto their leaf
    slots.

    ``filter_type`` selects the backbone via :data:`filters.APPLY` (the
    CNN/RNN ablation variants of Table 1 are reachable from search, not just
    from the ablation benchmark).  The MLP path routes shared (F,) offsets
    into the fused megakernel's epilogue — one launch produces the
    offset-adjusted d_F block on TPU.

    ``offsets`` is either one (F,) per-filter vector shared by every query
    (the paper's form: one quality target per batch) or (Q, F) per-query
    rows — the serving runtime's heterogeneous micro-batch form, where each
    query carries its own quality target and hence its own conformal
    adjustment of the same filter predictions.  The per-query rows broadcast
    over the (F, Q) output, so they are applied outside the kernel.
    """
    L = index.n_leaves
    Q = queries.shape[0]
    if filter_params is None or len(leaf_ids) == 0:
        return jnp.full((Q, L), -_INF)
    off = None if offsets is None else jnp.asarray(offsets)
    if filter_type == "mlp" and (off is None or off.ndim == 1):
        preds = filters.apply_mlp_offset(
            filter_params, queries, off, use_kernel)                # (F, Q)
    else:
        preds = filters.APPLY[filter_type](
            filter_params, queries, use_kernel)                    # (F, Q)
        if off is not None:
            preds = preds - (off.T if off.ndim == 2 else off[:, None])
    full = jnp.full((L, Q), -_INF)
    full = full.at[jnp.asarray(leaf_ids)].set(preds)
    return full.T                                                   # (Q, L)


# ---------------------------------------------------------------------------
# batched form
# ---------------------------------------------------------------------------


def search_batched_async(
    index: FlatIndex,
    queries: np.ndarray,
    *,
    k: int = 1,
    filter_params=None,
    leaf_ids: np.ndarray | None = None,
    tuner: Optional[conformal.AutoTuner] = None,
    quality_target: float | np.ndarray | None = None,
    use_filters: bool = True,
    use_kernel: bool = True,
    filter_type: str = "mlp",
    strategy: str = "auto",
    dist_impl: Optional[str] = None,
    bsf_ub: np.ndarray | None = None,
    trace: bool = False,
    audit: bool = False,
) -> PendingSearch:
    """Dispatch a batched LeaFi search without blocking on the device.

    Same arguments and semantics as :func:`search_batched` (which is just
    ``search_batched_async(...).result()``), plus ``bsf_ub``: an optional
    (Q,) per-query prune-only upper bound on the true k-th NN distance
    (``engine.run_cascade``'s warm-start seed — tightens pruning, never
    changes the answer).  Returns a :class:`PendingSearch` holding device
    arrays; call ``.result()`` to materialize.

    ``trace=True`` threads the engine's :class:`repro.obs.CascadeTrace`
    through the cascade (per-query pruning attribution); the materialized
    ``SearchResult.trace`` is its numpy dict.  Results stay bitwise
    identical to ``trace=False``.

    ``audit=True`` threads the engine's per-leaf
    :class:`repro.obs.FilterAudit` (prune/kept counts by bound, work
    saved, prediction-residual health stats — see ``repro.obs.audit``);
    the materialized ``SearchResult.audit`` is its numpy dict.  Same
    zero-cost-when-off discipline as ``trace``.
    """
    queries = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
    d_lb = bounds_mod.lower_bounds(index, queries)                  # (Q, L)
    if quality_target is not None:
        nd = np.ndim(quality_target)
        if nd > 1:
            raise ValueError(
                "quality_target must be a scalar or a (Q,) per-query "
                f"array, got shape {np.shape(quality_target)}")
        if nd == 1 and np.shape(quality_target)[0] != queries.shape[0]:
            raise ValueError(
                f"per-query quality_target has {np.shape(quality_target)[0]} "
                f"entries for {queries.shape[0]} queries")
    offsets = None
    if use_filters and filter_params is not None and tuner is not None \
            and quality_target is not None:
        offsets = tuner.offsets(quality_target)     # (F,) or (Q, F)
    if use_filters and filter_params is not None:
        d_F = predictions_for_all_leaves(
            index, filter_params, leaf_ids, queries, offsets, use_kernel,
            filter_type)
    else:
        d_F = jnp.full(d_lb.shape, -_INF)

    res = engine.run_cascade(
        jnp.asarray(index.series), jnp.asarray(index.leaf_start),
        jnp.asarray(index.leaf_size), queries, d_lb, d_F,
        k=k, max_leaf=index.max_leaf_size, strategy=strategy,
        dist_impl=dist_impl, bsf_ub=bsf_ub, trace=trace, audit=audit)
    return PendingSearch(raw=res, order=np.asarray(index.order),
                         n_series=index.n_series, n_leaves=index.n_leaves)


def search_batched(
    index: FlatIndex,
    queries: np.ndarray,
    *,
    k: int = 1,
    filter_params=None,
    leaf_ids: np.ndarray | None = None,
    tuner: Optional[conformal.AutoTuner] = None,
    quality_target: float | np.ndarray | None = None,
    use_filters: bool = True,
    use_kernel: bool = True,
    filter_type: str = "mlp",
    strategy: str = "auto",
    dist_impl: Optional[str] = None,
    bsf_ub: np.ndarray | None = None,
    trace: bool = False,
    audit: bool = False,
) -> SearchResult:
    """Batched LeaFi search.  Exact when filters are disabled.

    ``strategy``/``dist_impl`` select the engine execution plan (see
    :mod:`repro.core.engine`): "compact" (the "auto" default) only computes
    distances for cascade survivors; "scan" is the masked fallback.

    ``quality_target`` is one target shared by the batch (the paper's form)
    or an array of Q per-query targets — the serving runtime's heterogeneous
    micro-batch form, lowered to (Q, F) per-query conformal offset rows (the
    paper's §4.4 "quality target of each query", batched).  The grouped
    fallback :func:`search_batched_grouped` answers the same mixed batch as
    homogeneous sub-batches; tests pin the two equal to float tolerance.
    """
    return search_batched_async(
        index, queries, k=k, filter_params=filter_params, leaf_ids=leaf_ids,
        tuner=tuner, quality_target=quality_target, use_filters=use_filters,
        use_kernel=use_kernel, filter_type=filter_type, strategy=strategy,
        dist_impl=dist_impl, bsf_ub=bsf_ub, trace=trace,
        audit=audit).result()


def search_batched_grouped(
    index: FlatIndex,
    queries: np.ndarray,
    quality_targets: np.ndarray,
    *,
    k: int = 1,
    **kw,
) -> SearchResult:
    """Grouped-sub-batch fallback for per-query quality targets.

    Partitions the batch by unique target, answers each homogeneous group
    through :func:`search_batched` with a scalar target, and stitches the
    results back in request order.  Semantically identical to passing the
    target array straight to ``search_batched`` (the (Q, F)-offset path);
    the sub-batches compile as separate XLA programs, so prune decisions
    tied within an ulp of the bsf may fuse differently — the parity tests
    pin the two paths equal to float tolerance, not bitwise
    (tests/test_serving.py).
    """
    queries = np.atleast_2d(np.asarray(queries, np.float32))
    targets = np.asarray(quality_targets, np.float64).reshape(-1)
    Q = queries.shape[0]
    if targets.shape[0] != Q:
        raise ValueError(f"{targets.shape[0]} targets for {Q} queries")
    out: Optional[SearchResult] = None
    for val in np.unique(targets):
        sel = np.where(targets == val)[0]
        r = search_batched(index, queries[sel], k=k,
                           quality_target=float(val), **kw)
        if out is None:
            out = SearchResult(
                dists=np.empty((Q, r.dists.shape[1]), r.dists.dtype),
                ids=np.empty((Q, r.ids.shape[1]), r.ids.dtype),
                searched=np.empty(Q, r.searched.dtype),
                pruned_lb=np.empty(Q, r.pruned_lb.dtype),
                pruned_filter=np.empty(Q, r.pruned_filter.dtype),
                n_leaves=r.n_leaves,
                computed=np.empty(Q, r.computed.dtype))
        out.dists[sel], out.ids[sel] = r.dists, r.ids
        out.searched[sel], out.computed[sel] = r.searched, r.computed
        out.pruned_lb[sel], out.pruned_filter[sel] = (r.pruned_lb,
                                                      r.pruned_filter)
    assert out is not None
    return out


# ---------------------------------------------------------------------------
# early-termination form (single-query latency path)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k", "max_leaf"))
def _search_early_core(series, leaf_start, leaf_size, q, lb_row, dF_row,
                       order_row, k, max_leaf):
    L = order_row.shape[0]
    row_ids = jnp.arange(max_leaf)

    def cond(state):
        p, topk_d, *_ = state
        # visiting in LB order: the first lb > bsf prunes all the rest.
        return jnp.logical_and(p < L, lb_row[order_row[jnp.minimum(p, L - 1)]]
                               <= topk_d[-1])

    def body(state):
        p, topk_d, topk_i, n_s, n_pf = state
        leaf = order_row[p]
        bsf = topk_d[-1]
        p_f = dF_row[leaf] > bsf

        def scan_leaf(args):
            topk_d, topk_i = args
            start = leaf_start[leaf]
            slab = jax.lax.dynamic_slice_in_dim(series, start, max_leaf, 0)
            diff = slab - q[None, :]
            d = jnp.sqrt((diff * diff).sum(-1))
            d = jnp.where(row_ids < leaf_size[leaf], d, _INF)
            ids = (start + row_ids).astype(jnp.int32)
            neg_top, arg = jax.lax.top_k(
                -jnp.concatenate([topk_d, d]), k)
            return -neg_top, jnp.concatenate([topk_i, ids])[arg]

        topk_d, topk_i = jax.lax.cond(
            p_f, lambda a: a, scan_leaf, (topk_d, topk_i))
        return (p + 1, topk_d, topk_i, n_s + (~p_f).astype(jnp.int32),
                n_pf + p_f.astype(jnp.int32))

    init = (jnp.int32(0), jnp.full((k,), _INF), jnp.full((k,), -1, jnp.int32),
            jnp.int32(0), jnp.int32(0))
    p, topk_d, topk_i, n_s, n_pf = jax.lax.while_loop(cond, body, init)
    n_plb = L - p
    return topk_d, topk_i, n_s, n_plb, n_pf


def search_early(
    index: FlatIndex,
    query: np.ndarray,
    *,
    k: int = 1,
    filter_params=None,
    leaf_ids: np.ndarray | None = None,
    tuner: Optional[conformal.AutoTuner] = None,
    quality_target: Optional[float] = None,
    use_filters: bool = True,
    filter_type: str = "mlp",
) -> SearchResult:
    """Single-query early-termination search (real pruning skips)."""
    q = jnp.asarray(query, jnp.float32).reshape(1, -1)
    d_lb = bounds_mod.lower_bounds(index, q)[0]
    offsets = None
    if use_filters and filter_params is not None and tuner is not None \
            and quality_target is not None:
        offsets = tuner.offsets(quality_target)
    if use_filters and filter_params is not None:
        d_F = predictions_for_all_leaves(
            index, filter_params, leaf_ids, q, offsets,
            filter_type=filter_type)[0]
    else:
        d_F = jnp.full(d_lb.shape, -_INF)
    order = jnp.argsort(d_lb)
    td, ti, n_s, n_plb, n_pf = _search_early_core(
        jnp.asarray(index.series), jnp.asarray(index.leaf_start),
        jnp.asarray(index.leaf_size), q[0], d_lb, d_F, order,
        k=k, max_leaf=index.max_leaf_size)
    ids_sorted = np.asarray(ti)
    valid = ids_sorted >= 0
    orig = np.where(valid, np.asarray(index.order)[
        np.clip(ids_sorted, 0, index.n_series - 1)], -1)
    return SearchResult(
        dists=np.asarray(td)[None], ids=orig[None],
        searched=np.asarray(n_s)[None], pruned_lb=np.asarray(n_plb)[None],
        pruned_filter=np.asarray(n_pf)[None], n_leaves=index.n_leaves)
