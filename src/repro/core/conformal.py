"""Conformal auto-tuners (paper §4.4).

Per filter, the absolute prediction errors on a held-out calibration set are
the candidate adjusting offsets (the non-conformity scores of inductive
conformal regression).  Sorting them descending, rank j across *all* filters
jointly defines one operating point; simulating LeaFi search on the
calibration queries at each rank yields (achieved quality, offset) examples,
and a monotone Steffen (1990) spline — the same interpolant the paper uses
via GSL — maps a user-requested quality target to per-filter offsets at
query time.

The search simulation is exact w.r.t. Alg. 2 semantics: it replays the
lower-bound-ordered visit with the pruning cascade on the precollected
(d_lb, d_f, d_L) matrices, so no series data is touched during calibration.
The cascade itself lives in :func:`repro.core.engine.replay_cascade` — the
same code path the compact search engine replays over candidate summaries —
so calibration and search can never drift apart on pruning semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import engine


# ---------------------------------------------------------------------------
# Search simulation (shared by calibration, baselines and benchmarks)
# ---------------------------------------------------------------------------


def simulate_search(d_lb: jnp.ndarray, d_pred: jnp.ndarray,
                    offsets: jnp.ndarray, d_L: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Replay Alg. 2 on precollected matrices.

    d_lb, d_pred, d_L: (Q, L); d_pred is +inf where a leaf has no filter.
    offsets: (L,) conformal adjustments (0 where no filter).
    Returns (bsf_final (Q,), searched_count (Q,)).

    Thin adapter over the engine's shared cascade replay: each leaf's
    precollected NN distance d_L is its k=1 "summary", so the engine replays
    the identical prune/merge decisions it makes during search — this module
    no longer owns a second copy of the bsf cascade.
    """
    d_F = d_pred - offsets[None, :]
    order = jnp.argsort(d_lb, axis=1)
    leaf_d = d_L[..., None]                              # (Q, L, 1)
    leaf_i = jnp.zeros(leaf_d.shape, jnp.int32)
    bsf, _, n_s, _, _ = engine.replay_cascade(
        leaf_d, leaf_i, d_lb, d_F, order, k=1)
    return bsf[:, 0], n_s


def recall_at_1(bsf_final: jnp.ndarray, d_nn: jnp.ndarray,
                rtol: float = 1e-5) -> jnp.ndarray:
    """A query is correct iff the returned distance equals the true NN's."""
    return (bsf_final <= d_nn * (1 + rtol) + 1e-6).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Steffen (1990) monotone spline, vectorized over filters
# ---------------------------------------------------------------------------


def _steffen_slopes(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """x (K,), y (F, K) → per-knot slopes (F, K), monotonicity-preserving."""
    h = np.diff(x)                                  # (K-1,)
    s = np.diff(y, axis=1) / h                      # (F, K-1)
    d = np.zeros_like(y)
    if x.size == 1:
        return d
    p = (s[:, :-1] * h[1:] + s[:, 1:] * h[:-1]) / (h[:-1] + h[1:])
    d[:, 1:-1] = (np.sign(s[:, :-1]) + np.sign(s[:, 1:])) * np.minimum(
        np.minimum(np.abs(s[:, :-1]), np.abs(s[:, 1:])), 0.5 * np.abs(p))
    d[:, 0] = s[:, 0]
    d[:, -1] = s[:, -1]
    return d


@dataclasses.dataclass
class AutoTuner:
    """Fitted q → o mapping for every filter (shared quality knots)."""
    knots_q: np.ndarray          # (K,) strictly increasing qualities
    knots_o: np.ndarray          # (F, K) offsets per filter
    slopes: np.ndarray           # (F, K) Steffen slopes
    max_offset: np.ndarray       # (F,) most conservative offset observed

    def offsets(self, target, safety: float = 0.0) -> np.ndarray:
        """Per-filter offsets for quality target(s) (paper §4.4.2).

        ``target`` may be one quality target (→ (F,) offsets, the paper's
        form) or an array of B per-query targets (→ (B, F) offset rows —
        what the serving runtime feeds a heterogeneous micro-batch).  The
        batched form evaluates the same Steffen spline with identical
        arithmetic, so each row is bitwise-equal to the scalar call
        (tests/test_conformal.py pins this).

        ``safety`` (beyond-paper knob, default off = paper-faithful) aims the
        spline at target + safety·(1−target): a small calibration margin that
        fixes the high-target undershoot observed on iSAX backbones (their
        many small filtered leaves make the calibration set statistics
        thinner — cf. the paper's own §5.3.1 explanation of the SIFT/95%
        miss).
        """
        t = np.asarray(target, np.float64)
        out = self._offsets_batch(np.atleast_1d(t), safety)
        return out[0] if t.ndim == 0 else out

    def _offsets_batch(self, targets: np.ndarray,
                       safety: float = 0.0) -> np.ndarray:
        """(B,) targets → (B, F) offsets; one vectorized spline evaluation."""
        if safety:
            targets = targets + safety * (1.0 - targets)
        x, y, d = self.knots_q, self.knots_o, self.slopes
        B, F = targets.shape[0], y.shape[0]
        if x.size == 1:
            return np.broadcast_to(y[:, 0], (B, F)).copy()
        out = np.empty((B, F), y.dtype)
        # targets beyond anything achieved in simulation: be maximally
        # conservative (largest calibrated offset).
        hi = targets >= x[-1]
        out[hi] = self.max_offset
        if (~hi).any():
            q = np.clip(targets[~hi], x[0], x[-1])
            i = np.clip(np.searchsorted(x, q, side="right") - 1, 0, x.size - 2)
            h = x[i + 1] - x[i]                           # (b,)
            t = q - x[i]
            s = (y[:, i + 1] - y[:, i]) / h               # (F, b)
            a = (d[:, i] + d[:, i + 1] - 2 * s) / (h * h)
            b = (3 * s - 2 * d[:, i] - d[:, i + 1]) / h
            out[~hi] = (((a * t + b) * t + d[:, i]) * t + y[:, i]).T
        return out


def _pava_nondecreasing(y: np.ndarray) -> np.ndarray:
    """Pool-adjacent-violators: project y (F, J) onto non-decreasing rows."""
    y = y.copy()
    F, J = y.shape
    for f in range(F):
        vals = []
        counts = []
        for v in y[f]:
            vals.append(float(v))
            counts.append(1)
            while len(vals) > 1 and vals[-2] > vals[-1]:
                v2, c2 = vals.pop(), counts.pop()
                v1, c1 = vals.pop(), counts.pop()
                vals.append((v1 * c1 + v2 * c2) / (c1 + c2))
                counts.append(c1 + c2)
        out = np.repeat(vals, counts)
        y[f] = out
    return y


# ---------------------------------------------------------------------------
# Auto-tuner learning (Alg. 4)
# ---------------------------------------------------------------------------


def fit_autotuners(
    d_lb: np.ndarray,            # (C, L) calib lower bounds
    d_pred: np.ndarray,          # (C, L) calib filter predictions (+inf none)
    d_L: np.ndarray,             # (C, L) calib node-wise NN distances
    leaf_ids: np.ndarray,        # (F,) leaves with filters
    max_ranks: int = 64,
) -> Tuple[AutoTuner, dict]:
    """Learn per-filter quality→offset mappings by simulated search.

    Follows Alg. 4: candidate offsets are the sorted absolute calibration
    errors; each rank is evaluated by replaying the search on the calibration
    queries; a monotone spline is fitted per filter.
    """
    C, L = d_lb.shape
    F = len(leaf_ids)
    alphas = np.abs(d_pred[:, leaf_ids] - d_L[:, leaf_ids])       # (C, F)
    A = -np.sort(-alphas, axis=0)                                 # desc, (C, F)

    # subsample ranks for the simulation sweep (quantile-spaced)
    ranks = np.unique(np.linspace(0, C - 1, min(max_ranks, C)).astype(int))
    offsets_per_rank = np.zeros((len(ranks), L), np.float32)
    for r, j in enumerate(ranks):
        offsets_per_rank[r, leaf_ids] = A[j]

    d_nn = d_L.min(axis=1)
    sim = jax.vmap(lambda o: simulate_search(
        jnp.asarray(d_lb), jnp.asarray(d_pred), o, jnp.asarray(d_L)))
    bsf, searched = sim(jnp.asarray(offsets_per_rank))            # (J, C)
    quality = np.asarray(
        recall_at_1(bsf, jnp.asarray(d_nn)[None, :]).mean(axis=1))  # (J,)
    pruning = 1.0 - np.asarray(searched).mean(axis=1) / L

    # examples (q_j, o_{f,j}) → monotone mapping q → o
    orderq = np.argsort(quality, kind="stable")
    q_sorted = quality[orderq]
    o_sorted = A[ranks][orderq].T.astype(np.float64)              # (F, J)
    o_iso = _pava_nondecreasing(o_sorted)

    # collapse duplicate quality knots (keep the largest = safest offset)
    uq, inverse = np.unique(np.round(q_sorted, 6), return_inverse=True)
    K = len(uq)
    o_knots = np.full((F, K), -np.inf)
    np.maximum.at(o_knots.T, inverse, o_iso.T)
    slopes = (_steffen_slopes(uq, o_knots) if K > 1
              else np.zeros_like(o_knots))

    tuner = AutoTuner(knots_q=uq, knots_o=o_knots.astype(np.float32),
                      slopes=slopes.astype(np.float32),
                      max_offset=A.max(axis=0).astype(np.float32))
    report = {"rank_quality": quality, "rank_pruning": pruning,
              "ranks": ranks}
    return tuner, report


def scatter_offsets(tuner: Optional[AutoTuner], leaf_ids: np.ndarray,
                    n_leaves: int, target) -> np.ndarray:
    """Offset vector(s) for quality target(s); zeros where no filter.

    One target → (L,); an array of B per-query targets → (B, L) rows, one
    per query of a heterogeneous serving micro-batch (each row equals the
    scalar call for that target — the spline evaluation is shared, see
    :meth:`AutoTuner.offsets`).

    tuner=None (an index that selected zero filters — e.g. every leaf under
    the size threshold) degrades gracefully to the exact index."""
    t = None if target is None else np.asarray(target, np.float64)
    if t is not None and t.ndim:
        out = np.zeros((t.shape[0], n_leaves), np.float32)
        if tuner is not None and len(leaf_ids):
            out[:, leaf_ids] = tuner.offsets(t)
        return out
    out = np.zeros(n_leaves, np.float32)
    if target is not None and tuner is not None and len(leaf_ids):
        out[leaf_ids] = tuner.offsets(target)
    return out
