from .hlo_collectives import (collective_bytes_per_device,  # noqa: F401
                              hlo_stats, CollectiveStats)
from .roofline import RooflineTerms, roofline_from_compiled, V5E  # noqa: F401
