"""Three-term roofline model from a compiled dry-run artifact.

TPU v5e per chip (assignment constants): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  ``cost_analysis()`` of the SPMD-partitioned module gives
*per-device* FLOPs and memory bytes; the collective term comes from the HLO
parser (also per-device), so

    t_compute    = flops_per_device / peak_flops
    t_memory     = bytes_per_device / hbm_bw
    t_collective = link_bytes_per_device / ici_bw

The dominant term is the bottleneck; roofline fraction = t_compute /
max(all terms) (how close the cell is to being compute-bound at peak).
MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per trained token — the
useful-work yardstick that exposes remat/padding/capacity waste.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from .hlo_collectives import collective_bytes_per_device


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s
    ici_bw: float              # bytes/s per link


V5E = HardwareSpec("tpu-v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)


@dataclasses.dataclass
class RooflineTerms:
    flops_per_device: float
    hbm_bytes_per_device: float
    link_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: Optional[float] = None          # useful FLOPs (global)
    n_devices: int = 1

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak compute achievable given the dominant bound."""
        return self.t_compute / self.bound_time if self.bound_time else 0.0

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / (HLO flops × devices): remat/padding waste meter."""
        if self.model_flops is None or self.flops_per_device == 0:
            return None
        return self.model_flops / (self.flops_per_device * self.n_devices)

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, bound_time=self.bound_time,
                 roofline_fraction=self.roofline_fraction,
                 useful_flops_ratio=self.useful_flops_ratio)
        return d


def model_flops_per_step(cfg, shape) -> float:
    """6·N·D for training; 2·N·D per generated/prefilled token at serving."""
    n = cfg.n_active_params
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * n * tokens
    # decode: one token per sequence in the batch (+ attention over cache,
    # excluded from the useful-work yardstick by convention)
    return 2.0 * n * shape.batch


def roofline_from_compiled(compiled, n_devices: int,
                           model_flops: Optional[float] = None,
                           hw: HardwareSpec = V5E,
                           hlo_text: Optional[str] = None) -> RooflineTerms:
    """Terms from our HLO walker (cost_analysis counts while bodies once —
    verified — so scan-over-layers models need the trip-count-aware parse).

    Byte terms use the bf16 projection (XLA:CPU legalizes bf16 compute to
    f32; f32 traffic is halved — see hlo_collectives._type_bytes).
    """
    text = hlo_text if hlo_text is not None else compiled.as_text()
    stats = collective_bytes_per_device(text, f32_as_bf16=True)
    flops = stats.flops
    raw_bytes = stats.hbm_bytes
    return RooflineTerms(
        flops_per_device=flops,
        hbm_bytes_per_device=raw_bytes,
        link_bytes_per_device=stats.total_bytes,
        t_compute=flops / hw.peak_flops,
        t_memory=raw_bytes / hw.hbm_bw,
        t_collective=stats.total_bytes / hw.ici_bw,
        model_flops=model_flops,
        n_devices=n_devices,
    )


def filter_mlp_roofline(n_filters: int, n_queries: int, length: int,
                        hidden: Optional[int] = None, *,
                        variant: str = "fused",
                        weight_dtype: str = "float32",
                        bq: int = 128, bf: int = 8,
                        hw: HardwareSpec = V5E) -> RooflineTerms:
    """Analytic three-term bound for the stacked filter-inference kernels.

    Counts what each grid layout actually streams from HBM (no compiled
    artifact needed — the kernels' traffic is fully determined by shape):

    * weights — both kernels stream every filter's parameter block once per
      query tile: ``ceil(Q/bq) · F · (m·h + h)`` weight elements at the
      payload dtype's width plus the float32 bias/stat vectors.  bf16/int8
      cut this, the dominant term at large F, by 2×/4×.
    * queries — the per-filter kernel re-streams the (bq, m) query tile once
      per *filter* (F·Q·m·4 bytes); the fused kernel amortizes it across the
      bf filters of each block, a bf× cut.
    * output — F·Q·4 bytes once; the *unfused* composition pays ~3 extra
      read+write broadcast passes over the (F, Q) block for y_std, y_mean
      and the conformal offsets, which the fused epilogue eliminates.

    The fused variant's group-sum matmul trick costs ``2·h·bf`` extra FLOPs
    per (filter, query) — counted under t_compute, which is why the fused
    kernel stays memory-bound and the trade is free in wall-clock terms.
    ``link_bytes`` is zero: filter inference is single-chip; cross-shard
    aggregation is the engine's concern (see core.distributed).
    """
    # import here: analysis must stay importable without the core package
    from ..core.filters import WEIGHT_BYTES_PER_EL
    m, h = length, hidden or length
    F, Q = n_filters, n_queries
    wb = WEIGHT_BYTES_PER_EL[weight_dtype]
    n_scales = 2 if weight_dtype == "int8" else 0
    tiles = -(-Q // bq)
    flops = F * Q * (2 * m * h + 2 * h)
    # per-filter parameter block: w1, w2 at wb; b1 f32; b2/y_mean/y_std/off
    # f32 scalars; int8 adds the two per-filter scales
    per_filter = (m * h + h) * wb + h * 4 + (4 + n_scales) * 4
    weight_bytes = tiles * F * per_filter
    out_bytes = F * Q * 4
    if variant == "fused":
        flops += F * Q * 2 * h * bf            # group-sum matmul overhead
        query_bytes = -(-F // bf) * Q * m * 4
        epilogue_bytes = 0
    elif variant == "per_filter":
        query_bytes = F * Q * m * 4
        epilogue_bytes = 3 * 2 * F * Q * 4     # y_std, y_mean, offset passes
    else:
        raise ValueError(f"unknown variant {variant!r}")
    hbm = weight_bytes + query_bytes + out_bytes + epilogue_bytes
    return RooflineTerms(
        flops_per_device=float(flops),
        hbm_bytes_per_device=float(hbm),
        link_bytes_per_device=0.0,
        t_compute=flops / hw.peak_flops,
        t_memory=hbm / hw.hbm_bw,
        t_collective=0.0,
    )


def memory_report(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    out["total_hbm_bytes"] = (
        out.get("argument_size_in_bytes", 0.0)
        + out.get("output_size_in_bytes", 0.0)
        + out.get("temp_size_in_bytes", 0.0)
        - out.get("alias_size_in_bytes", 0.0))
    return out
