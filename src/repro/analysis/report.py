"""Generate EXPERIMENTS.md tables from dry-run/bench artifacts.

    PYTHONPATH=src python -m repro.analysis.report \
        --dryrun experiments/dryrun --bench experiments/bench_results.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict


def load_dryrun(path: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        r["_file"] = os.path.basename(f)
        recs.append(r)
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.1f}"


def dryrun_table(recs, pod: str = "pod1", tag: str = "") -> str:
    lines = [
        "| arch | shape | policy | dominant | t_comp (s) | t_mem (s) | "
        "t_coll (s) | roofline frac | useful-FLOPs | HBM GB/dev | note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        fname = r["_file"]
        if not fname.endswith(f"__{pod}{tag}.json"):
            continue
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — "
                f"| — | SKIP: {r['reason'][:40]} |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | ERROR "
                         f"| — | — | — | — | — | — | {r.get('error','')[:40]} |")
            continue
        rf = r["roofline"]
        ur = rf.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['policy']} | {rf['dominant']} "
            f"| {rf['t_compute']:.3f} | {rf['t_memory']:.3f} "
            f"| {rf['t_collective']:.3f} | {rf['roofline_fraction']:.3f} "
            f"| {ur:.2f} | {fmt_bytes(r['memory']['total_hbm_bytes'])} |  |")
    return "\n".join(lines)


def multipod_check(recs) -> str:
    by_cell = defaultdict(dict)
    for r in recs:
        if "_kvq" in r["_file"]:
            continue
        pod = "pod2" if "__pod2" in r["_file"] else "pod1"
        by_cell[(r["arch"], r["shape"])][pod] = r.get("status")
    ok = sum(1 for v in by_cell.values()
             if v.get("pod1") == v.get("pod2") == "ok")
    skip = sum(1 for v in by_cell.values()
               if v.get("pod1") == v.get("pod2") == "skipped")
    bad = {k: v for k, v in by_cell.items()
           if v.get("pod1") not in ("ok", "skipped")
           or v.get("pod2") not in ("ok", "skipped")}
    out = [f"Cells compiling on BOTH meshes (16×16 and 2×16×16): **{ok}**; "
           f"documented skips: **{skip}**; failures: **{len(bad)}**."]
    for k, v in bad.items():
        out.append(f"  FAIL {k}: {v}")
    return "\n".join(out)


def collective_summary(recs, cells) -> str:
    lines = ["| cell | all-gather GB | all-reduce GB | reduce-scatter GB | "
             "all-to-all GB | permute GB |", "|---|---|---|---|---|---|"]
    for r in recs:
        key = (r.get("arch"), r.get("shape"))
        if key not in cells or "__pod1" not in r["_file"] \
                or "_kvq" in r["_file"] or r.get("status") != "ok":
            continue
        b = r["collective_schedule"]["bytes_by_kind"]
        lines.append(
            f"| {key[0]}/{key[1]} | {b.get('all-gather',0)/2**30:.1f} "
            f"| {b.get('all-reduce',0)/2**30:.1f} "
            f"| {b.get('reduce-scatter',0)/2**30:.1f} "
            f"| {b.get('all-to-all',0)/2**30:.1f} "
            f"| {b.get('collective-permute',0)/2**30:.1f} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="experiments/dryrun")
    ap.add_argument("--bench", default="experiments/bench_results.json")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    recs = load_dryrun(args.dryrun)
    print("## Single-pod baseline (16×16)\n")
    print(dryrun_table(recs, "pod1"))
    print("\n## Multi-pod status\n")
    print(multipod_check(recs))


if __name__ == "__main__":
    main()
