"""Parse compiled (SPMD-partitioned) HLO text into roofline inputs.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically: a scanned matmul reports 1/8 of the unrolled flops), which
makes it useless for scan-over-layers models.  This module walks the HLO
call graph with loop trip counts and produces:

* **flops** — 2·|out|·|contraction| for every ``dot`` (including dots inside
  fusion computations), × enclosing loop trip counts.
* **hbm bytes** — Σ (operands + output) over *top-level* ops in control
  computations (entry, while bodies, conditional branches).  Fusion
  internals don't touch HBM post-fusion, so only the fusion op's boundary
  shapes count.
* **collective link bytes** — ring-model factors per collective kind:
      all-gather        (n−1)/n · output_bytes
      reduce-scatter    (n−1)/n · input_bytes
      all-reduce        2·(n−1)/n · input_bytes      (RS + AG)
      all-to-all        (n−1)/n · input_bytes
      collective-permute  input_bytes
  All shapes in the partitioned module are per-device, so totals are
  per-device link/HBM traffic — exactly what the roofline terms need.

HLO op lines reference operands by name only (no inline shapes on CPU), so
each computation gets a symbol table (params from the header, results from
each op line) before the walk.  Trip counts come from the largest integer
constant in each loop's condition computation (XLA emits counted loops for
lax.scan).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_HEADER_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*(\(?[\w\[\],\s{}\d]*)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _type_bytes(type_str: str, f32_as_bf16: bool = False) -> int:
    """Byte size of an HLO type string (tuples sum their elements).

    ``f32_as_bf16`` counts f32 as 2 bytes: XLA:CPU legalizes bf16 compute to
    f32 (verified — bf16 survives only at jit boundaries), so a TPU-projected
    roofline must halve the f32 traffic.  Genuinely-f32 tensors (optimizer
    moments, softmax stats) are then undercounted ≤2×, which is conservative
    for the collective/memory terms since weight+activation traffic
    dominates.  Both raw and projected totals are reported.
    """
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = 2 if (f32_as_bf16 and dt == "f32") else _DTYPE_BYTES[dt]
        total += n * b
    return total


def _first_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Comp:
    name: str
    header: str
    lines: List[str]
    symbols: Dict[str, str]          # value name -> type string


def _split_computations(hlo: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("->" in stripped or
                                       stripped.startswith("ENTRY")):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if m:
                cur = _Comp(m.group(1), stripped, [], {})
                comps[cur.name] = cur
        elif stripped.startswith("}"):
            cur = None
        elif cur is not None:
            cur.lines.append(stripped)
    # build symbol tables
    for comp in comps.values():
        pm = re.search(r"\((.*)\)\s*->", comp.header)
        if pm:
            for name, tstr in _HEADER_PARAM_RE.findall(pm.group(1)):
                comp.symbols[name] = tstr
        for ln in comp.lines:
            om = _OP_RE.match(ln)
            if om:
                comp.symbols[om.group(1)] = om.group(2)
    return comps


def _operand_names(rest: str) -> List[str]:
    """Operand names from the text following 'opcode(' (up to its ')')."""
    depth = 1
    out_chars = []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        out_chars.append(ch)
    inner = "".join(out_chars)
    return re.findall(r"%([\w\.\-]+)", inner)


def _operand_bytes(comp: _Comp, rest: str,
                   f32_as_bf16: bool = False) -> int:
    return sum(_type_bytes(comp.symbols.get(n, ""), f32_as_bf16)
               for n in _operand_names(rest))


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))      # [n_groups, group_size] <= [total]
    return default


def _trip_count(comp: Optional[_Comp]) -> int:
    if comp is None:
        return 1
    best = 1
    for ln in comp.lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(comp: _Comp, out_type: str, rest: str, line: str) -> float:
    out_elems = 1
    for d in _first_dims(out_type):
        out_elems *= d
    names = _operand_names(rest)
    lhs_dims = _first_dims(comp.symbols.get(names[0], "")) if names else []
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contract = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx:
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]
    flops: float = 0.0
    hbm_bytes: float = 0.0

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> Dict[str, float]:
        out = {f"bytes_{k}": v for k, v in self.bytes_by_kind.items()}
        out.update(bytes_total=self.total_bytes, flops=self.flops,
                   hbm_bytes=self.hbm_bytes)
        return out


def hlo_stats(hlo_text: str, default_group: int = 1,
              f32_as_bf16: bool = False) -> CollectiveStats:
    comps = _split_computations(hlo_text)
    bytes_by_kind: Dict[str, float] = defaultdict(float)
    count_by_kind: Dict[str, int] = defaultdict(int)
    totals = {"flops": 0.0, "hbm": 0.0}

    def fusion_flops(comp: _Comp, seen: tuple) -> float:
        fl = 0.0
        for ln in comp.lines:
            om = _OP_RE.match(ln)
            if not om:
                continue
            _, out_type, opcode, rest = om.groups()
            if opcode == "dot":
                fl += _dot_flops(comp, out_type, rest, ln)
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", ln):
                sub = comps.get(m.group(1))
                if sub and sub.name not in seen:
                    fl += fusion_flops(sub, seen + (sub.name,))
        return fl

    def visit(comp_name: str, mult: float, seen: tuple):
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen:
            return
        for ln in comp.lines:
            om = _OP_RE.match(ln)
            if not om:
                continue
            _, out_type, opcode, rest = om.groups()
            base = opcode.replace("-start", "").replace("-done", "")

            if base in COLLECTIVES and not opcode.endswith("-done"):
                operand_bytes = _operand_bytes(comp, rest, f32_as_bf16)
                out_bytes = _type_bytes(out_type, f32_as_bf16)
                n = _group_size(ln, default_group)
                f = (n - 1) / n if n > 1 else 0.0
                if base == "all-gather":
                    link = f * max(out_bytes, operand_bytes)
                elif base == "reduce-scatter":
                    link = f * operand_bytes
                elif base == "all-reduce":
                    link = 2 * f * operand_bytes
                elif base == "all-to-all":
                    link = f * operand_bytes
                else:  # collective-permute
                    link = float(operand_bytes)
                bytes_by_kind[base] += mult * link
                count_by_kind[base] += max(int(mult), 1)

            # hbm bytes: boundary traffic of real ops
            if opcode not in ("tuple", "get-tuple-element", "parameter",
                              "constant", "bitcast", "after-all"):
                totals["hbm"] += mult * (
                    _type_bytes(out_type, f32_as_bf16)
                    + _operand_bytes(comp, rest, f32_as_bf16))

            if opcode == "dot":
                totals["flops"] += mult * _dot_flops(comp, out_type, rest, ln)
            elif opcode == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", ln)
                sub = comps.get(m.group(1)) if m else None
                if sub:
                    totals["flops"] += mult * fusion_flops(sub, (sub.name,))
            elif opcode == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ln)
                cm = re.search(r"condition=%?([\w\.\-]+)", ln)
                trips = _trip_count(comps.get(cm.group(1))) if cm else 1
                if bm:
                    visit(bm.group(1), mult * trips, seen + (comp_name,))
            elif opcode == "conditional":
                for m in re.finditer(
                        r"(?:branch_computations=\{([^}]*)\}|"
                        r"(?:true|false)_computation=%?([\w\.\-]+))", ln):
                    blob = m.group(1) or m.group(2) or ""
                    for name in blob.split(","):
                        name = name.strip().lstrip("%")
                        if name:
                            visit(name, mult, seen + (comp_name,))
            elif opcode in ("call", "async-start"):
                m = re.search(r"to_apply=%?([\w\.\-]+)", ln)
                if m:
                    visit(m.group(1), mult, seen + (comp_name,))

    entry = None
    for ln in hlo_text.splitlines():
        if ln.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", ln)
            if m:
                entry = m.group(1)
            break
    if entry is not None:
        visit(entry, 1.0, ())
    return CollectiveStats(dict(bytes_by_kind), dict(count_by_kind),
                           flops=totals["flops"], hbm_bytes=totals["hbm"])


# backwards-compatible alias
def collective_bytes_per_device(hlo_text: str,
                                default_group: int = 1,
                                f32_as_bf16: bool = False) -> CollectiveStats:
    return hlo_stats(hlo_text, default_group, f32_as_bf16)
