"""Repo-contract rules: LF002 (parity-oracle coverage) and LF005
(benchmark-claim hygiene).

Both read fixed repo-relative locations through ``ctx.read_extra`` /
``ctx.root`` rather than the linted path set, so ``python -m
repro.analysis.lint src`` still checks ``tests/`` and ``benchmarks/``
contracts without linting those trees.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Iterable, List, Set

from .framework import Finding, LintContext, rule

_TESTS_REL = "tests/test_kernels.py"
_BENCH_REL = "benchmarks/run.py"


def _referenced_names(tree: ast.Module) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            names.add(node.id)
        elif isinstance(node, ast.Attribute):
            names.add(node.attr)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for a in node.names:
                names.add(a.asname or a.name.split(".")[-1])
    return names


@rule("LF002", "every public kernel op keeps a parity oracle")
def lf002(ctx: LintContext) -> Iterable[Finding]:
    """Every public export of ``kernels/*/ops.py`` (top-level def or
    assignment not prefixed ``_``) must be referenced from
    ``tests/test_kernels.py`` — the "every fast path keeps a parity oracle"
    convention as a gate.  A fast-path variant nobody pins drifts."""
    ops_modules = [m for m in ctx.modules
                   if re.search(r"kernels/[^/]+/ops\.py$", m.rel)]
    if not ops_modules:
        return
    tests = ctx.read_extra(_TESTS_REL)
    if tests is None:
        for m in ops_modules:
            yield Finding("LF002", m.rel, 1,
                          f"no {_TESTS_REL} found to reference this "
                          "kernel's exports from")
        return
    referenced = _referenced_names(tests.tree)
    for m in ops_modules:
        for node in m.tree.body:
            name, line = None, None
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                name, line = node.name, node.lineno
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                name, line = node.targets[0].id, node.lineno
            if not name or name.startswith("_") or name.isupper():
                continue                  # private / constant table
            if name not in referenced:
                yield Finding(
                    "LF002", m.rel, line,
                    f"public kernel export `{name}` is never referenced "
                    f"from {_TESTS_REL} — add a parity test or prefix it "
                    "with `_`")


@rule("LF005", "every benchmark suite backs its claim")
def lf005(ctx: LintContext) -> Iterable[Finding]:
    """Every suite registered in ``benchmarks/run.py`` must have (a) its
    JSON artifact committed under ``experiments/`` and (b) a
    ``bench-<suite>`` Makefile target — the "every perf claim lands as a
    suite entry with a JSON artifact" convention as a gate."""
    bench = ctx.read_extra(_BENCH_REL)
    if bench is None:
        return                            # no benchmark layer, nothing owed
    suites: List = []                     # (name, artifact_rel, line)
    for node in ast.walk(bench.tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "SUITES"
                and isinstance(node.value, ast.Dict)):
            continue
        for key, val in zip(node.value.keys, node.value.values):
            if not (isinstance(key, ast.Constant)
                    and isinstance(key.value, str)):
                continue
            artifact = None
            if isinstance(val, (ast.Tuple, ast.List)):
                for elt in val.elts:
                    if isinstance(elt, ast.Constant) \
                            and isinstance(elt.value, str) \
                            and elt.value.endswith(".json"):
                        artifact = elt.value
            suites.append((key.value, artifact, key.lineno))
    makefile_path = os.path.join(ctx.root, "Makefile")
    makefile = ""
    if os.path.isfile(makefile_path):
        with open(makefile_path, encoding="utf-8") as f:
            makefile = f.read()
    for name, artifact, line in suites:
        if artifact is None:
            yield Finding(
                "LF005", _BENCH_REL, line,
                f"suite `{name}` does not name a .json artifact path")
        elif not os.path.isfile(os.path.join(ctx.root, artifact)):
            yield Finding(
                "LF005", _BENCH_REL, line,
                f"suite `{name}` claims artifact `{artifact}` but it is "
                "not committed under experiments/ — run the suite and "
                "commit the JSON, or drop the suite")
        if not re.search(rf"^bench-{re.escape(name)}\s*:", makefile,
                         re.MULTILINE):
            yield Finding(
                "LF005", _BENCH_REL, line,
                f"suite `{name}` has no `bench-{name}` Makefile target")
