"""AST-based invariant linter for the LeaFi reproduction.

Rules (see each module's docstring for the rationale):

======  ==================================================================
LF000   pragma hygiene — every ``# leafi: ignore[...]`` carries a reason
LF001   dynamic-shape / host-sync ops inside jit/shard_map-reachable code
LF002   every public ``kernels/*/ops.py`` export has a parity test
LF003   no reads after ``donate_argnums``/``donate=`` buffer donation
LF004   recompile hazards at jitted call sites (unhashable / loop-varying
        static args)
LF005   every ``benchmarks/run.py`` suite has its JSON artifact + Makefile
        target
======  ==================================================================

CLI: ``python -m repro.analysis.lint [paths] [--root DIR] [--format
human|json] [--rules LF001,...] [--list-rules]``.  Exit 0 clean, 1
findings, 2 linter failure.
"""
from .framework import (Finding, LintReport, RULES, render,  # noqa: F401
                        run_lint)
from . import rules_flow, rules_jit, rules_repo  # noqa: F401  (register rules)
