"""Invariant-linter core: findings, rule registry, pragmas, runner, output.

The linter is a custom AST pass over this repo's load-bearing conventions
(static shapes under jit/shard_map, parity-oracle coverage, donation safety,
program-cache discipline, benchmark-claim hygiene) — the ROADMAP's
"Conventions" section as machine-checked gates instead of prose.  It is
stdlib-only (``ast`` + ``tokenize``): linting never imports jax or the code
under analysis, so it runs identically on bare runtime images.

Vocabulary:

* **Rule** — a registered check with a stable id (``LF001``…).  Every rule
  sees the whole parsed corpus (:class:`LintContext`) so cross-file rules
  are not special-cased.
* **Finding** — one violation: ``(rule, path, line, message)``.
* **Pragma** — ``# leafi: ignore[LF001]: reason`` suppresses that rule's
  findings on the same line (or on the line directly below a comment-only
  pragma line).  The reason is mandatory: a reasonless or malformed pragma
  is itself reported under the reserved id ``LF000`` and suppresses nothing.

Exit-code contract (:meth:`LintReport.exit_code`): 0 = clean, 1 = findings,
2 = the linter itself could not run (unreadable/unparseable target, unknown
rule selection).
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import os
import re
import tokenize
from typing import Callable, Dict, Iterable, List, Optional, Sequence

PRAGMA_ID = "LF000"
_PRAGMA_RE = re.compile(
    r"leafi:\s*ignore\s*\[(?P<rules>[^\]]*)\]\s*(?::\s*(?P<reason>.*\S))?\s*$")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str              # repo-root-relative, forward slashes
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}


@dataclasses.dataclass(frozen=True)
class Pragma:
    line: int
    rules: tuple                   # rule ids, upper-cased
    reason: str
    comment_only: bool             # the line holds nothing but the comment


@dataclasses.dataclass
class Module:
    """One parsed source file plus its pragma table."""
    path: str                      # absolute
    rel: str                       # repo-root-relative, forward slashes
    dotted: str                    # best-effort dotted module name
    source: str
    tree: ast.Module
    pragmas: Dict[int, Pragma]


@dataclasses.dataclass
class LintContext:
    root: str                      # absolute repo root
    modules: List[Module]
    by_dotted: Dict[str, Module]

    def read_extra(self, rel: str) -> Optional[Module]:
        """Parse a repo file outside the linted path set (cross-file rules).

        Returns None when the file does not exist; raises nothing — a
        syntactically broken extra file comes back as None too (the rule
        decides what absence means).
        """
        path = os.path.join(self.root, rel)
        if not os.path.isfile(path):
            return None
        try:
            return _load_module(path, self.root)
        except SyntaxError:
            return None


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    title: str
    doc: str
    fn: Callable[[LintContext], Iterable[Finding]]


RULES: Dict[str, Rule] = {}


def rule(rule_id: str, title: str):
    """Register a rule: the decorated fn maps a LintContext to findings."""
    def deco(fn):
        RULES[rule_id] = Rule(rule_id, title, (fn.__doc__ or "").strip(), fn)
        return fn
    return deco


# ---------------------------------------------------------------------------
# source loading + pragmas
# ---------------------------------------------------------------------------


def _parse_pragmas(source: str) -> Dict[int, Pragma]:
    pragmas: Dict[int, Pragma] = {}
    lines = source.splitlines()
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except tokenize.TokenError:          # ast.parse already succeeded; rare
        return pragmas
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _PRAGMA_RE.search(tok.string)
        if m is None:
            continue
        line = tok.start[0]
        ids = tuple(r.strip().upper() for r in m.group("rules").split(",")
                    if r.strip())
        reason = (m.group("reason") or "").strip()
        text = lines[line - 1] if line <= len(lines) else ""
        comment_only = text.strip().startswith("#")
        pragmas[line] = Pragma(line, ids, reason, comment_only)
    return pragmas


def _dotted_name(rel: str) -> str:
    parts = rel.replace(os.sep, "/").split("/")
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _load_module(path: str, root: str) -> Module:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    tree = ast.parse(source, filename=rel)
    return Module(path=path, rel=rel, dotted=_dotted_name(rel),
                  source=source, tree=tree, pragmas=_parse_pragmas(source))


def _collect_files(paths: Sequence[str], root: str) -> List[str]:
    out: List[str] = []
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(ap):
            out.append(ap)
            continue
        for dirpath, dirnames, filenames in os.walk(ap):
            dirnames[:] = [d for d in dirnames
                           if not d.startswith(".") and d != "__pycache__"]
            out.extend(os.path.join(dirpath, f)
                       for f in sorted(filenames) if f.endswith(".py"))
    return sorted(set(out))


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LintReport:
    findings: List[Finding]               # active (unsuppressed)
    suppressed: List[dict]                # {finding, reason}
    errors: List[str]                     # linter-level failures → exit 2
    files: int
    rules: List[str]

    def exit_code(self) -> int:
        if self.errors:
            return 2
        return 1 if self.findings else 0

    def to_json(self) -> dict:
        return {
            "files": self.files,
            "rules": self.rules,
            "findings": [f.to_json() for f in self.findings],
            "suppressed": [{**s["finding"].to_json(), "reason": s["reason"]}
                           for s in self.suppressed],
            "errors": self.errors,
            "exit_code": self.exit_code(),
        }

    def render_human(self) -> str:
        out = [f.render() for f in self.findings]
        for err in self.errors:
            out.append(f"error: {err}")
        n, s = len(self.findings), len(self.suppressed)
        out.append(f"invariant lint: {self.files} files, "
                   f"{len(self.rules)} rules, {n} finding(s)"
                   + (f", {s} suppressed" if s else ""))
        return "\n".join(out)


def _suppression_for(mod: Module, finding: Finding) -> Optional[Pragma]:
    """The pragma covering this finding, if any (same line, or the
    comment-only pragma line directly above)."""
    for line in (finding.line, finding.line - 1):
        p = mod.pragmas.get(line)
        if p is None:
            continue
        if line == finding.line - 1 and not p.comment_only:
            continue
        if finding.rule in p.rules:
            return p
    return None


def run_lint(paths: Sequence[str], root: Optional[str] = None,
             rules: Optional[Sequence[str]] = None) -> LintReport:
    """Lint ``paths`` (files or directories) against the registered rules.

    ``root`` anchors repo-relative lookups for cross-file rules (Makefile,
    tests/, benchmarks/, experiments/); defaults to the current directory.
    ``rules`` restricts to a subset of rule ids (default: all registered).
    """
    root = os.path.abspath(root or ".")
    selected = sorted(RULES) if rules is None else list(rules)
    errors: List[str] = []
    for r in selected:
        if r not in RULES:
            errors.append(f"unknown rule id {r!r} "
                          f"(known: {', '.join(sorted(RULES))})")
    if errors:
        return LintReport([], [], errors, 0, selected)

    modules: List[Module] = []
    for path in _collect_files(paths, root):
        try:
            modules.append(_load_module(path, root))
        except (OSError, SyntaxError) as e:
            errors.append(f"cannot parse {path}: {e}")
    if errors:
        return LintReport([], [], errors, len(modules), selected)

    ctx = LintContext(root=root, modules=modules,
                      by_dotted={m.dotted: m for m in modules})
    by_rel = {m.rel: m for m in modules}

    raw: List[Finding] = []
    for rid in selected:
        raw.extend(RULES[rid].fn(ctx))

    active: List[Finding] = []
    suppressed: List[dict] = []
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.rule)):
        mod = by_rel.get(f.path)
        pragma = _suppression_for(mod, f) if mod is not None else None
        if pragma is not None and pragma.reason:
            suppressed.append({"finding": f, "reason": pragma.reason})
        else:
            active.append(f)

    # pragma hygiene (LF000, never suppressible): mandatory reason, known ids
    for mod in modules:
        for p in mod.pragmas.values():
            if not p.reason:
                active.append(Finding(
                    PRAGMA_ID, mod.rel, p.line,
                    "ignore pragma without a reason — write "
                    "'# leafi: ignore[RULE]: why this is safe'"))
            for rid in p.rules:
                if rid not in RULES:
                    active.append(Finding(
                        PRAGMA_ID, mod.rel, p.line,
                        f"ignore pragma names unknown rule {rid!r}"))

    active.sort(key=lambda f: (f.path, f.line, f.rule))
    return LintReport(active, suppressed, errors, len(modules), selected)


def render(report: LintReport, fmt: str = "human") -> str:
    if fmt == "json":
        return json.dumps(report.to_json(), indent=1)
    return report.render_human()
