"""Trace-context rules: LF001 (dynamic shapes under jit) and LF004
(recompile hazards at jitted call sites).

Both rules share a :class:`JitIndex` — a conservative over-approximation of
"which functions does XLA trace".  Roots are functions that are (a)
jit/pmap-decorated (including ``functools.partial(jax.jit, ...)``), (b)
passed by name into a tracing higher-order call (``jax.jit(f)``,
``shard_map(f, ...)``, ``lax.scan(f, ...)``, ...), or (c) contain a
collective (``lax.pmin``/``psum``/``axis_index`` are only legal inside
``shard_map``/``pmap`` bodies, so containing one *proves* the function is a
mapped body even when it is built indirectly, e.g. returned from a factory).
Reachability then follows name references — calls *and* bare mentions, so
``vmap(probe)`` and ``lax.cond(p, f, g, x)`` create edges — across modules
via import-alias resolution, with a bare-name fallback into nested scopes
(factory-made closures like ``_make_shard_body.search_fn`` resolve even
though they are not importable names).

Over-approximation is the right failure mode for a linter: an unreachable
function misflagged costs one pragma; a reachable one missed costs a silent
``ConcretizationTypeError`` (or worse, a host sync) in production.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .framework import Finding, LintContext, Module, rule

# Callables whose function-valued arguments get traced by XLA.
_TRACING_HOFS = {
    "jit", "pmap", "vmap", "grad", "value_and_grad", "shard_map", "xmap",
    "scan", "while_loop", "fori_loop", "cond", "switch", "associated_scan",
    "associative_scan", "checkify", "custom_jvp", "custom_vjp", "remat",
    "checkpoint",
}
# Ops only legal inside a mapped (shard_map/pmap) body.
_COLLECTIVES = {
    "psum", "pmin", "pmax", "pmean", "ppermute", "all_gather", "all_to_all",
    "axis_index", "psum_scatter", "pshuffle",
}
# Array-producing calls with data-dependent output shape.
_DYNAMIC_SHAPE_FNS = {"nonzero", "unique", "argwhere", "flatnonzero",
                      "extract", "compress"}
# Attribute accesses that yield static Python values even on tracers.
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype", "itemsize"}


def _last_attr(node: ast.AST) -> Optional[str]:
    """Rightmost name of a Name/Attribute chain (``jax.lax.scan`` → scan)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


class _FuncInfo:
    __slots__ = ("node", "qual", "module", "is_root", "uses_jnp", "refs",
                 "attr_refs", "children")

    def __init__(self, node: ast.AST, qual: str, module: Module):
        self.node = node
        self.qual = qual
        self.module = module
        self.is_root = False
        self.uses_jnp = False
        self.refs: Set[str] = set()              # bare names mentioned
        self.attr_refs: Set[Tuple[str, str]] = set()   # (alias, name)
        self.children: List[str] = []            # nested defs' quals


class JitIndex:
    """Cross-module map of functions, jit roots, and reference edges."""

    def __init__(self, ctx: LintContext):
        self.ctx = ctx
        # (module_rel, qualname) -> _FuncInfo
        self.funcs: Dict[Tuple[str, str], _FuncInfo] = {}
        # module_rel -> {alias -> dotted module it refers to}
        self.aliases: Dict[str, Dict[str, str]] = {}
        # module_rel -> {bare function name -> [quals]} (any nesting depth)
        self.by_name: Dict[str, Dict[str, List[str]]] = {}
        for mod in ctx.modules:
            self._index_module(mod)
        self._mark_hof_roots()
        self.reachable = self._bfs()

    # -- indexing -----------------------------------------------------------

    def _index_module(self, mod: Module) -> None:
        self.aliases[mod.rel] = _import_aliases(mod)
        names: Dict[str, List[str]] = {}
        self.by_name[mod.rel] = names

        def walk_scope(body, prefix: str, parent: Optional[_FuncInfo]):
            for node in body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = f"{prefix}{node.name}"
                    info = _FuncInfo(node, qual, mod)
                    info.is_root = _is_jit_decorated(node)
                    _scan_body(node, info)
                    self.funcs[(mod.rel, qual)] = info
                    names.setdefault(node.name, []).append(qual)
                    if parent is not None:
                        parent.children.append(qual)
                    walk_scope(node.body, qual + ".", info)
                elif isinstance(node, ast.ClassDef):
                    walk_scope(node.body, f"{prefix}{node.name}.", parent)
                elif hasattr(node, "body") and not isinstance(node, ast.Lambda):
                    inner = getattr(node, "body", [])
                    if isinstance(inner, list):
                        walk_scope(inner, prefix, parent)
                    for extra in ("orelse", "finalbody"):
                        eb = getattr(node, extra, None)
                        if isinstance(eb, list):
                            walk_scope(eb, prefix, parent)
                    for h in getattr(node, "handlers", []) or []:
                        walk_scope(h.body, prefix, parent)

        walk_scope(mod.tree.body, "", None)

    def _mark_hof_roots(self) -> None:
        """Functions handed by name to a tracing HOF become roots."""
        for mod in self.ctx.modules:
            names = self.by_name[mod.rel]
            for call in ast.walk(mod.tree):
                if not isinstance(call, ast.Call):
                    continue
                head = _last_attr(call.func)
                if head == "partial":
                    # functools.partial(jax.jit, ...) → treat like jit(...)
                    if call.args and _last_attr(call.args[0]) in _TRACING_HOFS:
                        call_args = call.args[1:]
                    else:
                        continue
                elif head in _TRACING_HOFS:
                    call_args = list(call.args)
                else:
                    continue
                cands = call_args + [kw.value for kw in call.keywords]
                for arg in cands:
                    if isinstance(arg, ast.Name) and arg.id in names:
                        for qual in names[arg.id]:
                            self.funcs[(mod.rel, qual)].is_root = True

    def _bfs(self) -> Set[Tuple[str, str]]:
        seen: Set[Tuple[str, str]] = set()
        frontier = [k for k, f in self.funcs.items() if f.is_root]
        while frontier:
            key = frontier.pop()
            if key in seen or key not in self.funcs:
                continue
            seen.add(key)
            info = self.funcs[key]
            frontier.extend((key[0], c) for c in info.children)
            frontier.extend(self._resolve_edges(info))
        return seen

    def _resolve_edges(self, info: _FuncInfo):
        mod_rel = info.module.rel
        names = self.by_name[mod_rel]
        for name in info.refs:
            for qual in names.get(name, ()):          # bare-name fallback:
                yield (mod_rel, qual)                 # any nesting depth
        for alias, name in info.attr_refs:
            target = self.aliases[mod_rel].get(alias)
            if target is None:
                continue
            tmod = self.ctx.by_dotted.get(target)
            if tmod is None:
                continue
            for qual in self.by_name.get(tmod.rel, {}).get(name, ()):
                if "." not in qual:                   # only top-level names
                    yield (tmod.rel, qual)


def _import_aliases(mod: Module) -> Dict[str, str]:
    """alias -> dotted module, resolving relative imports against mod.dotted."""
    out: Dict[str, str] = {}
    pkg_parts = mod.dotted.split(".")[:-1]            # containing package
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base = pkg_parts[: len(pkg_parts) - (node.level - 1)]
                prefix = ".".join(base + ([node.module] if node.module else []))
            else:
                prefix = node.module or ""
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = (f"{prefix}.{a.name}"
                                           if prefix else a.name)
    return out


def _is_jit_decorated(node) -> bool:
    for dec in node.decorator_list:
        if _last_attr(dec) in ("jit", "pmap"):
            return True
        if isinstance(dec, ast.Call):
            head = _last_attr(dec.func)
            if head in ("jit", "pmap"):
                return True
            if head == "partial" and dec.args and \
                    _last_attr(dec.args[0]) in ("jit", "pmap"):
                return True
    return False


def _scan_body(fn_node, info: _FuncInfo) -> None:
    """Collect reference edges + jnp usage from a function's own statements
    (nested defs are indexed separately; their refs stay their own)."""
    for node in _own_nodes(fn_node):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            info.refs.add(node.id)
            if node.id in ("jnp", "jax", "lax"):
                info.uses_jnp = True
        elif isinstance(node, ast.Attribute):
            if isinstance(node.value, ast.Name):
                info.attr_refs.add((node.value.id, node.attr))
        elif isinstance(node, ast.Call):
            if _last_attr(node.func) in _COLLECTIVES:
                info.is_root = True


def _own_nodes(fn_node) -> Iterable[ast.AST]:
    """All AST nodes of a function excluding nested function bodies."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(fn_node))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


# ---------------------------------------------------------------------------
# LF001 — dynamic-shape / host-sync ops inside traced code
# ---------------------------------------------------------------------------


def _has_nonstatic_name(node: ast.AST, static: Set[str] = frozenset()) -> bool:
    """True when the expression mentions a value that could be a tracer —
    i.e. it is not built purely from constants, shapes, lens, dtypes, and
    names already known static (``static``)."""
    if isinstance(node, ast.Constant):
        return False
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return False
        return _has_nonstatic_name(node.value, static)
    if isinstance(node, ast.Call):
        head = _last_attr(node.func)
        if head in ("len", "bit_length"):
            return False
        if head in ("range", "enumerate", "min", "max", "abs", "round",
                    "int", "float", "bool"):
            args = list(node.args) + [kw.value for kw in node.keywords]
            return any(_has_nonstatic_name(a, static) for a in args)
        return True                    # unknown call: may return an array
    if isinstance(node, ast.Name):
        return node.id not in static
    return any(_has_nonstatic_name(c, static)
               for c in ast.iter_child_nodes(node))


_SCALAR_ANNOTATIONS = {"int", "float", "bool", "str"}


def _static_locals(fn_node) -> Set[str]:
    """Names provably static inside this function: parameters annotated
    with a Python scalar type, plus locals assigned from static-only
    expressions (a single forward pass in source order — shape-derived
    chains like ``dh = x.shape[-1]; d = int(dh * f)`` resolve)."""
    static: Set[str] = set()
    a = fn_node.args
    for p in a.posonlyargs + a.args + a.kwonlyargs:
        ann = p.annotation
        if isinstance(ann, ast.Name) and ann.id in _SCALAR_ANNOTATIONS:
            static.add(p.arg)
    stmts = sorted((n for n in _own_nodes(fn_node)
                    if isinstance(n, (ast.Assign, ast.AugAssign))),
                   key=lambda n: (n.lineno, n.col_offset))
    for node in stmts:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            if _has_nonstatic_name(node.value, static):
                static.discard(name)
            else:
                static.add(name)
        elif isinstance(node, ast.AugAssign) and \
                isinstance(node.target, ast.Name):
            if _has_nonstatic_name(node.value, static):
                static.discard(node.target.id)
    return static


def _is_boolean_mask(expr: ast.AST) -> bool:
    if isinstance(expr, ast.Compare):
        return True
    if isinstance(expr, ast.BoolOp):
        return True
    if isinstance(expr, ast.UnaryOp) and isinstance(expr.op, (ast.Invert,
                                                              ast.Not)):
        return _is_boolean_mask(expr.operand)
    return False


@rule("LF001", "dynamic-shape / host-sync ops inside jit-traced code")
def lf001(ctx: LintContext) -> Iterable[Finding]:
    """Data-dependent shapes (``jnp.nonzero``/``unique``/boolean-mask
    indexing) and host syncs (``.item()``, ``int()``/``float()`` on a likely
    tracer) break tracing — or worse, silently sync — inside any function XLA
    traces.  The engine's whole design (padded slabs, fixed-capacity survivor
    buffers, sentinel rows) exists to avoid these; this rule keeps them out."""
    index = JitIndex(ctx)
    for key in sorted(index.reachable):
        info = index.funcs[key]
        mod = info.module
        static = _static_locals(info.node)
        for node in _own_nodes(info.node):
            if isinstance(node, ast.Call):
                head = _last_attr(node.func)
                if head in _DYNAMIC_SHAPE_FNS:
                    yield Finding(
                        "LF001", mod.rel, node.lineno,
                        f"`{head}` has a data-dependent output shape; "
                        f"inside jit-reachable `{info.qual}` use a masked "
                        "fixed-capacity formulation instead")
                elif head == "where" and len(node.args) == 1:
                    yield Finding(
                        "LF001", mod.rel, node.lineno,
                        "single-argument `where` has a data-dependent "
                        f"output shape inside jit-reachable `{info.qual}`; "
                        "use the three-argument select form")
                elif head in ("item", "tolist") and not node.args:
                    yield Finding(
                        "LF001", mod.rel, node.lineno,
                        f"`.{head}()` forces a host sync inside "
                        f"jit-reachable `{info.qual}`")
                elif (isinstance(node.func, ast.Name)
                      and node.func.id in ("int", "float", "bool")
                      and info.uses_jnp and len(node.args) == 1
                      and _has_nonstatic_name(node.args[0], static)):
                    yield Finding(
                        "LF001", mod.rel, node.lineno,
                        f"`{node.func.id}(...)` on a possibly-traced value "
                        f"inside jit-reachable `{info.qual}` concretizes the "
                        "tracer (shape/len/dtype-derived values are exempt)")
            elif isinstance(node, ast.Subscript):
                if _is_boolean_mask(node.slice):
                    yield Finding(
                        "LF001", mod.rel, node.lineno,
                        "boolean-mask indexing has a data-dependent output "
                        f"shape inside jit-reachable `{info.qual}`; use "
                        "`jnp.where(mask, x, fill)` or a masked reduction")


# ---------------------------------------------------------------------------
# LF004 — recompile hazards at jitted call sites
# ---------------------------------------------------------------------------


def _jit_static_params(mod: Module) -> Dict[str, Tuple[Tuple[str, ...],
                                                       Tuple[str, ...]]]:
    """name -> (static_argnames, positional params of the jitted def).

    Covers ``@partial(jax.jit, static_argnames=...)`` decorators and
    ``g = jax.jit(f, static_argnames=...)`` assignments within the module.
    """
    defs: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.FunctionDef):
            defs.setdefault(node.name, node)

    def statics_from_call(call: ast.Call) -> Optional[Tuple[str, ...]]:
        for kw in call.keywords:
            if kw.arg in ("static_argnames", "static_argnums"):
                names: List[str] = []
                vals = kw.value.elts if isinstance(
                    kw.value, (ast.Tuple, ast.List)) else [kw.value]
                for v in vals:
                    if isinstance(v, ast.Constant):
                        names.append(str(v.value))
                return tuple(names)
        return ()

    out: Dict[str, Tuple[Tuple[str, ...], Tuple[str, ...]]] = {}

    def params_of(fn: ast.FunctionDef) -> Tuple[str, ...]:
        a = fn.args
        return tuple(p.arg for p in a.posonlyargs + a.args)

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call) and \
                        _last_attr(dec.func) == "partial" and dec.args and \
                        _last_attr(dec.args[0]) == "jit":
                    st = statics_from_call(dec)
                    # static_argnums → map to names via the def
                    named = _nums_to_names(st, dec, node)
                    out[node.name] = (named, params_of(node))
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            call = node.value
            if _last_attr(call.func) == "jit" and call.args:
                inner = call.args[0]
                if isinstance(inner, ast.Name) and inner.id in defs:
                    st = statics_from_call(call)
                    named = _nums_to_names(st, call, defs[inner.id])
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            out[tgt.id] = (named, params_of(defs[inner.id]))
    return out


def _nums_to_names(statics, call: ast.Call,
                   fn: ast.FunctionDef) -> Tuple[str, ...]:
    params = [p.arg for p in fn.args.posonlyargs + fn.args.args]
    named: List[str] = []
    for s in statics or ():
        if s.isdigit() and int(s) < len(params):
            named.append(params[int(s)])
        else:
            named.append(s)
    return tuple(named)


_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
               ast.SetComp, ast.GeneratorExp)


@rule("LF004", "recompile hazards at jitted call sites")
def lf004(ctx: LintContext) -> Iterable[Finding]:
    """A jitted callable keyed on static args recompiles per distinct value:
    passing an unhashable literal is a ``TypeError`` at runtime, and passing
    the loop variable of the enclosing ``for`` re-traces every iteration —
    the serving layer's ``(bucket, k)`` program-cache discipline exists
    precisely to bound this."""
    for mod in ctx.modules:
        table = _jit_static_params(mod)
        if not table:
            continue
        # call-site walk with enclosing for-loop targets tracked
        def visit(node, loop_vars: Set[str]):
            if isinstance(node, ast.For):
                inner = set(loop_vars)
                for t in ast.walk(node.target):
                    if isinstance(t, ast.Name):
                        inner.add(t.id)
                for child in node.body + node.orelse:
                    yield from visit(child, inner)
                return
            if isinstance(node, ast.Call):
                callee = node.func.id if isinstance(node.func, ast.Name) \
                    else None
                if callee in table:
                    statics, params = table[callee]
                    yield from _check_site(node, callee, statics, params,
                                           loop_vars, mod)
            for child in ast.iter_child_nodes(node):
                yield from visit(child, loop_vars)

        for top in mod.tree.body:
            yield from visit(top, set())


def _check_site(call: ast.Call, callee: str, statics, params,
                loop_vars: Set[str], mod: Module) -> Iterable[Finding]:
    bound: List[Tuple[str, ast.AST]] = []
    for i, arg in enumerate(call.args):
        if isinstance(arg, ast.Starred):
            return                        # cannot map positions past *args
        if i < len(params):
            bound.append((params[i], arg))
    for kw in call.keywords:
        if kw.arg is not None:
            bound.append((kw.arg, kw.value))
    for name, expr in bound:
        if name not in statics:
            continue
        if isinstance(expr, _UNHASHABLE):
            yield Finding(
                "LF004", mod.rel, call.lineno,
                f"unhashable literal bound to static arg `{name}` of jitted "
                f"`{callee}` — jit static args must be hashable (use a "
                "tuple)")
        elif isinstance(expr, ast.Name) and expr.id in loop_vars:
            yield Finding(
                "LF004", mod.rel, call.lineno,
                f"loop variable `{expr.id}` bound to static arg `{name}` of "
                f"jitted `{callee}` re-traces every iteration; hoist or "
                "bucket it (pow2 buckets bound the program cache)")
