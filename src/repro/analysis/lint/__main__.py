"""CLI entry point: ``python -m repro.analysis.lint [paths] ...``."""
from __future__ import annotations

import argparse
import sys

from . import RULES, render, run_lint


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="LeaFi invariant linter (exit 0 clean, 1 findings, "
                    "2 linter failure)")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to lint (default: src)")
    parser.add_argument("--root", default=".",
                        help="repo root for cross-file contracts "
                             "(tests/, benchmarks/, Makefile); default: .")
    parser.add_argument("--format", choices=("human", "json"),
                        default="human")
    parser.add_argument("--rules", default=None,
                        help="comma-separated rule ids (default: all)")
    parser.add_argument("--list-rules", action="store_true")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rid in sorted(RULES):
            print(f"{rid}  {RULES[rid].title}")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip().upper() for r in args.rules.split(",") if r.strip()]
    report = run_lint(args.paths or ["src"], root=args.root, rules=rules)
    print(render(report, args.format))
    return report.exit_code()


if __name__ == "__main__":
    sys.exit(main())
