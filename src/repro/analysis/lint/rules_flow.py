"""Dataflow rule: LF003 — no reads after buffer donation.

``donate_argnums`` tells XLA it may alias an input buffer into the output;
reading the donated array afterwards returns garbage (or raises, backend-
dependent) — precisely the aliasing bug the pipelined serving path had to
design around (at most one in-flight batch per program, see
``serving/session.py``).  The rule tracks, per function scope:

* which local callables are *donating* — assigned from a call that carries
  ``donate_argnums=``/``donate_argnames=``, a ``donate=`` flag, or a
  ``**kw`` whose name mentions donation (the ``jax.jit(run_pq,
  **donate_kw)`` idiom), including tuple-unpacked and ``self.x`` targets
  and decorator form ``@partial(jax.jit, donate_argnums=...)``;
* which variable names were passed in a donated position at a call of such
  a callable;
* any later ``Load`` of those names in the same scope (rebinding clears the
  taint; reads lexically inside the donating call itself are fine — args
  are consumed before the call donates).

Scope-local on purpose: cross-function escape analysis would drown the
signal in false positives.  Nested ``def`` bodies are separate scopes.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .framework import Finding, LintContext, Module, rule
from .rules_jit import _last_attr


def _donated_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """Donated positional indices if this call creates a donating callable.

    () means "donating, positions unknown" (donate every positional arg);
    None means not a donation site at all.
    """
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            val = kw.value
            elts = val.elts if isinstance(val, (ast.Tuple, ast.List)) else [val]
            nums = tuple(e.value for e in elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, int))
            return nums or ()
        if kw.arg == "donate":
            if isinstance(kw.value, ast.Constant) and kw.value.value is False:
                return None
            return ()
        if kw.arg is None and isinstance(kw.value, ast.Name) \
                and "donate" in kw.value.id.lower():
            return ()                      # jax.jit(f, **donate_kw)
    return None


def _target_names(target: ast.AST) -> Iterable[str]:
    """Bindable names in an assignment target: x, self.x, (a, b) unpack."""
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, ast.Attribute) and \
            isinstance(target.value, ast.Name) and target.value.id == "self":
        yield f"self.{target.attr}"
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from _target_names(elt)


def _callee_key(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute) and \
            isinstance(func.value, ast.Name) and func.value.id == "self":
        return f"self.{func.attr}"
    return None


class _Scope:
    """Linear walk of one function body in source order."""

    def __init__(self, mod: Module,
                 donating: Dict[str, Tuple[int, ...]]):
        self.mod = mod
        self.donating = donating
        # name -> (donation position, callee) of the pending donation
        self.tainted: Dict[str, Tuple[Tuple[int, int], str]] = {}
        self.findings: List[Finding] = []

    def run(self, body: List[ast.stmt]) -> List[Finding]:
        for stmt in body:
            self._stmt(stmt)
        return self.findings

    def _stmt(self, node: ast.AST) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return                         # separate scope
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            # evaluation order: RHS first (a donating call taints its
            # args), then the binding clears taint — so the
            # `x, y = step(x, y)` rebind idiom stays clean.
            if node.value is not None:
                for expr in _exprs_in_order(node.value, as_root=True):
                    self._expr(expr)
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                for expr in _exprs_in_order(tgt, as_root=True):
                    self._expr(expr)
            return
        for expr in _exprs_in_order(node):
            self._expr(expr)
        for block in ("body", "orelse", "finalbody"):
            for child in getattr(node, block, []) or []:
                if isinstance(child, ast.stmt):
                    self._stmt(child)
        for h in getattr(node, "handlers", []) or []:
            for child in h.body:
                self._stmt(child)

    def _expr(self, node: ast.AST) -> None:
        pos = (node.lineno, node.col_offset)
        if isinstance(node, ast.Name):
            if isinstance(node.ctx, ast.Load):
                hit = self.tainted.get(node.id)
                if hit is not None and pos > hit[0]:
                    self.findings.append(Finding(
                        "LF003", self.mod.rel, node.lineno,
                        f"`{node.id}` is read after being donated to "
                        f"`{hit[1]}` — the buffer may already be aliased "
                        "into the output; recompute or copy before donating"))
            elif isinstance(node.ctx, ast.Store):
                self.tainted.pop(node.id, None)
        elif isinstance(node, ast.Call):
            key = _callee_key(node.func)
            if key is not None and key in self.donating:
                positions = self.donating[key]
                end = (getattr(node, "end_lineno", node.lineno),
                       getattr(node, "end_col_offset", node.col_offset))
                for i, arg in enumerate(node.args):
                    if positions and i not in positions:
                        continue
                    if isinstance(arg, ast.Name):
                        self.tainted[arg.id] = (end, key)


def _exprs_in_order(stmt: ast.AST, as_root: bool = False) -> List[ast.AST]:
    """All expression nodes of a statement (not nested stmts/defs), in
    (line, col) order so donation/read/rebind events sequence correctly.
    With ``as_root`` the node itself is an expression and is included."""
    out: List[ast.AST] = []
    stack: List[ast.AST] = []
    if as_root:
        stack.append(stmt)
    else:
        for child in ast.iter_child_nodes(stmt):
            if not isinstance(child, (ast.stmt, ast.excepthandler)):
                stack.append(child)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        if hasattr(node, "lineno"):
            out.append(node)
        stack.extend(c for c in ast.iter_child_nodes(node)
                     if not isinstance(c, ast.stmt))
    out.sort(key=lambda n: (n.lineno, n.col_offset))
    return out


def _collect_donating(mod: Module) -> Dict[str, Tuple[int, ...]]:
    """Module-wide table of donating callables (incl. self.x methods —
    an __init__-created jitted runner is invoked from other methods)."""
    table: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            positions = _donated_positions(node.value)
            if positions is None:
                continue
            for tgt in node.targets:
                names = list(_target_names(tgt))
                for name in names:
                    if name == "_":
                        continue
                    # tuple unpack: which element is the callable is unknown
                    # — taint all bound names; non-callables are never
                    # invoked, so they add no findings.
                    table[name] = positions if len(names) == 1 else ()
        elif isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call):
                    positions = _donated_positions(dec)
                    if positions is not None and \
                            _last_attr(dec.func) in ("jit", "partial", "pmap"):
                        table[node.name] = positions
    return table


@rule("LF003", "no reads after buffer donation")
def lf003(ctx: LintContext) -> Iterable[Finding]:
    """A value handed to a ``donate_argnums``/``donate=`` callable must not
    be read afterwards in the same scope — XLA may have reused its buffer
    for the output."""
    for mod in ctx.modules:
        donating = _collect_donating(mod)
        if not donating:
            continue
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from _Scope(mod, donating).run(node.body)
