"""Qwen1.5-32B — dense, MHA 40 heads (kv=40), QKV bias.
[hf:Qwen/Qwen1.5-0.5B (family); hf] — 40 heads ∤ 16 ⇒ context-parallel
attention policy on the production mesh (DESIGN.md §sharding)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", n_layers=64, d_model=5120,
    n_heads=40, n_kv_heads=40, d_head=128, d_ff=27392, vocab=152064,
    qkv_bias=True, rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen1.5-32b-smoke", n_layers=2, d_model=160,
    n_heads=5, n_kv_heads=5, d_head=32, d_ff=448, vocab=512,
    qkv_bias=True, rope_theta=1e6, dtype="float32", remat=False,
)
