"""Mixtral-8x7B — MoE 8 experts top-2, GQA 32q/8kv, SWA window 4096.
[arXiv:2401.04088; hf]  Pure-SWA stack ⇒ ring KV cache bounds 500k decode."""
from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_head=128, d_ff=14336, vocab=32000,
    attn_window=4096, rope_theta=1e6,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336),
)

SMOKE = ArchConfig(
    name="mixtral-8x7b-smoke", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_head=32, d_ff=256, vocab=512,
    attn_window=64, rope_theta=1e6,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=256),
    dtype="float32", remat=False,
)
