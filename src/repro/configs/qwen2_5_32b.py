"""Qwen2.5-32B — dense, GQA 40q/8kv heads, QKV bias.
[hf:Qwen/Qwen2.5-0.5B (family); hf] — 40 heads ∤ 16 ⇒ context-parallel."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b", n_layers=64, d_model=5120,
    n_heads=40, n_kv_heads=8, d_head=128, d_ff=27648, vocab=152064,
    qkv_bias=True, rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen2.5-32b-smoke", n_layers=2, d_model=160,
    n_heads=5, n_kv_heads=1, d_head=32, d_ff=448, vocab=512,
    qkv_bias=True, rope_theta=1e6, dtype="float32", remat=False,
)
