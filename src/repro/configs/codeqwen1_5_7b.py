"""CodeQwen1.5-7B — qwen1.5 arch, MHA (kv=heads), QKV bias.
[hf:Qwen/CodeQwen1.5-7B; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=32, d_head=128, d_ff=13440, vocab=92416,
    qkv_bias=True, rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="codeqwen1.5-7b-smoke", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_head=32, d_ff=384, vocab=512,
    qkv_bias=True, rope_theta=1e6, dtype="float32", remat=False,
)
