"""GLM4-9B — dense, GQA 32q/2kv, partial (half) rotary, QKV bias.
[hf:THUDM/glm-4-9b; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=2, d_head=128, d_ff=13696, vocab=151552,
    qkv_bias=True, partial_rotary=0.5, rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="glm4-9b-smoke", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_head=32, d_ff=384, vocab=512,
    qkv_bias=True, partial_rotary=0.5, rope_theta=1e4,
    dtype="float32", remat=False,
)
