"""Assigned architecture registry + input-shape cells.

Each ``<id>.py`` exports ``CONFIG`` (the exact published configuration) and
``SMOKE`` (a reduced same-family config for CPU tests).  The four assigned
input shapes and per-cell applicability rules live here.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig

ARCH_IDS = [
    "codeqwen1_5_7b", "qwen1_5_32b", "qwen2_5_32b", "glm4_9b", "rwkv6_1_6b",
    "mixtral_8x7b", "qwen2_moe_a2_7b", "musicgen_large", "pixtral_12b",
    "hymba_1_5b",
]

# public ids (dashes) ↔ module names (underscores)
PUBLIC_IDS = {i.replace("_", "-"): i for i in ARCH_IDS}
PUBLIC_IDS.update({
    "codeqwen1.5-7b": "codeqwen1_5_7b", "qwen1.5-32b": "qwen1_5_32b",
    "qwen2.5-32b": "qwen2_5_32b", "glm4-9b": "glm4_9b",
    "rwkv6-1.6b": "rwkv6_1_6b", "mixtral-8x7b": "mixtral_8x7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b", "musicgen-large": "musicgen_large",
    "pixtral-12b": "pixtral_12b", "hymba-1.5b": "hymba_1_5b",
})


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(
        f".{PUBLIC_IDS.get(name, name)}", __package__)
    return mod.CONFIG


def get_smoke(name: str) -> ArchConfig:
    mod = importlib.import_module(
        f".{PUBLIC_IDS.get(name, name)}", __package__)
    return mod.SMOKE


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str            # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}

# long_500k needs sub-quadratic attention / bounded KV (DESIGN.md §skips):
LONG_CONTEXT_ARCHS = {"rwkv6_1_6b", "hymba_1_5b", "mixtral_8x7b"}


def supports_shape(arch: str, shape: str) -> bool:
    arch = PUBLIC_IDS.get(arch, arch)
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def decode_cache_len(cfg: ArchConfig, seq: int) -> int:
    """KV slots needed to decode at position `seq`.

    Pure-SWA stacks (mixtral) need only a window-sized ring; stacks with any
    full-attention layer need the whole prefix; attention-free stacks keep a
    single slot placeholder (their state is the recurrent one)."""
    if cfg.layer_kind == "rwkv6":
        return 1
    if cfg.attn_window and not cfg.global_attn_layers:
        return min(seq, cfg.attn_window)
    return seq


def input_specs(arch: str, shape_name: str, smoke: bool = False,
                overrides: Optional[dict] = None) -> Dict[str, object]:
    """ShapeDtypeStruct stand-ins for every model input of one cell.

    Keys: 'inputs' (token/embed dict incl. labels for train), plus for decode
    'cache' and 'pos'.  No device allocation — dry-run food.
    ``overrides`` patches config fields (e.g. kv_quant for §Perf variants).
    """
    from ..models import transformer
    cfg = get_smoke(arch) if smoke else get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    B, S = shape.batch, shape.seq
    sd = jax.ShapeDtypeStruct
    dt = jnp.dtype(cfg.dtype)

    def token_inputs(seq_len: int, with_labels: bool, decode: bool = False):
        d: Dict[str, object] = {}
        if cfg.input_mode == "tokens":
            d["tokens"] = sd((B, seq_len), jnp.int32)
        elif cfg.input_mode == "embeddings":
            d["embeds"] = sd((B, seq_len, cfg.d_model), dt)
        else:  # mixed
            if decode:
                d["tokens"] = sd((B, seq_len), jnp.int32)
                d["patches"] = sd((B, 0, cfg.d_model), dt)
            else:
                n_img = int(seq_len * cfg.patch_frac)
                d["tokens"] = sd((B, seq_len - n_img), jnp.int32)
                d["patches"] = sd((B, n_img, cfg.d_model), dt)
        if with_labels:
            n_lbl = d["tokens"].shape[1] if "tokens" in d else seq_len
            d["labels"] = sd((B, n_lbl), jnp.int32)
        return d

    out: Dict[str, object] = {"config": cfg, "shape": shape}
    if shape.kind == "train":
        out["inputs"] = token_inputs(S, with_labels=True)
    elif shape.kind == "prefill":
        out["inputs"] = token_inputs(S, with_labels=False)
        out["cache_len"] = decode_cache_len(cfg, S)
    else:  # decode
        out["inputs"] = token_inputs(1, with_labels=False, decode=True)
        clen = decode_cache_len(cfg, S)
        out["cache"] = jax.eval_shape(
            lambda: transformer.init_cache(cfg, B, clen))
        out["pos"] = sd((), jnp.int32)
        out["cache_len"] = clen
    return out
