"""MusicGen-large backbone — decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]  Modality frontend is a STUB: input_specs provides
precomputed frame embeddings (input_mode='embeddings'); GELU MLP, sinusoidal
positions (adaptation of the learned offsets noted in DESIGN.md)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=32, d_head=64, d_ff=8192, vocab=2048,
    mlp_kind="gelu", pos_mode="sinusoid", input_mode="embeddings",
)

SMOKE = ArchConfig(
    name="musicgen-large-smoke", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_head=32, d_ff=256, vocab=128,
    mlp_kind="gelu", pos_mode="sinusoid", input_mode="embeddings",
    dtype="float32", remat=False,
)
