"""RWKV6 "Finch" 1.6B — attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]  heads = d_model/64; channel-mix FFN."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=32, d_head=64, d_ff=7168, vocab=65536,
    layer_kind="rwkv6", mlp_kind="rwkv_cm", pos_mode="none",
)

SMOKE = ArchConfig(
    name="rwkv6-1.6b-smoke", n_layers=2, d_model=128,
    n_heads=2, n_kv_heads=2, d_head=64, d_ff=448, vocab=512,
    layer_kind="rwkv6", mlp_kind="rwkv_cm", pos_mode="none",
    dtype="float32", remat=False,
)
