"""Qwen1.5-MoE-A2.7B — 60 routed experts top-4 + 4 shared, MHA 16H, QKV bias.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]  d_shared = 4 × 1408 (fused shared expert)."""
from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_head=128, d_ff=1408, vocab=151936,
    qkv_bias=True, rope_theta=1e6,
    moe=MoEConfig(n_experts=60, top_k=4, d_expert=1408,
                  n_shared=4, d_shared=5632),
)

SMOKE = ArchConfig(
    name="qwen2-moe-a2.7b-smoke", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_head=32, d_ff=96, vocab=512,
    qkv_bias=True, rope_theta=1e6,
    moe=MoEConfig(n_experts=8, top_k=4, d_expert=96,
                  n_shared=2, d_shared=192),
    dtype="float32", remat=False,
)
