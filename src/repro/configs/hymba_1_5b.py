"""Hymba-1.5B — hybrid: parallel attention + mamba heads per layer.
[arXiv:2411.13676; hf]  25 heads × 64 = 1600; GQA kv=5; ssm_state=16;
SWA everywhere except 3 global-attention layers (first/middle/last).
25 heads ∤ 16 ⇒ context-parallel attention policy on the production mesh."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, d_head=64, d_ff=5504, vocab=32001,
    layer_kind="hymba", ssm_state=16, ssm_expand=2,
    attn_window=1024, global_attn_layers=(0, 15, 31), rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="hymba-1.5b-smoke", n_layers=2, d_model=128,
    n_heads=2, n_kv_heads=1, d_head=64, d_ff=256, vocab=512,
    layer_kind="hymba", ssm_state=16, ssm_expand=2,
    attn_window=32, global_attn_layers=(0,), rope_theta=1e4,
    dtype="float32", remat=False,
)
