"""Pixtral-12B backbone — mistral-nemo-style decoder, GQA 32q/8kv.
[hf:mistralai/Pixtral-12B-2409; unverified]  Vision frontend is a STUB:
input_specs provides precomputed patch embeddings concatenated before the
text tokens (input_mode='mixed')."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_head=128, d_ff=14336, vocab=131072,
    rope_theta=1e6, input_mode="mixed", patch_frac=0.25,
)

SMOKE = ArchConfig(
    name="pixtral-12b-smoke", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_head=32, d_ff=256, vocab=512,
    rope_theta=1e6, input_mode="mixed", patch_frac=0.25,
    dtype="float32", remat=False,
)
