"""Composable decoder stack: one definition, ten architectures.

Scan-over-layers with stacked parameters (compile time and HLO size are
O(1) in depth — essential for 64-layer dry-runs), remat per layer, and a
per-layer ``window`` vector so heterogeneous stacks (hymba's 3 global-attn
layers among SWA layers) stay scan-homogeneous.

Execution modes:
* ``forward``        — logits for a full sequence (training / prefill).
* ``forward_decode`` — one token against per-layer caches (KV ring buffers
                        for attention, recurrent states for rwkv6/mamba).

All functions are pure; sharding is applied by the launchers via
``sharding.param_specs`` + in/out shardings on the jitted steps.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import attention, layers, mamba, moe, rwkv6
from .config import ArchConfig

PyTree = Any


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def _init_one_layer(cfg: ArchConfig, key: jax.Array) -> Dict[str, jnp.ndarray]:
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    ks = iter(jax.random.split(key, 16))
    p: Dict[str, jnp.ndarray] = {
        "ln1": jnp.zeros((D,), jnp.float32),
        "ln2": jnp.zeros((D,), jnp.float32),
    }
    s = 1.0 / jnp.sqrt(D)
    if cfg.layer_kind in ("attn", "hymba"):
        p["wq"] = (jax.random.normal(next(ks), (D, cfg.n_heads, cfg.d_head)) * s).astype(dt)
        p["wk"] = (jax.random.normal(next(ks), (D, cfg.n_kv_heads, cfg.d_head)) * s).astype(dt)
        p["wv"] = (jax.random.normal(next(ks), (D, cfg.n_kv_heads, cfg.d_head)) * s).astype(dt)
        p["wo"] = (jax.random.normal(next(ks), (cfg.n_heads, cfg.d_head, D)) * s).astype(dt)
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((cfg.n_heads, cfg.d_head), dt)
            p["bk"] = jnp.zeros((cfg.n_kv_heads, cfg.d_head), dt)
            p["bv"] = jnp.zeros((cfg.n_kv_heads, cfg.d_head), dt)
    if cfg.layer_kind == "rwkv6":
        p.update(rwkv6.init_layer(next(ks), D, dt))
    if cfg.layer_kind == "hymba":
        p.update(mamba.init_layer(next(ks), D, cfg.ssm_state, cfg.ssm_expand, dt))
        p["attn_norm"] = jnp.ones((D,), jnp.float32)
    if cfg.moe is not None:
        p.update(moe.init_layer(next(ks), D, cfg.moe, dt))
    elif cfg.mlp_kind == "swiglu":
        sf = 1.0 / jnp.sqrt(cfg.d_ff)
        p["w_in"] = (jax.random.normal(next(ks), (D, cfg.d_ff)) * s).astype(dt)
        p["w_gate"] = (jax.random.normal(next(ks), (D, cfg.d_ff)) * s).astype(dt)
        p["w_out"] = (jax.random.normal(next(ks), (cfg.d_ff, D)) * sf).astype(dt)
    elif cfg.mlp_kind == "gelu":
        sf = 1.0 / jnp.sqrt(cfg.d_ff)
        p["w_in"] = (jax.random.normal(next(ks), (D, cfg.d_ff)) * s).astype(dt)
        p["b_in"] = jnp.zeros((cfg.d_ff,), dt)
        p["w_out"] = (jax.random.normal(next(ks), (cfg.d_ff, D)) * sf).astype(dt)
        p["b_out"] = jnp.zeros((D,), dt)
    elif cfg.mlp_kind == "rwkv_cm":
        sf = 1.0 / jnp.sqrt(cfg.d_ff)
        p["cm_mix"] = jnp.zeros((2, D), dt)
        p["w_in"] = (jax.random.normal(next(ks), (D, cfg.d_ff)) * s).astype(dt)
        p["w_out"] = (jax.random.normal(next(ks), (cfg.d_ff, D)) * sf).astype(dt)
        p["w_recv"] = (jax.random.normal(next(ks), (D, D)) * s).astype(dt)
    return p


def init_params(cfg: ArchConfig, key: jax.Array) -> PyTree:
    dt = jnp.dtype(cfg.dtype)
    k_embed, k_head, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _init_one_layer(cfg, k))(layer_keys)
    params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) * 0.02).astype(dt),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "layers": stacked,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab)) * 0.02).astype(dt)
    return params


def layer_windows(cfg: ArchConfig, max_positions: int) -> jnp.ndarray:
    """(L,) per-layer attention windows.  'Huge' ≡ full causal attention."""
    full = jnp.int32(1 << 30)
    if cfg.layer_kind == "hymba":
        w = jnp.full((cfg.n_layers,), cfg.attn_window or 512, jnp.int32)
        for i in cfg.global_attn_layers:
            w = w.at[i].set(full)
        return w
    if cfg.attn_window:
        return jnp.full((cfg.n_layers,), cfg.attn_window, jnp.int32)
    return jnp.full((cfg.n_layers,), full, jnp.int32)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, cache_len: int) -> Dict[str, jnp.ndarray]:
    """Stacked (leading L) per-layer decode state."""
    dt = jnp.dtype(cfg.dtype)
    L, D = cfg.n_layers, cfg.d_model
    c: Dict[str, jnp.ndarray] = {}
    if cfg.layer_kind in ("attn", "hymba"):
        kv_dt = jnp.int8 if cfg.kv_quant else dt
        c["k"] = jnp.zeros((L, batch, cache_len, cfg.n_kv_heads, cfg.d_head),
                           kv_dt)
        c["v"] = jnp.zeros((L, batch, cache_len, cfg.n_kv_heads, cfg.d_head),
                           kv_dt)
        c["kpos"] = jnp.full((L, cache_len), -1, jnp.int32)
        if cfg.kv_quant:
            c["k_scale"] = jnp.zeros((L, batch, cache_len, cfg.n_kv_heads),
                                     jnp.float32)
            c["v_scale"] = jnp.zeros((L, batch, cache_len, cfg.n_kv_heads),
                                     jnp.float32)
    if cfg.layer_kind == "rwkv6":
        H = D // rwkv6.HEAD_DIM
        c["state"] = jnp.zeros((L, batch, H, rwkv6.HEAD_DIM, rwkv6.HEAD_DIM),
                               jnp.float32)
        c["shift_tm"] = jnp.zeros((L, batch, D), dt)
        c["shift_cm"] = jnp.zeros((L, batch, D), dt)
    if cfg.layer_kind == "hymba":
        di = cfg.ssm_expand * D
        nh = mamba.N_HEADS
        c["ssm_state"] = jnp.zeros((L, batch, nh, cfg.ssm_state,
                                    di // nh), jnp.float32)
        c["conv"] = jnp.zeros((L, batch, mamba.CONV_K - 1, di), dt)
    return c


def cache_specs(cfg: ArchConfig, rules) -> Dict[str, Any]:
    """Logical PartitionSpecs matching init_cache's structure."""
    from .sharding import spec
    s = lambda *ax: spec(rules, *ax)                    # noqa: E731
    c = {}
    if cfg.layer_kind in ("attn", "hymba"):
        c["k"] = s(None, "batch", "kv_seq", "kv_heads", "head_dim")
        c["v"] = s(None, "batch", "kv_seq", "kv_heads", "head_dim")
        c["kpos"] = s(None, None)
        if cfg.kv_quant:
            c["k_scale"] = s(None, "batch", "kv_seq", "kv_heads")
            c["v_scale"] = s(None, "batch", "kv_seq", "kv_heads")
    if cfg.layer_kind == "rwkv6":
        c["state"] = s(None, "batch", "rwkv_heads", None, None)
        c["shift_tm"] = s(None, "batch", None)
        c["shift_cm"] = s(None, "batch", None)
    if cfg.layer_kind == "hymba":
        c["ssm_state"] = s(None, "batch", "ssm_inner", None, None)
        c["conv"] = s(None, "batch", None, "ssm_inner")
    return c


# ---------------------------------------------------------------------------
# layer body
# ---------------------------------------------------------------------------


def _attn_branch(cfg: ArchConfig, p, h, qpos, kpos, window,
                 k_ext=None, v_ext=None):
    """h (B, S, D) → attention output (B, S, D).  If k_ext/v_ext are given
    they are the (cached) keys/values; otherwise self-attention on h."""
    B, S, D = h.shape
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    if k_ext is None:
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
        if cfg.qkv_bias:
            k, v = k + p["bk"], v + p["bv"]
        if cfg.pos_mode == "rope":
            k = layers.apply_rope(k, kpos, cfg.rope_theta, cfg.partial_rotary)
    else:
        k, v = k_ext, v_ext
    if cfg.pos_mode == "rope":
        q = layers.apply_rope(q, qpos, cfg.rope_theta, cfg.partial_rotary)
    o = attention.attend(q, k, v, qpos, kpos, window)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), (k if k_ext is None else None,
                                                     v if k_ext is None else None)


def _ffn(cfg: ArchConfig, p, h):
    if cfg.moe is not None:
        # decode (S == 1) never drops tokens; training uses capacity dropping
        return moe.moe_ffn(p, h, cfg.moe, no_drop=h.shape[1] == 1)
    if cfg.mlp_kind == "swiglu":
        return layers.swiglu(h, p["w_in"], p["w_gate"], p["w_out"]), 0.0
    if cfg.mlp_kind == "gelu":
        return layers.gelu_mlp(h, p["w_in"], p["b_in"], p["w_out"], p["b_out"]), 0.0
    raise ValueError(cfg.mlp_kind)


def _layer_train(cfg: ArchConfig, p, x, window, positions):
    """Full-sequence layer (training / prefill without cache return)."""
    from .sharding import maybe_constrain
    B, S, D = x.shape
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    aux = 0.0
    if cfg.layer_kind == "attn":
        o, _ = _attn_branch(cfg, p, h, positions, positions, window)
        # seq-shard the branch output: turns the row-parallel psum into a
        # reduce-scatter (§Perf iteration 3 — the baseline all-reduced the
        # full (B,S,D) residual every layer)
        o = maybe_constrain(o, "batch", "seq_act", None)
        x = x + o
    elif cfg.layer_kind == "rwkv6":
        o, _, _ = rwkv6.time_mix(p, h, jnp.zeros((B, D), h.dtype),
                                 jnp.zeros((B, D // 64, 64, 64), jnp.float32))
        x = x + o
    elif cfg.layer_kind == "hymba":
        oa, _ = _attn_branch(cfg, p, h, positions, positions, window)
        om, _, _ = mamba.ssm_branch(p, h)
        oa_n = layers.rms_norm(oa, p["attn_norm"] - 1.0, cfg.norm_eps)
        om_n = layers.rms_norm(om, jnp.zeros_like(p["attn_norm"]), cfg.norm_eps)
        x = x + 0.5 * (oa_n + om_n)
    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.mlp_kind == "rwkv_cm":
        o2 = layers.rwkv_channel_mix(
            h2, jnp.concatenate([jnp.zeros_like(h2[:, :1]), h2[:, :-1]], 1),
            p["cm_mix"], p["w_in"], p["w_out"], p["w_recv"])
    else:
        o2, aux = _ffn(cfg, p, h2)
    o2 = maybe_constrain(o2, "batch", "seq_act", None)
    return x + o2, aux


def forward(cfg: ArchConfig, params: PyTree, inputs: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequence forward → (logits (B, S, V), aux_loss)."""
    from .sharding import maybe_constrain
    x = embed_inputs(cfg, params, inputs)
    x = maybe_constrain(x, "batch", None, None)
    B, S, D = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    windows = layer_windows(cfg, S)

    def body(carry, xs):
        x, aux = carry
        p, w = xs
        x = maybe_constrain(x, "batch", "seq_act", None)
        x, a = _layer_train(cfg, p, x, w, positions)
        x = maybe_constrain(x, "batch", "seq_act", None)
        return (x, aux + a), None

    body_fn = jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, 0.0), (params["layers"], windows))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = project_vocab(cfg, params, x)
    return logits, aux


def embed_inputs(cfg: ArchConfig, params, inputs,
                 pos0: jnp.ndarray | int = 0) -> jnp.ndarray:
    if cfg.input_mode == "tokens":
        x = params["embed"][inputs["tokens"]]
    elif cfg.input_mode == "embeddings":      # musicgen: EnCodec frames (stub)
        x = inputs["embeds"].astype(jnp.dtype(cfg.dtype))
    elif cfg.input_mode == "mixed":           # pixtral: patches ++ tokens
        tok = params["embed"][inputs["tokens"]]
        x = jnp.concatenate(
            [inputs["patches"].astype(tok.dtype), tok], axis=1)
    else:
        raise ValueError(cfg.input_mode)
    if cfg.pos_mode == "sinusoid":
        S = x.shape[1]
        x = x + layers.sinusoid_positions(
            pos0 + jnp.arange(S), cfg.d_model)[None].astype(x.dtype)
    return x


def project_vocab(cfg: ArchConfig, params, x) -> jnp.ndarray:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head)


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------


def _kv_quantize(x: jnp.ndarray):
    """(…, dh) → (int8 payload, per-vector max-abs scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequantize(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _layer_decode(cfg: ArchConfig, p, x, cache_slice, window, pos):
    """x (B, 1, D); cache_slice: this layer's state (no leading L)."""
    B, _, D = x.shape
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    new_cache = dict(cache_slice)
    qpos = pos[None] if pos.ndim == 0 else pos

    def attn_with_cache():
        k_new = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
        if cfg.qkv_bias:
            kn, vn = k_new + p["bk"], v_new + p["bv"]
        else:
            kn, vn = k_new, v_new
        if cfg.pos_mode == "rope":
            kn = layers.apply_rope(kn, qpos, cfg.rope_theta, cfg.partial_rotary)
        if cfg.kv_quant:
            kn, ks = _kv_quantize(kn)
            vn, vs = _kv_quantize(vn)
            slot = pos % cache_slice["k"].shape[1]
            new_cache["k_scale"] = jax.lax.dynamic_update_slice(
                cache_slice["k_scale"], ks, (0, slot, 0))
            new_cache["v_scale"] = jax.lax.dynamic_update_slice(
                cache_slice["v_scale"], vs, (0, slot, 0))
        kv = attention.KVCache(cache_slice["k"], cache_slice["v"],
                               cache_slice["kpos"])
        kv = attention.cache_update(kv, kn, vn, pos)
        if cfg.kv_quant:
            dt = jnp.dtype(cfg.dtype)
            k_full = _kv_dequantize(kv.k, new_cache["k_scale"], dt)
            v_full = _kv_dequantize(kv.v, new_cache["v_scale"], dt)
        else:
            k_full, v_full = kv.k, kv.v
        o, _ = _attn_branch(cfg, p, h, qpos, kv.kpos, window,
                            k_ext=k_full, v_ext=v_full)
        return o, kv

    if cfg.layer_kind == "attn":
        o, kv = attn_with_cache()
        new_cache.update(k=kv.k, v=kv.v, kpos=kv.kpos)
        x = x + o
    elif cfg.layer_kind == "rwkv6":
        o, x_last, state = rwkv6.time_mix_step(
            p, h[:, 0], cache_slice["shift_tm"], cache_slice["state"])
        new_cache.update(state=state, shift_tm=x_last)
        x = x + o[:, None]
    elif cfg.layer_kind == "hymba":
        oa, kv = attn_with_cache()
        om, sstate, conv = mamba.ssm_branch_step(
            p, h[:, 0], cache_slice["ssm_state"], cache_slice["conv"])
        new_cache.update(k=kv.k, v=kv.v, kpos=kv.kpos, ssm_state=sstate,
                         conv=conv)
        oa_n = layers.rms_norm(oa, p["attn_norm"] - 1.0, cfg.norm_eps)
        om_n = layers.rms_norm(om[:, None], p["attn_norm"] * 0, cfg.norm_eps)
        x = x + 0.5 * (oa_n + om_n)

    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.mlp_kind == "rwkv_cm":
        o2 = layers.rwkv_channel_mix(
            h2, cache_slice["shift_cm"][:, None], p["cm_mix"],
            p["w_in"], p["w_out"], p["w_recv"])
        new_cache.update(shift_cm=h2[:, 0])
    else:
        o2, _ = _ffn(cfg, p, h2)
    return x + o2, new_cache


def forward_decode(cfg: ArchConfig, params: PyTree, cache: Dict,
                   token_inputs: Dict, pos: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, Dict]:
    """One decode step: token_inputs as in forward but S = 1."""
    x = embed_inputs(cfg, params, token_inputs, pos0=pos)
    windows = layer_windows(cfg, 1 << 30)

    def body(x, xs):
        p, cs, w = xs
        x, new_cs = _layer_decode(cfg, p, x, cs, w, pos)
        return x, new_cs

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache, windows))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return project_vocab(cfg, params, x), new_cache


# ---------------------------------------------------------------------------
# step factories (loss / train / prefill / decode)
# ---------------------------------------------------------------------------


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(logits.max(-1, keepdims=True))
    lse = jnp.log(jnp.exp(logits - m).sum(-1)) + m[..., 0]
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (lse - gold).mean()


def loss_fn(cfg: ArchConfig, params, batch) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = forward(cfg, params, batch)
    if cfg.input_mode == "mixed":
        # loss over the text positions only (patches precede tokens)
        n_txt = batch["labels"].shape[1]
        logits = logits[:, -n_txt:]
    ce = cross_entropy(logits, batch["labels"])
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ArchConfig, optimizer_cfg=None,
                    compress_grads: bool = False):
    """``compress_grads`` applies int8 block quantization (with error
    feedback folded in by the immediate dequantize) to the gradients before
    the optimizer — the arithmetic the cross-pod compressed all-reduce
    performs; on a multi-pod mesh XLA then moves 1-byte payloads over the
    slow inter-pod links (optim/compress.py)."""
    from ..optim import (AdamWConfig, adamw_update, int8_compress,
                         int8_decompress)
    from ..optim.schedule import cosine_schedule
    ocfg = optimizer_cfg or AdamWConfig()

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        if compress_grads:
            grads = jax.tree.map(
                lambda g: int8_decompress(*int8_compress(g), g.shape,
                                          g.dtype), grads)
        lr_scale = cosine_schedule(opt_state["step"], 100_000, 1_000)
        params, opt_state, gnorm = adamw_update(
            params, grads, opt_state, ocfg, lr_scale)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, cache_len: int):
    """Full-sequence forward that also materializes the decode cache."""

    def prefill_step(params, inputs):
        x = embed_inputs(cfg, params, inputs)
        B, S, D = x.shape
        positions = jnp.arange(S, dtype=jnp.int32)
        windows = layer_windows(cfg, S)
        cache = init_cache(cfg, B, cache_len)

        def body(x, xs):
            p, w, cs = xs
            h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
            new_cs = dict(cs)
            if cfg.layer_kind in ("attn", "hymba"):
                o, (k, v) = _attn_branch(cfg, p, h, positions, positions, w)
                if cfg.kv_quant:
                    k, ks = _kv_quantize(k)
                    v, vs = _kv_quantize(v)
                    slots = positions % cs["k"].shape[1]
                    new_cs["k_scale"] = cs["k_scale"].at[:, slots].set(ks)
                    new_cs["v_scale"] = cs["v_scale"].at[:, slots].set(vs)
                kv = attention.cache_update(
                    attention.KVCache(cs["k"], cs["v"], cs["kpos"]),
                    k, v, jnp.int32(0))
                new_cs.update(k=kv.k, v=kv.v, kpos=kv.kpos)
                if cfg.layer_kind == "hymba":
                    om, sstate, conv = mamba.ssm_branch(p, h)
                    new_cs.update(ssm_state=sstate, conv=conv)
                    oa_n = layers.rms_norm(o, p["attn_norm"] - 1.0, cfg.norm_eps)
                    om_n = layers.rms_norm(om, p["attn_norm"] * 0, cfg.norm_eps)
                    o = 0.5 * (oa_n + om_n)
                x = x + o
            elif cfg.layer_kind == "rwkv6":
                o, x_last, state = rwkv6.time_mix(
                    p, h, jnp.zeros((B, D), h.dtype),
                    jnp.zeros((B, D // 64, 64, 64), jnp.float32))
                new_cs.update(state=state, shift_tm=x_last)
                x = x + o
            h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
            if cfg.mlp_kind == "rwkv_cm":
                o2 = layers.rwkv_channel_mix(
                    h2, jnp.concatenate(
                        [jnp.zeros_like(h2[:, :1]), h2[:, :-1]], 1),
                    p["cm_mix"], p["w_in"], p["w_out"], p["w_recv"])
                new_cs.update(shift_cm=h2[:, -1])
            else:
                o2, _ = _ffn(cfg, p, h2)
            return x + o2, new_cs

        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable) \
            if cfg.remat else body
        x, cache = jax.lax.scan(body_fn, x,
                                (params["layers"], windows, cache))
        x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = project_vocab(cfg, params, x[:, -1:])
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, cache, inputs, pos):
        return forward_decode(cfg, params, cache, inputs, pos)

    return decode_step


class TransformerLM:
    """Thin OO wrapper used by examples."""

    def __init__(self, cfg: ArchConfig, key: jax.Array):
        self.cfg = cfg
        self.params = init_params(cfg, key)

    def __call__(self, inputs):
        return forward(self.cfg, self.params, inputs)
