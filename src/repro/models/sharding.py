"""Logical-axis sharding rules → PartitionSpecs.

Parameters and activations are annotated with *logical* axis names; a rule
table per execution mode maps them to mesh axes.  This is the MaxText-style
indirection that lets one model definition serve:

* ``train``  — TP over `model` + ZeRO-3/FSDP over (`pod`, `data`): every
  weight is additionally sharded on its non-TP dim; XLA inserts the per-layer
  all-gathers (prefetched across the scan) and reduce-scatters the grads.
* ``serve``  — TP over `model` only; weights replicated across (`pod`,
  `data`) which carry the request batch.

Attention-policy-specific axes (`heads`, `kv_heads`, `kv_seq`) resolve
according to the arch's policy (see config.resolve_attn_policy).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, object]


def make_rules(mode: str, policy: str, mesh: Mesh,
               cfg=None) -> Rules:
    """mode ∈ {train, prefill, decode}.

    train:   TP over `model` + FSDP over (`pod`,`data`) on weights.
    prefill: TP only (weights replicated over dp, which carries requests).
    decode:  like prefill, but kv-replicated GQA archs switch to split-KV —
             the cache sequence dim shards over `model` (softmax reductions
             over it lower to psum), since head-sharding a single query row
             buys nothing.
    """
    assert mode in ("train", "prefill", "decode"), mode
    axes = mesh.axis_names
    fsdp = tuple(a for a in ("pod", "data") if a in axes) or None
    dp = fsdp
    tp = "model" if "model" in axes else None

    def div(n: int, axis) -> Optional[str]:
        """Use `axis` only if it divides n (jit rejects uneven shardings)."""
        if axis is None or n is None:
            return None
        size = mesh.shape[axis] if isinstance(axis, str) else 1
        return axis if n % size == 0 else None

    if policy == "head_tp":
        heads_ax, kv_ax, kvseq_ax = tp, tp, None
    elif policy == "head_tp_kv_rep":
        if mode == "decode":
            heads_ax, kv_ax, kvseq_ax = None, None, tp
        else:
            heads_ax, kv_ax, kvseq_ax = tp, None, None
    else:  # context_parallel
        heads_ax, kv_ax, kvseq_ax = None, None, tp

    rules: Rules = {
        # activations
        "batch": dp,
        "seq": None,
        # Megatron-SP-style: shard the residual-stream carry over `model` in
        # training so the per-layer saved activations (scan carries) divide
        # by TP width; maybe_constrain drops it where S doesn't divide.
        "seq_act": tp if mode == "train" else None,
        "kv_seq": kvseq_ax,          # decode cache / CP key dim
        "heads_act": heads_ax,
        "embed_act": None,
        # params
        "vocab": tp,
        "embed": fsdp if mode == "train" else None,
        "mlp": tp,
        "heads": heads_ax,
        "kv_heads": kv_ax,
        "head_dim": None,
        "expert": None,              # experts are TP-inside by default
        "rwkv_heads": tp,
        "ssm_inner": tp,
        "dmodel_tp": tp,
        "norm": None,
        "lora": None,
    }
    if cfg is not None:
        # guard every param axis for divisibility at this mesh
        rules["vocab"] = div(cfg.vocab, rules["vocab"])
        rules["mlp"] = div(cfg.d_ff, rules["mlp"])
        rules["heads"] = div(cfg.n_heads, rules["heads"])
        rules["kv_heads"] = div(cfg.n_kv_heads, rules["kv_heads"])
        rules["heads_act"] = div(cfg.n_heads, rules["heads_act"])
        rules["dmodel_tp"] = div(cfg.d_model, rules["dmodel_tp"])
        if cfg.layer_kind == "rwkv6":
            rules["rwkv_heads"] = div(cfg.d_model // 64, rules["rwkv_heads"])
        if cfg.ssm_state:
            rules["ssm_inner"] = div(cfg.ssm_expand * cfg.d_model,
                                     rules["ssm_inner"])
        if cfg.moe is not None:
            rules["mlp"] = div(cfg.moe.d_expert, tp)
        if fsdp is not None and mode == "train":
            import numpy as _np
            fs = int(_np.prod([mesh.shape[a] for a in fsdp]))
            rules["embed"] = fsdp if cfg.d_model % fs == 0 else None
    if mode != "train":
        rules["embed"] = None          # serve: weights replicated over dp
    return rules


def spec(rules: Rules, *logical: Optional[str]) -> P:
    return P(*(rules.get(ax) if ax else None for ax in logical))


def named(mesh: Mesh, rules: Rules, *logical) -> NamedSharding:
    return NamedSharding(mesh, spec(rules, *logical))


def constrain(x, mesh: Mesh, rules: Rules, *logical):
    """with_sharding_constraint via logical names (no-op without mesh ctx)."""
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(rules, *logical)))


# ---------------------------------------------------------------------------
# sharding context: lets model code anchor GSPMD without threading mesh/rules
# through every function signature.  Outside the context (CPU smoke tests)
# maybe_constrain is the identity.
# ---------------------------------------------------------------------------

import contextlib as _contextlib
import threading as _threading

_CTX = _threading.local()


@_contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: Rules):
    prev = getattr(_CTX, "val", None)
    _CTX.val = (mesh, rules)
    try:
        yield
    finally:
        _CTX.val = prev


def maybe_constrain(x, *logical):
    ctx = getattr(_CTX, "val", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    # drop constraints that don't divide the actual dim
    resolved = []
    for dim, ax in zip(x.shape, logical):
        mesh_ax = rules.get(ax) if ax else None
        if mesh_ax is not None:
            axes = (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax)
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            if dim % total != 0:
                mesh_ax = None
        resolved.append(mesh_ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


# ---------------------------------------------------------------------------
# parameter spec trees
# ---------------------------------------------------------------------------


def param_specs(cfg, rules: Rules) -> dict:
    """Logical→mesh PartitionSpec pytree matching init_params' structure."""
    s = lambda *ax: spec(rules, *ax)                    # noqa: E731
    layer: dict = {"ln1": s("norm"), "ln2": s("norm")}
    if cfg.layer_kind in ("attn", "hymba"):
        layer.update({
            "wq": s("embed", "heads", "head_dim"),
            "wk": s("embed", "kv_heads", "head_dim"),
            "wv": s("embed", "kv_heads", "head_dim"),
            "wo": s("heads", "head_dim", "embed"),
        })
        if cfg.qkv_bias:
            layer.update({"bq": s("heads", "head_dim"),
                          "bk": s("kv_heads", "head_dim"),
                          "bv": s("kv_heads", "head_dim")})
    if cfg.layer_kind == "rwkv6":
        layer.update({
            "mix_base": s(None, "embed"),
            "mix_lora_a": s("embed", None, "lora"),
            "mix_lora_b": s(None, "lora", "embed"),
            # column-parallel projections: output channels over `model`
            # (head-aligned: D/16 is a whole number of 64-wide heads),
            # input dim FSDP-sharded in training.
            "wr": s("embed", "dmodel_tp"), "wk": s("embed", "dmodel_tp"),
            "wv": s("embed", "dmodel_tp"), "wg": s("embed", "dmodel_tp"),
            "wo": s("dmodel_tp", "embed"),
            "decay_base": s("dmodel_tp"),
            "decay_lora_a": s("embed", "lora"),
            "decay_lora_b": s("lora", "dmodel_tp"),
            "bonus": s("rwkv_heads", "head_dim"),
            "ln_x": s("norm"),
        })
    if cfg.layer_kind == "hymba":
        layer.update({
            "ssm_in": s("embed", None, "ssm_inner"),
            "ssm_conv": s(None, "ssm_inner"),
            "ssm_dt": s("ssm_inner"),
            "ssm_A": s(None),               # per-head scalar (nh ∤ tp)
            "ssm_B": s("ssm_inner", None),
            "ssm_C": s("ssm_inner", None),
            "ssm_D": s("ssm_inner"),
            "ssm_out": s("ssm_inner", "embed"),
            "ssm_norm": s("ssm_inner"),
            "attn_norm": s("head_dim"),
        })
    if cfg.moe is not None:
        layer.update({
            "router": s("embed", "expert"),
            "we_in": s("expert", "embed", "mlp"),
            "we_gate": s("expert", "embed", "mlp"),
            "we_out": s("expert", "mlp", "embed"),
        })
        if cfg.moe.d_shared:
            layer.update({
                "ws_in": s("embed", "mlp"), "ws_gate": s("embed", "mlp"),
                "ws_out": s("mlp", "embed"),
                "shared_gate": s("embed"),
            })
    elif cfg.mlp_kind == "swiglu":
        layer.update({"w_in": s("embed", "mlp"), "w_gate": s("embed", "mlp"),
                      "w_out": s("mlp", "embed")})
    elif cfg.mlp_kind == "gelu":
        layer.update({"w_in": s("embed", "mlp"), "w_out": s("mlp", "embed"),
                      "b_in": s("mlp"), "b_out": s("embed")})
    elif cfg.mlp_kind == "rwkv_cm":
        layer.update({"cm_mix": s(None, "embed"),
                      "w_in": s("embed", "mlp"), "w_out": s("mlp", "embed"),
                      "w_recv": s("embed", "dmodel_tp")})

    # stacked-layer leaves carry a leading L axis (scan-over-layers)
    layer = {k: P(None, *v) for k, v in layer.items()}
    out = {
        "embed": s("vocab", "embed"),
        "final_norm": s("norm"),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = s("embed", "vocab")
    return out


def tree_shardings(mesh: Mesh, spec_tree) -> object:
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
