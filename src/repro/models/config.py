"""Architecture configuration for the LM-family substrate.

One config type drives all 10 assigned architectures: dense GQA decoders,
MoE, RWKV6 (attention-free), Hymba (parallel attention+SSM heads), and the
audio/VLM backbones (whose modality frontends are stubs per the assignment —
``input_mode`` selects how inputs enter the stack).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN width
    n_shared: int = 0             # always-on shared experts (qwen2-moe)
    d_shared: int = 0             # combined shared-expert width
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    layer_kind: str = "attn"          # attn | rwkv6 | hymba
    mlp_kind: str = "swiglu"          # swiglu | gelu | rwkv_cm
    qkv_bias: bool = False
    pos_mode: str = "rope"            # rope | sinusoid | none
    rope_theta: float = 1e6
    partial_rotary: float = 1.0       # glm4 rotates half the head dim
    attn_window: Optional[int] = None # sliding-window width (mixtral, hymba)
    global_attn_layers: Tuple[int, ...] = ()   # hymba: full-attn layer ids
    moe: Optional[MoEConfig] = None
    input_mode: str = "tokens"        # tokens | embeddings (audio) | mixed (vlm)
    patch_frac: float = 0.25          # mixed mode: fraction of seq from patches
    ssm_state: int = 0                # hymba mamba state size
    ssm_expand: int = 2               # mamba d_inner = expand × d_model
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: bool = True
    # int8 KV cache (per-vector max-abs scales over d_head): §Perf iteration
    # for decode cells whose bf16 cache exceeds HBM (qwen1.5-32b decode_32k)
    kv_quant: bool = False
    # sharding policy: auto | head_tp | head_tp_kv_rep | context_parallel
    attn_policy: str = "auto"

    @property
    def d_attn(self) -> int:
        return self.n_heads * self.d_head

    @property
    def n_params(self) -> int:
        """Parameter count (exact for the layer stack as built here)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_layer = 0
        if self.layer_kind in ("attn", "hymba"):
            per_layer += d * self.d_attn + 2 * d * self.n_kv_heads * self.d_head
            per_layer += self.d_attn * d
            if self.qkv_bias:
                per_layer += self.d_attn + 2 * self.n_kv_heads * self.d_head
        if self.layer_kind == "rwkv6":
            dk = d  # r/k/w dims
            per_layer += 4 * d * d + d * d   # r,k,v,g,o projections
            per_layer += 6 * d * 32 * 2       # ddlerp/decay loras (approx)
        if self.layer_kind == "hymba":
            di = self.ssm_expand * d
            per_layer += d * 2 * di + di * d + di * 4  # in/out proj + conv
            per_layer += di * (self.ssm_state * 2 + 2)  # B,C,dt,A heads
        if self.moe is not None:
            per_layer += d * self.moe.n_experts            # router
            per_layer += self.moe.n_experts * 3 * d * self.moe.d_expert
            if self.moe.d_shared:
                per_layer += 3 * d * self.moe.d_shared + d
        elif self.mlp_kind == "swiglu":
            per_layer += 3 * d * f
        elif self.mlp_kind == "rwkv_cm":
            per_layer += d * f + f * d + d * d
        else:  # gelu
            per_layer += 2 * d * f
        per_layer += 2 * d  # norms
        embed = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + d

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed-in experts)."""
        if self.moe is None:
            return self.n_params
        full = self.n_params
        inactive = self.n_layers * (self.moe.n_experts - self.moe.top_k) \
            * 3 * self.d_model * self.moe.d_expert
        return full - inactive


def resolve_attn_policy(cfg: ArchConfig, tp: int) -> str:
    """Pick the attention TP policy for a given model-axis width.

    jit boundaries require divisible shardings (verified empirically), so:
    * kv and q heads divide tp      → classic Megatron head sharding;
    * only q heads divide tp        → shard q heads, replicate kv (GQA norm);
    * neither (40H, 25H archs)      → context parallelism: shard the *key*
      sequence dim; softmax reductions over it lower to psum (split-KV).
    """
    if cfg.attn_policy != "auto":
        return cfg.attn_policy
    if cfg.n_kv_heads % tp == 0 and cfg.n_heads % tp == 0:
        return "head_tp"
    if cfg.n_heads % tp == 0:
        return "head_tp_kv_rep"
    return "context_parallel"
