"""RWKV6 "Finch" time-mix: data-dependent token shift and decay.

Faithful structure (arXiv:2404.05892): ddlerp token-shift mixing with a
low-rank data-dependent component for the five mix targets (w, k, v, r, g);
per-channel data-dependent decay w_t = exp(−exp(base + LoRA(x_w))); bonus u
for the current token; per-head group norm on the attention output; silu(g)
output gate.  The WKV recurrence runs through the shared chunked
linear-attention core (state (dk × dv) per head) — see linear_attention.py
for why chunking is the TPU-native form.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import linear_attention as la

HEAD_DIM = 64
MIX_TARGETS = 5          # w, k, v, r, g
LORA_MIX = 32
LORA_DECAY = 64


def init_layer(key: jax.Array, d_model: int, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 8)
    D = d_model
    H = D // HEAD_DIM
    s = 1.0 / jnp.sqrt(D)
    return {
        # row 0 is the pre-mix (maa_x); rows 1.. are per-target bases
        "mix_base": jnp.zeros((1 + MIX_TARGETS, D), dtype),
        "mix_lora_a": (jax.random.normal(ks[0], (D, MIX_TARGETS, LORA_MIX)) * 0.01).astype(dtype),
        "mix_lora_b": (jax.random.normal(ks[1], (MIX_TARGETS, LORA_MIX, D)) * 0.01).astype(dtype),
        "wr": (jax.random.normal(ks[2], (D, D)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[3], (D, D)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[4], (D, D)) * s).astype(dtype),
        "wg": (jax.random.normal(ks[5], (D, D)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[6], (D, D)) * s).astype(dtype),
        "decay_base": jnp.full((D,), -1.0, dtype),       # soft init: slowish
        "decay_lora_a": (jax.random.normal(ks[7], (D, LORA_DECAY)) * 0.01).astype(dtype),
        "decay_lora_b": jnp.zeros((LORA_DECAY, D), dtype),
        "bonus": jnp.zeros((H, HEAD_DIM), jnp.float32),
        "ln_x": jnp.ones((D,), jnp.float32),
    }


def _ddlerp(x, x_shift, p):
    """Data-dependent lerp: five mixed views of (x, shifted x)."""
    dx = x_shift - x                                    # (B, S, D)
    xxx = x + dx * p["mix_base"][0]
    lora = jnp.einsum("bsd,dtr->bstr", jnp.tanh(xxx), p["mix_lora_a"])
    lora = jnp.einsum("bstr,trd->tbsd", lora, p["mix_lora_b"])
    mixes = p["mix_base"][1:][:, None, None, :] + lora   # (5, B, S, D)
    return x[None] + dx[None] * mixes                    # (5, B, S, D)


def _shift(x, x_prev):
    """Token shift: x_{t-1}, with x_prev carrying the cross-call state."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1]], axis=1)


def time_mix(params: dict, x: jnp.ndarray, x_prev: jnp.ndarray,
             state: jnp.ndarray, chunk: int = 32
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x (B, S, D), x_prev (B, D), state (B, H, dk, dv) → (out, x_last, state)."""
    B, S, D = x.shape
    H = D // HEAD_DIM
    xs = _shift(x, x_prev)
    xw, xk, xv, xr, xg = _ddlerp(x, xs, params)

    r = xr @ params["wr"]
    k = xk @ params["wk"]
    v = xv @ params["wv"]
    g = jax.nn.silu(xg @ params["wg"])
    # data-dependent decay (per channel): ld = −exp(w) ≤ 0
    w = params["decay_base"].astype(jnp.float32) + \
        jnp.tanh(xw.astype(jnp.float32) @ params["decay_lora_a"].astype(jnp.float32)) \
        @ params["decay_lora_b"].astype(jnp.float32)
    log_decay = -jnp.exp(jnp.clip(w, -8.0, 4.0))

    def heads(t):
        return t.reshape(B, S, H, HEAD_DIM)

    o, state = la.chunked_linear_attention(
        heads(r), heads(k), heads(v), heads(log_decay), state,
        bonus=params["bonus"], include_current=False, chunk=chunk)
    o = o.reshape(B, S, D)
    # per-head group norm (ln_x)
    oh = o.reshape(B, S, H, HEAD_DIM).astype(jnp.float32)
    oh = oh * jax.lax.rsqrt((oh * oh).mean(-1, keepdims=True) + 1e-5)
    o = (oh.reshape(B, S, D) * params["ln_x"]).astype(x.dtype)
    out = (o * g) @ params["wo"]
    return out, x[:, -1], state


def time_mix_step(params: dict, x: jnp.ndarray, x_prev: jnp.ndarray,
                  state: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Decode: x (B, D) one token."""
    B, D = x.shape
    H = D // HEAD_DIM
    out, x_last, state = time_mix(params, x[:, None, :], x_prev, state,
                                  chunk=1)
    return out[:, 0], x_last, state
