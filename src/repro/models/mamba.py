"""Selective SSM branch for Hymba's parallel attention+mamba heads.

Implemented in the SSD (scalar-per-head decay) form so the recurrence runs
through the shared chunked linear-attention core — the same TPU adaptation
argument as RWKV6 (see linear_attention.py).  DESIGN.md §HW-adaptation notes
this deviation from elementwise-A mamba-1: Hymba's contribution (parallel
hybrid heads) is preserved; the SSM parameterization is the TPU-chunkable
one.

Structure: in_proj → (x, z); causal depthwise conv (k=4) + silu on x;
B, C projections (shared across heads, mamba-1 style); per-head Δ via
softplus; y = SSM(x̃=Δ·x, B, C, decay=exp(Δ·A)) ⊙ silu(z); out_proj with
skip D·x.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import linear_attention as la

N_HEADS = 32        # §Perf iter 1: 32 heads divide the 16-wide TP axis
CONV_K = 4          # (head_dim = d_inner / 32; was 64-wide heads ⇒ 50 ∤ 16)


def init_layer(key: jax.Array, d_model: int, d_state: int, expand: int = 2,
               dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 6)
    D = d_model
    di = expand * D
    nh = N_HEADS
    s = 1.0 / jnp.sqrt(D)
    return {
        "ssm_in": (jax.random.normal(ks[0], (D, 2, di)) * s).astype(dtype),
        "ssm_conv": (jax.random.normal(ks[1], (CONV_K, di)) * 0.5).astype(dtype),
        "ssm_B": (jax.random.normal(ks[2], (di, d_state)) / jnp.sqrt(di)).astype(dtype),
        "ssm_C": (jax.random.normal(ks[3], (di, d_state)) / jnp.sqrt(di)).astype(dtype),
        "ssm_dt": (jax.random.normal(ks[4], (di,)) * 0.01).astype(jnp.float32),
        "ssm_A": jnp.zeros((nh,), jnp.float32),          # A = −exp(ssm_A)
        "ssm_D": jnp.ones((di,), jnp.float32),
        "ssm_out": (jax.random.normal(ks[5], (di, D)) * s).astype(dtype),
        "ssm_norm": jnp.ones((di,), jnp.float32),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 conv_state: jnp.ndarray | None = None):
    """Depthwise causal conv1d.  x (B, S, di); w (K, di).

    Returns (y, new_conv_state (B, K−1, di))."""
    B, S, di = x.shape
    K = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, K - 1, di), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)        # (B, S+K-1, di)
    y = sum(xp[:, i:i + S] * w[i] for i in range(K))
    return y, xp[:, -(K - 1):]


def _project(params, x, conv_state):
    xz = jnp.einsum("bsd,dti->bsti", x, params["ssm_in"])
    x1, z = xz[..., 0, :], xz[..., 1, :]
    x1, conv_state = _causal_conv(x1, params["ssm_conv"], conv_state)
    x1 = jax.nn.silu(x1)
    B, S, di = x1.shape
    nh = N_HEADS
    dt = jax.nn.softplus(
        (x1.astype(jnp.float32) * params["ssm_dt"])
        .reshape(B, S, nh, di // nh).mean(-1))            # (B, S, nh)
    log_decay = -jnp.exp(params["ssm_A"])[None, None] * dt  # ≤ 0
    Bq = x1 @ params["ssm_B"]                             # (B, S, N) keys
    Cq = x1 @ params["ssm_C"]                             # (B, S, N) queries
    xh = x1.reshape(B, S, nh, di // nh) * dt[..., None]   # values (Δ·x)
    return x1, z, Bq, Cq, xh, log_decay, conv_state


def ssm_branch(params: dict, x: jnp.ndarray,
               ssm_state: jnp.ndarray | None = None,
               conv_state: jnp.ndarray | None = None,
               chunk: int = 128):
    """x (B, S, D) → (out (B, S, D), ssm_state, conv_state)."""
    B, S, D = x.shape
    x1, z, Bq, Cq, xh, log_decay, conv_state = _project(params, x, conv_state)
    di = x1.shape[-1]
    nh = N_HEADS
    N = Bq.shape[-1]
    if ssm_state is None:
        ssm_state = jnp.zeros((B, nh, N, di // nh), jnp.float32)
    # linear attention: q=C, k=B (broadcast over heads), v=Δ·x; the decay is
    # a per-head SCALAR (trailing dim 1 → the exact (T,T) fast path)
    q = jnp.broadcast_to(Cq[:, :, None, :], (B, S, nh, N))
    k = jnp.broadcast_to(Bq[:, :, None, :], (B, S, nh, N))
    ld = log_decay[..., None]                             # (B, S, nh, 1)
    y, ssm_state = la.chunked_linear_attention(
        q, k, xh, ld, ssm_state, include_current=True, chunk=chunk)
    y = y.reshape(B, S, di) + params["ssm_D"] * x1        # skip
    # branch norm + gate
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt((yf * yf).mean(-1, keepdims=True) + 1e-5)
    y = (yf * params["ssm_norm"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ params["ssm_out"], ssm_state, conv_state


def ssm_branch_step(params: dict, x: jnp.ndarray, ssm_state: jnp.ndarray,
                    conv_state: jnp.ndarray):
    """Decode: x (B, D) single token."""
    out, ssm_state, conv_state = ssm_branch(
        params, x[:, None, :], ssm_state, conv_state, chunk=1)
    return out[:, 0], ssm_state, conv_state
