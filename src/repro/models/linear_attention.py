"""Chunked linear attention with data-dependent decay — shared core.

Both assigned recurrent families reduce to the affine state recurrence

    S_t = diag(exp(ld_t)) · S_{t−1} + k_tᵀ v_t          S: (dk, dv)
    o_t = q_t · S_{t−1} (+ (q_t·u·k_t) v_t)   [RWKV6: pre-state + bonus]
    o_t = q_t · S_t                           [mamba/SSD: post-state]

with ld_t ≤ 0 the per-step log-decay: per-channel (dk,) for RWKV6's
data-dependent decay, a per-head scalar for the SSD-form SSM in Hymba
(signalled by a trailing log_decay dim of 1).

TPU adaptation + §Perf iteration 1 (see EXPERIMENTS.md):
* scalar decay  → the intra-chunk interaction is (q@kᵀ) ⊙ exp(ref_t − cum_s):
  one (T,T) decay matrix per head, pure MXU work, exact and overflow-free
  (exponents ≤ 0 on the causal mask).
* per-channel decay → stable factorized matmul: shift both factors by the
  per-channel chunk midpoint c = (cum_0 + cum_T)/2, so each side's exponent
  is bounded by half the chunk's decay range; exponents are clamped at ±80
  (f32-safe), which only perturbs coefficients whose true value is ≤ e⁻⁸⁰ —
  numerically zero contributions.  This removes the baseline's (T, T, dk)
  materialization (the dominant HBM-traffic term in the rwkv6/hymba train
  cells: 204 s → see §Perf).
* the chunk body is rematerialized (jax.checkpoint): the chunk scan saves
  only the (dk × dv) state carries for backward instead of every
  intermediate, which removed the hymba train cell's 52 GB/device residual
  blow-up.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_CLAMP = 80.0


def _chunk_body(q, k, v, ld, state, bonus, include_current):
    """One chunk, one (batch, head).

    q, k (T, dk); v (T, dv); ld (T, dk) or (T, 1) [scalar decay]; state
    (dk, dv)."""
    T = q.shape[0]
    scalar_decay = ld.shape[-1] == 1
    cum = jnp.cumsum(ld, axis=0)                     # (T, dk|1) ≤ 0, decreasing
    cum_prev = cum - ld
    ref = cum if include_current else cum_prev        # decay reference at t

    # state (cross-chunk) contribution: o1_t = (q_t ⊙ exp(ref_t)) · S
    o1 = (q * jnp.exp(ref)) @ state                   # (T, dv)

    # intra-chunk scores
    tri = jnp.tril(jnp.ones((T, T), bool), 0 if include_current else -1)
    if scalar_decay:
        # exact: exponent ≤ 0 everywhere on the mask
        D = jnp.exp(ref - cum.T)                      # (T, T)
        A = (q @ k.T) * D
    else:
        c = 0.5 * (cum[0] + cum[-1])                  # (dk,) chunk midpoint
        qf = q * jnp.exp(jnp.clip(ref - c, -_CLAMP, _CLAMP))
        kf = k * jnp.exp(jnp.clip(c - cum, -_CLAMP, _CLAMP))
        A = qf @ kf.T
    A = jnp.where(tri, A, 0.0)
    o2 = A @ v

    o = o1 + o2
    if bonus is not None:                             # RWKV6 current-token u
        o = o + ((q * bonus * k).sum(-1, keepdims=True)) * v

    # carry: S' = diag(exp(cum_T)) S + Σ_s (k_s ⊙ exp(cum_T − cum_s))ᵀ v_s
    decay_tail = jnp.exp(cum[-1][None, :] - cum)      # (T, dk|1) ≤ 1
    state_scale = jnp.exp(cum[-1])
    if scalar_decay:
        state_scale = jnp.broadcast_to(state_scale, (q.shape[1],))
    new_state = state_scale[:, None] * state + (k * decay_tail).T @ v
    return o, new_state


@functools.partial(jax.jit, static_argnames=("chunk", "include_current"))
def chunked_linear_attention(
    q: jnp.ndarray,            # (B, S, H, dk)
    k: jnp.ndarray,            # (B, S, H, dk)
    v: jnp.ndarray,            # (B, S, H, dv)
    log_decay: jnp.ndarray,    # (B, S, H, dk) or (B, S, H, 1) — scalar decay
    state: Optional[jnp.ndarray] = None,     # (B, H, dk, dv)
    bonus: Optional[jnp.ndarray] = None,     # (H, dk) — RWKV6 u
    include_current: bool = False,
    chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (o (B, S, H, dv), final_state (B, H, dk, dv))."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        state = jnp.zeros((B, H, dk, dv), jnp.float32)
    pad = (-S) % chunk
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, padw) for a in (q, k, v))
        log_decay = jnp.pad(log_decay, padw)
    nc = q.shape[1] // chunk

    def to_chunks(x):                                  # (nc, B, H, T, d)
        return x.reshape(B, nc, chunk, H, -1).transpose(1, 0, 3, 2, 4)

    qc, kc, vc, ldc = map(to_chunks, (q, k, v, log_decay))

    body = _chunk_body
    if bonus is not None:
        inner = jax.vmap(lambda qq, kk, vv, ll, ss, bb: body(
            qq, kk, vv, ll, ss, bb, include_current),
            in_axes=(0, 0, 0, 0, 0, 0))                # over H
        outer = jax.vmap(inner, in_axes=(0, 0, 0, 0, 0, None))  # over B
    else:
        inner = jax.vmap(lambda qq, kk, vv, ll, ss: body(
            qq, kk, vv, ll, ss, None, include_current))
        outer = jax.vmap(inner)

    @jax.checkpoint
    def step(carry, xs):
        st = carry
        qi, ki, vi, li = xs
        if bonus is not None:
            o, st = outer(qi.astype(jnp.float32), ki.astype(jnp.float32),
                          vi.astype(jnp.float32), li, st, bonus)
        else:
            o, st = outer(qi.astype(jnp.float32), ki.astype(jnp.float32),
                          vi.astype(jnp.float32), li, st)
        return st, o

    state, o = jax.lax.scan(step, state, (qc, kc, vc, ldc))
    o = o.transpose(1, 0, 3, 2, 4).reshape(B, nc * chunk, H, dv)
    return o[:, :S].astype(v.dtype), state


def linear_attention_step(
    q: jnp.ndarray,            # (B, H, dk)
    k: jnp.ndarray,
    v: jnp.ndarray,            # (B, H, dv)
    log_decay: jnp.ndarray,    # (B, H, dk) or (B, H, 1)
    state: jnp.ndarray,        # (B, H, dk, dv)
    bonus: Optional[jnp.ndarray] = None,
    include_current: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token decode: direct recurrence (no chunking needed)."""
    q, k, v = (a.astype(jnp.float32) for a in (q, k, v))
    kv = k[..., :, None] * v[..., None, :]             # (B, H, dk, dv)
    decay = jnp.exp(log_decay)
    if log_decay.shape[-1] == 1:
        decay = jnp.broadcast_to(decay, k.shape)
    if include_current:
        new_state = decay[..., None] * state + kv
        o = jnp.einsum("bhk,bhkv->bhv", q, new_state)
    else:
        o = jnp.einsum("bhk,bhkv->bhv", q, state)
        if bonus is not None:
            o = o + (q * bonus[None] * k).sum(-1, keepdims=True) * v
        new_state = decay[..., None] * state + kv
    return o.astype(v.dtype), new_state
