"""GQA attention with policy-driven sharding and chunked online compute.

One implementation serves every assigned arch:
* GQA via local repeat of K/V to full heads (identity when kv == heads; a
  per-shard-local broadcast under every sharding policy — see sharding.py).
* Sliding windows (mixtral, hymba) and mixed global/local layers (hymba) via
  a per-layer ``window`` scalar — a huge window ≡ full causal attention, so
  the scan-over-layers stays homogeneous.
* Long sequences never materialize (Sq × Sk): queries are processed in
  chunks with full keys per chunk (the key dim is the sharded one under the
  context-parallel policy, so per-device score blocks stay ~100 MB at 32k).
* Decode uses a positions-stamped ring cache: slot = pos % cache_len, with a
  per-slot position array driving validity/window masking — the same code
  path covers full caches (cache_len = max_seq) and SWA ring caches
  (cache_len = window), which is what makes mixtral's 500k-decode KV bounded.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-1e30)


class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, C, KV, dh)
    v: jnp.ndarray          # (B, C, KV, dh)
    kpos: jnp.ndarray       # (C,) int32 stored absolute positions; -1 empty


def init_cache(batch: int, cache_len: int, n_kv: int, d_head: int,
               dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, cache_len, n_kv, d_head), dtype),
        v=jnp.zeros((batch, cache_len, n_kv, d_head), dtype),
        kpos=jnp.full((cache_len,), -1, jnp.int32),
    )


def _mask(qpos: jnp.ndarray, kpos: jnp.ndarray, window) -> jnp.ndarray:
    """(Sq, Sk) validity: causal, in-window, slot non-empty."""
    d = qpos[:, None] - kpos[None, :]
    ok = (d >= 0) & (kpos[None, :] >= 0)
    if window is not None:
        ok &= d < window
    return ok


def _sdpa(q, k, v, qpos, kpos, window, scale):
    """Dense scores path.  q (B,Sq,H,dh); k/v (B,Sk,H,dh)."""
    s = jnp.einsum("bqhd,bskd->bhqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(_mask(qpos, kpos, window)[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v)


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           qpos: jnp.ndarray, kpos: jnp.ndarray,
           window: Optional[int] = None,
           chunk_q: int = 512) -> jnp.ndarray:
    """q (B, Sq, H, dh); k, v (B, Sk, KV, dh) → (B, Sq, H, dh).

    qpos (Sq,), kpos (Sk,) absolute positions (kpos may contain −1 = empty).
    """
    from .sharding import maybe_constrain
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    q = maybe_constrain(q, "batch", None, "heads_act", None)
    k = maybe_constrain(k, "batch", "kv_seq", "kv_heads", None)
    v = maybe_constrain(v, "batch", "kv_seq", "kv_heads", None)
    if H != KV:                       # GQA: local repeat (see module doc)
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
        k = maybe_constrain(k, "batch", "kv_seq", "heads_act", None)
        v = maybe_constrain(v, "batch", "kv_seq", "heads_act", None)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    if Sq <= chunk_q:
        return _sdpa(q, k, v, qpos, kpos, window, scale)

    # q-chunked path: full keys per chunk; no (Sq × Sk) materialization.
    nc = Sq // chunk_q
    assert Sq % chunk_q == 0, "pad sequence to a chunk multiple"
    qc = q.reshape(B, nc, chunk_q, H, dh).swapaxes(0, 1)     # (nc, B, cq, H, dh)
    qpc = qpos.reshape(nc, chunk_q)

    def one_chunk(_, xs):
        qi, pi = xs
        return None, _sdpa(qi, k, v, pi, kpos, window, scale)

    _, out = jax.lax.scan(one_chunk, None, (qc, qpc))
    return out.swapaxes(0, 1).reshape(B, Sq, H, dh)


def cache_update(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                 pos: jnp.ndarray) -> KVCache:
    """Insert (B, T, KV, dh) new keys/values at absolute position ``pos``.

    Ring semantics: slot = pos % cache_len.  For full caches (cache_len ≥
    max positions) this is a plain append; for SWA ring caches old slots are
    overwritten and the stamped positions keep masking correct.
    """
    C = cache.k.shape[1]
    T = k_new.shape[1]
    positions = pos + jnp.arange(T, dtype=jnp.int32)
    slots = positions % C

    if T == 1:
        s = slots[0]
        k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                         (0, s, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                         (0, s, 0, 0))
        kpos = jax.lax.dynamic_update_slice(cache.kpos, positions, (s,))
    else:
        k = cache.k.at[:, slots].set(k_new.astype(cache.k.dtype))
        v = cache.v.at[:, slots].set(v_new.astype(cache.v.dtype))
        kpos = cache.kpos.at[slots].set(positions)
    return KVCache(k=k, v=v, kpos=kpos)
