"""Shared layer primitives: norms, rotary embeddings, MLP variants."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt((x * x).mean(-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_frequencies(d_rot: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               partial: float = 1.0) -> jnp.ndarray:
    """x (B, S, H, dh); positions (B, S) or (S,).  Rotates the first
    ``partial``·dh dims (glm4 uses partial=0.5)."""
    dh = x.shape[-1]
    d_rot = int(dh * partial)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    freqs = rope_frequencies(d_rot, theta)                   # (d_rot/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :d_rot].astype(jnp.float32)
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    rot = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    rot = rot.reshape(x.shape[:-1] + (d_rot,)).astype(x.dtype)
    return jnp.concatenate([rot, x[..., d_rot:]], axis=-1)


def sinusoid_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """Classic sinusoidal embeddings (musicgen backbone's positional mode)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def swiglu(x, w_in, w_gate, w_out):
    h = jax.nn.silu(x @ w_gate) * (x @ w_in)
    return h @ w_out


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = jax.nn.gelu((x @ w_in + b_in), approximate=True)
    return h @ w_out + b_out


def rwkv_channel_mix(x, x_prev, mix, w_in, w_out, w_recv):
    """RWKV6 channel mix: token-shift lerp, squared-relu FFN, receptance gate."""
    xk = x + (x_prev - x) * mix[0]
    xr = x + (x_prev - x) * mix[1]
    k = jnp.square(jax.nn.relu(xk @ w_in))
    return jax.nn.sigmoid(xr @ w_recv) * (k @ w_out)
