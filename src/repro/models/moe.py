"""Mixture-of-Experts FFN: top-k routing, capacity-bounded scatter dispatch.

The dispatch is scatter/gather-based (megablocks-style) rather than the
GShard one-hot-einsum form: with 1M tokens × 60 experts the (tokens, E, C)
dispatch tensor is infeasible, while the (E, C, D) expert buffer shards
cleanly (tokens over data, expert FFN width over model).  Tokens beyond an
expert's capacity fall through on the residual path (standard
capacity-factor semantics); an auxiliary load-balancing loss keeps the
router honest.

Sharding policies: default TP-inside-experts (d_expert over `model`; valid
for every assigned MoE since both 8 and 60 experts don't divide 16); EP is a
config flag used in the §Perf hillclimb (experts padded to a multiple of the
mesh axis there).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import MoEConfig


def init_layer(key: jax.Array, d_model: int, moe: MoEConfig,
               dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 5)
    E, F = moe.n_experts, moe.d_expert
    s_in = 1.0 / jnp.sqrt(d_model)
    s_out = 1.0 / jnp.sqrt(F)
    p = {
        "router": (jax.random.normal(ks[0], (d_model, E)) * 0.02).astype(jnp.float32),
        "we_in": (jax.random.normal(ks[1], (E, d_model, F)) * s_in).astype(dtype),
        "we_gate": (jax.random.normal(ks[2], (E, d_model, F)) * s_in).astype(dtype),
        "we_out": (jax.random.normal(ks[3], (E, F, d_model)) * s_out).astype(dtype),
    }
    if moe.d_shared:
        ks2 = jax.random.split(ks[4], 4)
        p.update({
            "ws_in": (jax.random.normal(ks2[0], (d_model, moe.d_shared)) * s_in).astype(dtype),
            "ws_gate": (jax.random.normal(ks2[1], (d_model, moe.d_shared)) * s_in).astype(dtype),
            "ws_out": (jax.random.normal(ks2[2], (moe.d_shared, d_model))
                       / jnp.sqrt(moe.d_shared)).astype(dtype),
            "shared_gate": (jax.random.normal(ks2[3], (d_model,)) * 0.02).astype(jnp.float32),
        })
    return p


def moe_ffn(params: dict, x: jnp.ndarray, moe: MoEConfig,
            no_drop: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) → (out (B, S, D), aux_loss scalar).

    Dispatch is *grouped per batch row* (§Perf iteration 1: the baseline's
    single global position-in-expert cumsum serialized across data shards —
    2.6 TB of all-reduce per mixtral prefill step; ranking within each
    batch-sharded row keeps every dispatch op shard-local, leaving only the
    expert-TP psums on the wire).  Capacity is likewise per row:
    C = cf·S·K/E slots per expert per sequence.

    ``no_drop=True`` (decode) sets capacity = all tokens: serving never drops
    a token, matching production MoE inference semantics."""
    B, S, D = x.shape
    E, K = moe.n_experts, moe.top_k

    logits = x.astype(jnp.float32) @ params["router"]          # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, K)                  # (B, S, K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # load-balancing auxiliary loss (Switch-style), all row-local reductions
    me = probs.mean(axis=(0, 1))                               # (E,)
    ce = jnp.zeros((B, E), jnp.float32)
    ce = ce.at[jnp.arange(B)[:, None, None],
               eidx].add(1.0).mean(0) / (S * K)
    aux = E * (me * ce).sum()

    # per-row capacity and position-in-expert (rank within the row)
    # leafi: ignore[LF001]: moe.capacity_factor is a Python config float (MoEConfig), concrete at trace time
    C = S * K if no_drop else (int(moe.capacity_factor * S * K / E) or 1)
    flat_e = eidx.reshape(B, S * K)                            # (B, S*K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (B, S*K, E)
    pos = (jnp.cumsum(onehot, axis=1) - 1)[
        jnp.arange(B)[:, None], jnp.arange(S * K)[None, :], flat_e]
    keep = pos < C
    pos_c = jnp.minimum(pos, C - 1)

    # dispatch: (B, E, C, D) buffer — batched scatter, row-local.  The
    # explicit batch-sharding constraints matter: without them GSPMD
    # replicates the scatter output and reconciles shards with full-buffer
    # all-reduces (2.4 TB/step on mixtral prefill — §Perf iteration 2).
    from .sharding import maybe_constrain
    vals = jnp.repeat(x.reshape(B, S, 1, D), K, axis=2).reshape(B, S * K, D)
    vals = vals * keep[..., None].astype(x.dtype)
    buf = jnp.zeros((B, E, C, D), x.dtype).at[
        jnp.arange(B)[:, None], flat_e, pos_c].add(vals)
    buf = maybe_constrain(buf, "batch", None, None, None)

    # expert compute (TP on F via sharding rules)
    h = jnp.einsum("becd,edf->becf", buf, params["we_in"])
    g = jnp.einsum("becd,edf->becf", buf, params["we_gate"])
    h = maybe_constrain(jax.nn.silu(g) * h, "batch", None, None, "mlp")
    out_buf = jnp.einsum("becf,efd->becd", h, params["we_out"])
    out_buf = maybe_constrain(out_buf, "batch", None, None, None)

    # combine (row-local gather)
    gathered = out_buf[jnp.arange(B)[:, None], flat_e, pos_c] \
        * keep[..., None]                                       # (B, S*K, D)
    weighted = gathered * gate_vals.reshape(B, S * K, 1).astype(x.dtype)
    out = weighted.reshape(B, S, K, D).sum(axis=2)

    if moe.d_shared:
        sh = jax.nn.silu(x @ params["ws_gate"]) * (x @ params["ws_in"])
        sh = sh @ params["ws_out"]
        sgate = jax.nn.sigmoid(
            x.astype(jnp.float32) @ params["shared_gate"][:, None])
        out = out + sh * sgate.astype(x.dtype)

    return out, aux
