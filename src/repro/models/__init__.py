from .config import ArchConfig, MoEConfig                         # noqa: F401
from .transformer import (TransformerLM, init_params,             # noqa: F401
                          make_train_step, make_prefill_step,
                          make_decode_step)
