"""Pure-jnp oracle for stacked per-leaf filter MLP inference."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def filter_predict(w1: jnp.ndarray, b1: jnp.ndarray, w2: jnp.ndarray,
                   b2: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """w1 (F,m,h), b1 (F,h), w2 (F,h), b2 (F,) × queries (Q,m) → (F,Q)."""

    def one(w1_i, b1_i, w2_i, b2_i):
        hidden = jax.nn.relu(
            queries.astype(jnp.float32) @ w1_i.astype(jnp.float32) + b1_i
        )
        return hidden @ w2_i.astype(jnp.float32) + b2_i

    return jax.vmap(one)(w1, b1, w2, b2)
