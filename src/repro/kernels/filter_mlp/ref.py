"""Pure-jnp oracles for stacked per-leaf filter MLP inference.

``filter_predict`` is the parity oracle every kernel variant (per-filter,
fused, bf16, int8) is tested against; the quantized variants are checked
against it evaluated on the *dequantized* weights, so one oracle covers the
whole family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def filter_predict(w1: jnp.ndarray, b1: jnp.ndarray, w2: jnp.ndarray,
                   b2: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """w1 (F,m,h), b1 (F,h), w2 (F,h), b2 (F,) × queries (Q,m) → (F,Q)."""

    def one(w1_i, b1_i, w2_i, b2_i):
        hidden = jax.nn.relu(
            queries.astype(jnp.float32) @ w1_i.astype(jnp.float32) + b1_i
        )
        return hidden @ w2_i.astype(jnp.float32) + b2_i

    return jax.vmap(one)(w1, b1, w2, b2)


def dequantize_weights(w1, w2, w1_scale=None, w2_scale=None):
    """Effective float32 weights of a (possibly compressed) filter stack.

    int8 payloads are rescaled by their per-filter max-abs/127 scales;
    bf16 payloads upcast; float32 passes through untouched.
    """
    if w1_scale is not None:
        w1 = w1.astype(jnp.float32) * w1_scale[:, None, None]
    if w2_scale is not None:
        w2 = w2.astype(jnp.float32) * w2_scale[:, None]
    return w1.astype(jnp.float32), w2.astype(jnp.float32)


def filter_predict_destd(w1, b1, w2, b2, y_mean, y_std, queries,
                         offsets=None, w1_scale=None, w2_scale=None
                         ) -> jnp.ndarray:
    """De-standardized (and offset-adjusted) predictions → (F, Q).

    The unfused composition the megakernel's epilogue is pinned against:
    raw z, then z·y_std + y_mean, then −offsets — same op order, so interpret
    runs of the fused kernel must match it bitwise (tests/test_kernels.py).
    """
    w1f, w2f = dequantize_weights(w1, w2, w1_scale, w2_scale)
    z = filter_predict(w1f, b1, w2f, b2, queries)
    out = z * y_std[:, None] + y_mean[:, None]
    if offsets is not None:
        out = out - offsets[:, None]
    return out
