"""Stacked per-leaf MLP inference Pallas kernel.

The paper runs one tiny MLP per visited leaf on a GPU, one call at a time.
On TPU we stack all F filters' weights — w1 (F, m, h), b1 (F, h), w2 (F, h),
b2 (F,) — and evaluate every (filter × query) pair in a single grouped-matmul
kernel: grid (F, Q/bq); each step loads one filter's weights into VMEM and
pushes a bq-query tile through the two layers on the MXU.

VMEM per step at m = h = 256, bq = 128: w1 block 256 KiB + query tile 128 KiB
+ hidden 128 KiB — small enough that the filter-weight stream (one (m,h)
block per grid step) stays double-buffered from HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(q_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)                       # (bq, m)
    w1 = w1_ref[0].astype(jnp.float32)                       # (m, h)
    hidden = jnp.maximum(
        jax.lax.dot_general(q, w1, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + b1_ref[...].astype(jnp.float32),                   # (bq, h)
        0.0,
    )
    w2 = w2_ref[...].astype(jnp.float32)                     # (1, h)
    out = jax.lax.dot_general(hidden, w2, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (bq, 1)
    o_ref[...] = out.T + b2_ref[...]                         # (1, bq)


def filter_mlp_kernel(
    queries: jnp.ndarray,          # (Q, m), Q multiple of bq
    w1: jnp.ndarray,               # (F, m, h)
    b1: jnp.ndarray,               # (F, h)
    w2: jnp.ndarray,               # (F, h)
    b2: jnp.ndarray,               # (F, 1)
    *,
    bq: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    Q, m = queries.shape
    F, _, h = w1.shape
    grid = (F, Q // bq)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, m), lambda f, q: (q, 0)),
            pl.BlockSpec((1, m, h), lambda f, q: (f, 0, 0)),
            pl.BlockSpec((1, h), lambda f, q: (f, 0)),
            pl.BlockSpec((1, h), lambda f, q: (f, 0)),
            pl.BlockSpec((1, 1), lambda f, q: (f, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq), lambda f, q: (f, q)),
        out_shape=jax.ShapeDtypeStruct((F, Q), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel"))
        ) if not interpret else None,
        interpret=interpret,
    )(queries, w1, b1, w2, b2)
