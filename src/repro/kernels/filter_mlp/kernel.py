"""Stacked per-leaf MLP inference Pallas kernels.

The paper runs one tiny MLP per visited leaf on a GPU, one call at a time.
On TPU we stack all F filters' weights — w1 (F, m, h), b1 (F, h), w2 (F, h),
b2 (F,) — and evaluate every (filter × query) pair in grouped-matmul kernels.
Two grid layouts:

* ``filter_mlp_kernel`` — the original per-filter sweep: grid (F, Q/bq);
  each step loads ONE filter's (m, h) weight block into VMEM and pushes a
  bq-query tile through the two layers.  The query tile is re-streamed from
  HBM once per filter, so the sweep is weight/query-bandwidth-bound and
  F-linear regardless of batch size.

* ``fused_filter_mlp_kernel`` — the filter-block megakernel: grid
  (F/bf, Q/bq).  The stacked weights are pre-grouped outside the kernel into
  (F/bf, m, bf·h) layer-1 blocks and (F/bf, bf·h) layer-2 rows, so each step
  evaluates ``bf`` filters with ONE (bq, m) × (m, bf·h) MXU matmul — the
  VMEM-resident query tile is amortized across bf filters' weights (a bf×
  cut of the query re-stream) and the single wide matmul keeps the MXU fed
  where bf narrow ones would each pay their own latency.  Layer 2 is an
  elementwise multiply with the grouped w2 row followed by a per-group sum,
  expressed as a matmul against a block-diagonal 0/1 group-sum operand so it
  also runs on the MXU.  The epilogue applies b2, the per-filter
  ``y_mean``/``y_std`` de-standardization and the conformal offset
  subtraction in-register, so the megakernel's output is the search-ready
  d_F block — no separate broadcast passes over the (F, Q) output.

The fused kernel also takes compressed weights: bf16 blocks are upcast on
load (half the weight stream), int8 blocks carry per-filter max-abs/127
scales (``optim.compress``'s symmetric scheme at filter granularity, a 4×
cut) and the scales are folded in after the matmul — algebraically exact
w.r.t. dequantize-then-multiply because each scale is constant per output
column.

VMEM per fused step at m = h = 128, bf = 8, bq = 128: w1 block 512 KiB f32
(128 KiB int8) + query tile 64 KiB + hidden 512 KiB — comfortably
double-buffered.  int8 caveat: the (1, bf·h) layer-2 blocks have a
single-sublane layout that real-MXU Mosaic may reject (min int8 tile is
(32, 128)); the path is interpret-validated here and flagged for on-device
tuning in the ROADMAP's hardware-gated measurement item.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(q_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)                       # (bq, m)
    w1 = w1_ref[0].astype(jnp.float32)                       # (m, h)
    hidden = jnp.maximum(
        jax.lax.dot_general(q, w1, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + b1_ref[...].astype(jnp.float32),                   # (bq, h)
        0.0,
    )
    w2 = w2_ref[...].astype(jnp.float32)                     # (1, h)
    out = jax.lax.dot_general(hidden, w2, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (bq, 1)
    o_ref[...] = out.T + b2_ref[...]                         # (1, bq)


def filter_mlp_kernel(
    queries: jnp.ndarray,          # (Q, m), Q multiple of bq
    w1: jnp.ndarray,               # (F, m, h)
    b1: jnp.ndarray,               # (F, h)
    w2: jnp.ndarray,               # (F, h)
    b2: jnp.ndarray,               # (F, 1)
    *,
    bq: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    Q, m = queries.shape
    F, _, h = w1.shape
    grid = (F, Q // bq)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, m), lambda f, q: (q, 0)),
            pl.BlockSpec((1, m, h), lambda f, q: (f, 0, 0)),
            pl.BlockSpec((1, h), lambda f, q: (f, 0)),
            pl.BlockSpec((1, h), lambda f, q: (f, 0)),
            pl.BlockSpec((1, 1), lambda f, q: (f, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq), lambda f, q: (f, q)),
        out_shape=jax.ShapeDtypeStruct((F, Q), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel"))
        ) if not interpret else None,
        interpret=interpret,
    )(queries, w1, b1, w2, b2)


# ---------------------------------------------------------------------------
# fused filter-block megakernel
# ---------------------------------------------------------------------------


def _group_sum_operand(bfh: int, bf: int, h: int) -> jnp.ndarray:
    """(bf·h, bf) block-diagonal 0/1 matrix: column f sums its filter's h
    hidden lanes.  Built from iota so it materializes in-register — no HBM
    operand, and the layer-2 reduction stays a plain MXU matmul."""
    row = jax.lax.broadcasted_iota(jnp.int32, (bfh, bf), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (bfh, bf), 1)
    return (row // h == col).astype(jnp.float32)


def _fused_body(q_ref, w1_ref, b1_ref, w2_ref, b2_ref, ym_ref, ys_ref,
                off_ref, o_ref, *, h: int, bf: int):
    q = q_ref[...].astype(jnp.float32)                       # (bq, m)
    w1 = w1_ref[0].astype(jnp.float32)                       # (m, bf·h)
    hidden = jnp.maximum(
        jax.lax.dot_general(q, w1, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + b1_ref[...],                                       # (bq, bf·h)
        0.0,
    )
    w2 = w2_ref[...].astype(jnp.float32)                     # (1, bf·h)
    hw = hidden * w2                                         # (bq, bf·h)
    z = jax.lax.dot_general(
        hw, _group_sum_operand(hw.shape[1], bf, h),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b2_ref[...]    # (bq, bf)
    # epilogue: de-standardize + conformal offset, same op order as the
    # unfused composition (z·y_std + y_mean, then −offset) so the fused
    # output is bitwise-equal to it.
    o_ref[...] = (z * ys_ref[...] + ym_ref[...] - off_ref[...]).T


def _fused_body_q(q_ref, w1_ref, s1_ref, b1_ref, w2_ref, s2_ref, b2_ref,
                  ym_ref, ys_ref, off_ref, o_ref, *, h: int, bf: int):
    """int8 variant: weights arrive quantized; per-filter scales are folded
    in after the layer-1 matmul (exact per output column) and into the
    grouped w2 row before the elementwise multiply."""
    q = q_ref[...].astype(jnp.float32)                       # (bq, m)
    w1 = w1_ref[0].astype(jnp.float32)                       # (m, bf·h) deq.
    hidden = jnp.maximum(
        jax.lax.dot_general(q, w1, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        * s1_ref[...]                                        # (1, bf·h)
        + b1_ref[...],
        0.0,
    )
    w2 = w2_ref[...].astype(jnp.float32) * s2_ref[...]       # (1, bf·h)
    hw = hidden * w2
    z = jax.lax.dot_general(
        hw, _group_sum_operand(hw.shape[1], bf, h),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b2_ref[...]
    o_ref[...] = (z * ys_ref[...] + ym_ref[...] - off_ref[...]).T


def fused_filter_mlp_kernel(
    queries: jnp.ndarray,          # (Q, m), Q multiple of bq
    w1g: jnp.ndarray,              # (G, m, bf·h) grouped layer-1 blocks
    b1g: jnp.ndarray,              # (G, bf·h) float32
    w2g: jnp.ndarray,              # (G, bf·h)
    b2g: jnp.ndarray,              # (G, bf) float32
    ymg: jnp.ndarray,              # (G, bf) per-filter y_mean
    ysg: jnp.ndarray,              # (G, bf) per-filter y_std
    offg: jnp.ndarray,             # (G, bf) conformal offsets (zeros = none)
    *,
    s1g: jnp.ndarray | None = None,   # (G, bf·h) int8 scales, expanded
    s2g: jnp.ndarray | None = None,   # (G, bf·h)
    bq: int = 128,
    bf: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Grouped operands → (G·bf, Q) de-standardized, offset-adjusted preds.

    ``w1g``/``w2g`` may be float32, bfloat16 or int8; int8 requires the
    expanded per-filter scale rows.  Grouping/padding is the wrapper's job
    (:func:`repro.kernels.filter_mlp.ops.pack_fused`).
    """
    Q, m = queries.shape
    G, _, bfh = w1g.shape
    h = bfh // bf
    quantized = s1g is not None
    body = functools.partial(
        _fused_body_q if quantized else _fused_body, h=h, bf=bf)
    vec_spec = pl.BlockSpec((1, bfh), lambda g, t: (g, 0))
    flt_spec = pl.BlockSpec((1, bf), lambda g, t: (g, 0))
    in_specs = [
        pl.BlockSpec((bq, m), lambda g, t: (t, 0)),
        pl.BlockSpec((1, m, bfh), lambda g, t: (g, 0, 0)),
    ]
    operands = [queries, w1g]
    if quantized:
        in_specs.append(vec_spec)
        operands.append(s1g)
    in_specs += [vec_spec, vec_spec]
    operands += [b1g, w2g]
    if quantized:
        in_specs.append(vec_spec)
        operands.append(s2g)
    in_specs += [flt_spec, flt_spec, flt_spec, flt_spec]
    operands += [b2g, ymg, ysg, offg]
    return pl.pallas_call(
        body,
        grid=(G, Q // bq),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bf, bq), lambda g, t: (g, t)),
        out_shape=jax.ShapeDtypeStruct((G * bf, Q), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel"))
        ) if not interpret else None,
        interpret=interpret,
    )(*operands)
