"""Jitted wrapper for stacked filter-MLP inference."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def filter_predict(
    w1: jnp.ndarray,               # (F, m, h)
    b1: jnp.ndarray,               # (F, h)
    w2: jnp.ndarray,               # (F, h)
    b2: jnp.ndarray,               # (F,)
    queries: jnp.ndarray,          # (Q, m)
    *,
    bq: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """All-filters × all-queries predictions → (F, Q) float32.

    Zero-padding on m and h is exact: padded input dims meet zero w1 rows;
    padded hidden dims have zero b1/w2, so relu(0)·0 contributes nothing.
    Off-TPU the jnp oracle runs (see l2_scan.ops for the rationale).
    """
    if interpret is None:
        if _use_interpret():
            return ref.filter_predict(w1, b1, w2, b2, queries)
        interpret = False
    F, m, h = w1.shape
    Q = queries.shape[0]
    qp = _pad_to(_pad_to(queries, bq, 0), 128, 1)
    w1p = _pad_to(_pad_to(w1, 128, 1), 128, 2)
    b1p = _pad_to(b1, 128, 1)
    w2p = _pad_to(w2, 128, 1)
    out = kernel.filter_mlp_kernel(
        qp, w1p, b1p, w2p, b2[:, None], bq=bq, interpret=interpret
    )
    return out[:, :Q]


reference = ref.filter_predict
