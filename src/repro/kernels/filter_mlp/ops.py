"""Jitted wrappers for stacked filter-MLP inference.

Two entry points over the same stacked parameters:

* :func:`filter_predict` — the original per-filter-step kernel (grid (F,
  Q/bq)), kept as the baseline the fused path is benchmarked against.
* :func:`filter_predict_fused` — the filter-block megakernel (grid (F/bf,
  Q/bq)) with the de-standardization/offset epilogue fused in and optional
  bf16/int8 compressed weights; this is what the search path runs on TPU.

Zero-padding on m and h is exact: padded input dims meet zero w1 rows;
padded hidden dims have zero b1/w2, so relu(0)·0 contributes nothing.
Padded filters (F → bf multiple) have all-zero weights *and stats*, so their
rows are finite garbage-free zeros and are sliced off.  Off-TPU the jnp
oracle runs (see kernels/common.py for the rationale).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref
from ..common import pad_to as _pad_to, use_interpret as _use_interpret


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def filter_predict(
    w1: jnp.ndarray,               # (F, m, h)
    b1: jnp.ndarray,               # (F, h)
    w2: jnp.ndarray,               # (F, h)
    b2: jnp.ndarray,               # (F,)
    queries: jnp.ndarray,          # (Q, m)
    *,
    bq: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """All-filters × all-queries raw predictions → (F, Q) float32."""
    if interpret is None:
        if _use_interpret():
            return ref.filter_predict(w1, b1, w2, b2, queries)
        interpret = False
    F, m, h = w1.shape
    Q = queries.shape[0]
    qp = _pad_to(_pad_to(queries, bq, 0), 128, 1)
    w1p = _pad_to(_pad_to(w1, 128, 1), 128, 2)
    b1p = _pad_to(b1, 128, 1)
    w2p = _pad_to(w2, 128, 1)
    out = kernel.filter_mlp_kernel(
        qp, w1p, b1p, w2p, b2[:, None], bq=bq, interpret=interpret
    )
    return out[:, :Q]


def pack_fused(w1, b1, w2, b2, y_mean, y_std, offsets=None,
               w1_scale=None, w2_scale=None, *, bf: int = 8) -> dict:
    """Stacked (F, …) params → the megakernel's grouped, padded operands.

    Layer-1 weights become (G, m', bf·h') blocks (filter-major within the
    lane axis: lane j of group g is filter ``g·bf + j//h'``), layer-2 rows
    and per-filter vectors follow the same layout.  int8 scales are expanded
    to per-lane rows here so the kernel's dequant is a plain broadcast
    multiply.  Grouping is cheap (one transpose-copy of the weight bytes)
    but callers on a hot loop should pack once and reuse.
    """
    F, m, h = w1.shape
    G = -(-F // bf)
    w1p = _pad_to(_pad_to(_pad_to(w1, 128, 1), 128, 2), bf, 0)
    hp = w1p.shape[2]
    w1g = w1p.reshape(G, bf, w1p.shape[1], hp).transpose(0, 2, 1, 3)
    out = {
        "w1g": w1g.reshape(G, w1p.shape[1], bf * hp),
        "b1g": _pad_to(_pad_to(b1, 128, 1), bf, 0)
        .astype(jnp.float32).reshape(G, bf * hp),
        "w2g": _pad_to(_pad_to(w2, 128, 1), bf, 0).reshape(G, bf * hp),
        "b2g": _pad_to(b2, bf, 0).astype(jnp.float32).reshape(G, bf),
        "ymg": _pad_to(y_mean, bf, 0).astype(jnp.float32).reshape(G, bf),
        "ysg": _pad_to(y_std, bf, 0).astype(jnp.float32).reshape(G, bf),
        "offg": (jnp.zeros((G, bf), jnp.float32) if offsets is None else
                 _pad_to(offsets.astype(jnp.float32), bf, 0).reshape(G, bf)),
    }
    for name, s in (("s1g", w1_scale), ("s2g", w2_scale)):
        if s is not None:
            srow = jnp.broadcast_to(
                _pad_to(s.astype(jnp.float32), bf, 0)[:, None],
                (G * bf, hp))
            out[name] = srow.reshape(G, bf * hp)
    return out


@functools.partial(jax.jit, static_argnames=("bq", "bf", "interpret"))
def filter_predict_fused(
    w1: jnp.ndarray,               # (F, m, h) f32 | bf16 | int8
    b1: jnp.ndarray,               # (F, h) float32
    w2: jnp.ndarray,               # (F, h) f32 | bf16 | int8
    b2: jnp.ndarray,               # (F,) float32
    y_mean: jnp.ndarray,           # (F,) de-standardization stats
    y_std: jnp.ndarray,            # (F,)
    queries: jnp.ndarray,          # (Q, m)
    offsets: jnp.ndarray | None = None,     # (F,) conformal offsets
    w1_scale: jnp.ndarray | None = None,    # (F,) int8 scales
    w2_scale: jnp.ndarray | None = None,
    *,
    bq: int = 128,
    bf: int = 8,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """De-standardized, offset-adjusted predictions → (F, Q) float32.

    One kernel launch replaces kernel + three broadcast passes (y_std,
    y_mean, offsets) over the (F, Q) output.  ``w1.dtype`` selects the
    variant: float32/bfloat16 load-and-upcast, int8 dequants in-kernel via
    the per-filter scales (both required then).
    """
    if interpret is None:
        if _use_interpret():
            return ref.filter_predict_destd(
                w1, b1, w2, b2, y_mean, y_std, queries, offsets,
                w1_scale, w2_scale)
        interpret = False
    if (w1.dtype == jnp.int8) != (w1_scale is not None):
        raise ValueError("int8 weights require w1_scale/w2_scale "
                         "(and float weights must not carry them)")
    F = w1.shape[0]
    Q = queries.shape[0]
    qp = _pad_to(_pad_to(queries, bq, 0), 128, 1)
    g = pack_fused(w1, b1, w2, b2, y_mean, y_std, offsets,
                   w1_scale, w2_scale, bf=bf)
    out = kernel.fused_filter_mlp_kernel(
        qp, g["w1g"], g["b1g"], g["w2g"], g["b2g"], g["ymg"], g["ysg"],
        g["offg"], s1g=g.get("s1g"), s2g=g.get("s2g"),
        bq=bq, bf=bf, interpret=interpret)
    return out[:F, :Q]


reference = ref.filter_predict
fused_reference = ref.filter_predict_destd
