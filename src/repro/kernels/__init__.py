"""Pallas TPU kernels for LeaFi's compute hot spots.

The paper's search cost decomposes into (1) leaf scans — batched L2 distance
computations, (2) learned-filter inference — thousands of tiny per-leaf MLPs,
and (3) summarization lower bounds.  Each gets a kernel:

* ``l2_scan``     — tiled (query × series) L2 distances on the MXU via the
                    ‖q−s‖² = ‖q‖² + ‖s‖² − 2·q·s decomposition.
* ``filter_mlp``  — stacked per-leaf MLP inference (the TPU-native
                    replacement for the paper's per-leaf GPU calls): a
                    per-filter grid kernel, plus the fused filter-block
                    megakernel — bf filters per grid step as one wide
                    grouped matmul, de-standardization + conformal offsets
                    fused into the epilogue, and bf16/int8 weight variants
                    with in-kernel dequant (``benchmarks/filters_bench.py``).
* ``box_lb``      — box lower bounds; both the iSAX MINDIST and the DSTree
                    EAPCA bound reduce to it after pre-scaling (see ops).

Every kernel ships ``ref.py`` (pure-jnp oracle) and ``ops.py`` (jitted
wrapper); helpers shared across wrappers (backend detection, padding) live
in ``common.py``.  Off-TPU the wrappers run the oracle unless a test forces
``interpret=True``.  Shape/dtype sweeps live in ``tests/test_kernels.py``.
"""
from .l2_scan import ops as l2_scan        # noqa: F401
from .filter_mlp import ops as filter_mlp  # noqa: F401
from .box_lb import ops as box_lb          # noqa: F401
