"""Tiled pairwise-L2 Pallas kernel (the LeaFi leaf-scan hot spot).

MESSI scans leaves with SIMD CPU loops; on TPU the same computation is a
matmul: ‖q−s‖² = ‖q‖² + ‖s‖² − 2·q·sᵀ, so the MXU does the heavy lifting.

Grid = (Q/bq, B/bb, m/bk).  The k axis accumulates −2·q·sᵀ into the output
block (index map independent of k); on the last k step the norms are fused in
and the sqrt epilogue runs.  f32 accumulation throughout; inputs may be bf16.

VMEM working set per step: q (bq·bk), s (bb·bk), out (bq·bb) — at the default
128³ tiling ≈ 3 × 64 KiB, comfortably inside the ~16 MiB VMEM budget, leaving
room for double buffering of the q/s streams from HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _l2_kernel(q_ref, s_ref, qn_ref, sn_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    o_ref[...] += -2.0 * jax.lax.dot_general(
        q, s, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        d2 = o_ref[...] + qn_ref[...].T + sn_ref[...]
        o_ref[...] = jnp.sqrt(jnp.maximum(d2, 0.0))


def pairwise_l2_kernel(
    queries: jnp.ndarray,          # (Q, m) — Q, m multiples of the tile
    series: jnp.ndarray,           # (B, m)
    q_norms: jnp.ndarray,          # (1, Q) squared norms
    s_norms: jnp.ndarray,          # (1, B)
    *,
    bq: int = 128,
    bb: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    Q, m = queries.shape
    B, _ = series.shape
    nk = m // bk
    grid = (Q // bq, B // bb, nk)
    return pl.pallas_call(
        functools.partial(_l2_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bb, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, bq), lambda i, j, k: (0, i)),
            pl.BlockSpec((1, bb), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bq, bb), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, B), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(queries, series, q_norms, s_norms)


def _slab_l2_kernel(q_ref, s_ref, qn_ref, sn_ref, o_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0].astype(jnp.float32)
    s = s_ref[0].astype(jnp.float32)
    o_ref[0] += -2.0 * jax.lax.dot_general(
        q, s, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        d2 = o_ref[0] + qn_ref[0].T + sn_ref[0]
        o_ref[0] = jnp.sqrt(jnp.maximum(d2, 0.0))


def slab_l2_kernel(
    queries: jnp.ndarray,          # (F, Nq, m) per-slab query batches
    slabs: jnp.ndarray,            # (F, R, m) padded leaf slabs
    q_norms: jnp.ndarray,          # (F, 1, Nq) squared norms
    s_norms: jnp.ndarray,          # (F, 1, R)
    *,
    bq: int = 128,
    bb: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Batched pairwise-L2 over stacked leaf slabs → (F, Nq, R).

    The slab axis F rides as a leading parallel grid dimension (block width
    1): each grid step runs the same ‖q‖²+‖s‖²−2·q·sᵀ accumulation as
    :func:`pairwise_l2_kernel` on one slab's tile, so the F filters of the
    build pipeline share a single kernel launch instead of F dispatches.
    """
    F, Nq, m = queries.shape
    _, R, _ = slabs.shape
    nk = m // bk
    grid = (F, Nq // bq, R // bb, nk)
    return pl.pallas_call(
        functools.partial(_slab_l2_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, bk), lambda f, i, j, k: (f, i, k)),
            pl.BlockSpec((1, bb, bk), lambda f, i, j, k: (f, j, k)),
            pl.BlockSpec((1, 1, bq), lambda f, i, j, k: (f, 0, i)),
            pl.BlockSpec((1, 1, bb), lambda f, i, j, k: (f, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, bb), lambda f, i, j, k: (f, i, j)),
        out_shape=jax.ShapeDtypeStruct((F, Nq, R), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=(
                "parallel", "parallel", "parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(queries, slabs, q_norms, s_norms)
