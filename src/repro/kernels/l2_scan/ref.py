"""Pure-jnp oracle for the l2_scan kernel."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_l2(queries: jnp.ndarray, series: jnp.ndarray) -> jnp.ndarray:
    """Exact pairwise euclidean distances, direct form.  (Q, m) × (B, m) → (Q, B)."""
    diff = queries[:, None, :].astype(jnp.float32) - series[None, :, :].astype(jnp.float32)
    return jnp.sqrt((diff * diff).sum(-1))


def pairwise_l2_matmul(queries: jnp.ndarray, series: jnp.ndarray) -> jnp.ndarray:
    """Matmul-decomposed form (what the kernel computes), for tolerance studies."""
    q = queries.astype(jnp.float32)
    s = series.astype(jnp.float32)
    qn = (q * q).sum(-1)
    sn = (s * s).sum(-1)
    d2 = qn[:, None] + sn[None, :] - 2.0 * (q @ s.T)
    return jnp.sqrt(jnp.maximum(d2, 0.0))
