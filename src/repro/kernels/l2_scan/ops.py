"""Jitted wrapper around the l2_scan kernel: padding, norms, masking, min."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref

_INF = jnp.float32(jnp.inf)


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bq", "bb", "bk", "interpret"))
def pairwise_l2(
    queries: jnp.ndarray,
    series: jnp.ndarray,
    *,
    bq: int = 128,
    bb: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(Q, m) × (B, m) → (Q, B) euclidean distances via the Pallas kernel.

    Off-TPU (interpret=None) the mathematically-identical jnp oracle runs
    instead: Pallas interpret mode executes the kernel body per grid step in
    Python — fine for validation (tests pass interpret=True explicitly),
    hopeless for the benchmark workloads.
    """
    if interpret is None:
        if _use_interpret():
            return ref.pairwise_l2_matmul(queries, series)
        interpret = False
    Q, m = queries.shape
    B, _ = series.shape
    bk = min(bk, max(128, 1 << (m - 1).bit_length()))  # never exceed padded m
    qp = _pad_to(_pad_to(queries, bq, 0), bk, 1)
    sp = _pad_to(_pad_to(series, bb, 0), bk, 1)
    qn = (qp.astype(jnp.float32) ** 2).sum(-1)[None, :]
    sn = (sp.astype(jnp.float32) ** 2).sum(-1)[None, :]
    out = kernel.pairwise_l2_kernel(
        qp, sp, qn, sn, bq=bq, bb=bb, bk=bk, interpret=interpret
    )
    return out[:Q, :B]


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_min_l2(
    queries: jnp.ndarray,          # (Q, m)
    slab: jnp.ndarray,             # (B, m) leaf slab (may contain padding)
    valid: jnp.ndarray,            # (B,) bool
    *,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query min distance over the valid rows of a leaf slab.

    Returns (min_dist (Q,), argmin (Q,) — index into the slab).
    """
    d = pairwise_l2(queries, slab, interpret=interpret)
    d = jnp.where(valid[None, :], d, _INF)
    return d.min(axis=1), d.argmin(axis=1)


def default_gathered_impl() -> str:
    """Distance formulation the search engine should use on this backend.

    ``matmul`` is the kernel's decomposition (‖q‖² + ‖s‖² − 2·q·sᵀ): for the
    per-query gathered slabs of the compact search engine it lowers to one
    batched GEMM, which is the MXU mapping of the candidate pass.  Off-TPU we
    default to ``direct`` (elementwise diff-square), which is bitwise-stable
    against the sequential scan path — the engine's parity suite relies on
    that.
    """
    return "matmul" if jax.default_backend() == "tpu" else "direct"


def gathered_leaf_l2(
    queries: jnp.ndarray,          # (N, m)
    slabs: jnp.ndarray,            # (N, C, R, m) per-query gathered leaf rows
    impl: str | None = None,
) -> jnp.ndarray:
    """Euclidean distances from each query to its own candidate slab.

    Unlike :func:`pairwise_l2` (one shared series block for all queries) each
    query here owns a different (C·R)-row candidate set — the output of the
    engine's survivor compaction — so the all-pairs kernel would recompute
    every other query's candidates too.  The ``matmul`` impl keeps the
    kernel's exact algebra but contracts per query (batched GEMM → MXU); the
    ``direct`` impl matches the scan path bit-for-bit.  Returns (N, C, R).
    """
    impl = impl or default_gathered_impl()
    q = queries.astype(jnp.float32)
    s = slabs.astype(jnp.float32)
    if impl == "direct":
        diff = s - q[:, None, None, :]
        return jnp.sqrt((diff * diff).sum(-1))
    if impl == "matmul":
        qn = (q * q).sum(-1)
        sn = (s * s).sum(-1)
        dot = jnp.einsum("ncrm,nm->ncr", s, q,
                         preferred_element_type=jnp.float32)
        return jnp.sqrt(jnp.maximum(qn[:, None, None] + sn - 2.0 * dot, 0.0))
    raise ValueError(f"unknown gathered-l2 impl {impl!r}")


def leaf_topk(
    dists: jnp.ndarray,            # (N, C, R) masked distances (+inf invalid)
    rows: jnp.ndarray,             # (N, C, R) global row ids
    k: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-leaf k smallest distances and their row ids → ((N,C,k), (N,C,k)).

    ``lax.top_k`` breaks ties toward the lower index, i.e. toward the lower
    row within the leaf — the same order the sequential scan path merges
    candidates in, which keeps the engine's replay bitwise-faithful.
    """
    neg, arg = jax.lax.top_k(-dists, k)
    return -neg, jnp.take_along_axis(rows, arg, axis=-1).astype(jnp.int32)


# the oracle, re-exported for benchmarks that compare both paths
reference = ref.pairwise_l2
