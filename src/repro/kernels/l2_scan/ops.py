"""Jitted wrapper around the l2_scan kernel: padding, norms, masking, min."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref
from ... import sanitize
from ..common import pad_to as _pad_to, use_interpret as _use_interpret

_INF = jnp.float32(jnp.inf)


@functools.partial(jax.jit, static_argnames=("bq", "bb", "bk", "interpret"))
def pairwise_l2(
    queries: jnp.ndarray,
    series: jnp.ndarray,
    *,
    bq: int = 128,
    bb: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(Q, m) × (B, m) → (Q, B) euclidean distances via the Pallas kernel.

    Off-TPU (interpret=None) the mathematically-identical jnp oracle runs
    instead: Pallas interpret mode executes the kernel body per grid step in
    Python — fine for validation (tests pass interpret=True explicitly),
    hopeless for the benchmark workloads.
    """
    if interpret is None:
        if _use_interpret():
            return ref.pairwise_l2_matmul(queries, series)
        interpret = False
    Q, m = queries.shape
    B, _ = series.shape
    bk = min(bk, max(128, 1 << (m - 1).bit_length()))  # never exceed padded m
    qp = _pad_to(_pad_to(queries, bq, 0), bk, 1)
    sp = _pad_to(_pad_to(series, bb, 0), bk, 1)
    qn = (qp.astype(jnp.float32) ** 2).sum(-1)[None, :]
    sn = (sp.astype(jnp.float32) ** 2).sum(-1)[None, :]
    out = kernel.pairwise_l2_kernel(
        qp, sp, qn, sn, bq=bq, bb=bb, bk=bk, interpret=interpret
    )
    return out[:Q, :B]


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_min_l2(
    queries: jnp.ndarray,          # (Q, m)
    slab: jnp.ndarray,             # (B, m) leaf slab (may contain padding)
    valid: jnp.ndarray,            # (B,) bool
    *,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query min distance over the valid rows of a leaf slab.

    Returns (min_dist (Q,), argmin (Q,) — index into the slab).
    """
    d = pairwise_l2(queries, slab, interpret=interpret)
    d = jnp.where(valid[None, :], d, _INF)
    return d.min(axis=1), d.argmin(axis=1)


def default_gathered_impl() -> str:
    """Distance formulation the search engine should use on this backend.

    ``matmul`` is the kernel's decomposition (‖q‖² + ‖s‖² − 2·q·sᵀ): for the
    per-query gathered slabs of the compact search engine it lowers to one
    batched GEMM, which is the MXU mapping of the candidate pass.  Off-TPU we
    default to ``direct`` (elementwise diff-square), which is bitwise-stable
    against the sequential scan path — the engine's parity suite relies on
    that.
    """
    return "matmul" if jax.default_backend() == "tpu" else "direct"


def gathered_leaf_l2(
    queries: jnp.ndarray,          # (N, m)
    slabs: jnp.ndarray,            # (N, C, R, m) per-query gathered leaf rows
    impl: str | None = None,
) -> jnp.ndarray:
    """Euclidean distances from each query to its own candidate slab.

    Unlike :func:`pairwise_l2` (one shared series block for all queries) each
    query here owns a different (C·R)-row candidate set — the output of the
    engine's survivor compaction — so the all-pairs kernel would recompute
    every other query's candidates too.  The ``matmul`` impl keeps the
    kernel's exact algebra but contracts per query (batched GEMM → MXU); the
    ``direct`` impl matches the scan path bit-for-bit.  Returns (N, C, R).
    """
    impl = impl or default_gathered_impl()
    q = queries.astype(jnp.float32)
    s = slabs.astype(jnp.float32)
    if impl == "direct":
        diff = s - q[:, None, None, :]
        return jnp.sqrt((diff * diff).sum(-1))
    if impl == "matmul":
        qn = (q * q).sum(-1)
        sn = (s * s).sum(-1)
        dot = jnp.einsum("ncrm,nm->ncr", s, q,
                         preferred_element_type=jnp.float32)
        return jnp.sqrt(jnp.maximum(qn[:, None, None] + sn - 2.0 * dot, 0.0))
    raise ValueError(f"unknown gathered-l2 impl {impl!r}")


def leaf_topk(
    dists: jnp.ndarray,            # (N, C, R) masked distances (+inf invalid)
    rows: jnp.ndarray,             # (N, C, R) global row ids
    k: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-leaf k smallest distances and their row ids → ((N,C,k), (N,C,k)).

    ``lax.top_k`` breaks ties toward the lower index, i.e. toward the lower
    row within the leaf — the same order the sequential scan path merges
    candidates in, which keeps the engine's replay bitwise-faithful.
    """
    neg, arg = jax.lax.top_k(-dists, k)
    return -neg, jnp.take_along_axis(rows, arg, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Leaf-slab batch layer: padded (F, R, m) gathers + vmapped masked primitives.
# The build pipeline (filter_training via core/engine.py) and the engine's
# pairwise candidate pass are expressed on these instead of per-leaf loops.
# ---------------------------------------------------------------------------


def gather_leaf_slabs(
    series: jnp.ndarray,           # (n + max_leaf, m) leaf-sorted, padded
    leaf_start: jnp.ndarray,       # (L,)
    leaf_size: jnp.ndarray,        # (L,)
    leaf_ids: jnp.ndarray,         # (F,) — ids == L are invalid sentinels
    max_leaf: int,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Padded leaf slabs for a batch of leaves.

    Returns (slabs (F, R, m), rows (F, R) global row ids, valid (F, R)).
    Invalid leaf ids (== L, the engine's padding convention) clamp their
    gathers harmlessly and come back with an all-False valid mask; the
    clamp is explicit (``jnp.minimum``), so ``REPRO_CHECKIFY=1`` eager
    calls (routed through ``repro.sanitize``) stay clean on healthy
    layouts and trip on genuinely corrupted ones (a ``leaf_start`` aimed
    past the padded series rows).
    """
    return sanitize.call(_gather_leaf_slabs, series, leaf_start, leaf_size,
                         leaf_ids, max_leaf)


def _gather_leaf_slabs(series, leaf_start, leaf_size, leaf_ids, max_leaf):
    L = leaf_start.shape[0]
    ids = jnp.asarray(leaf_ids)
    ok = ids < L
    safe = jnp.minimum(ids, L - 1)
    starts = leaf_start[safe]                            # (F,)
    sizes = jnp.where(ok, leaf_size[safe], 0)            # (F,)
    rows = starts[:, None] + jnp.arange(max_leaf)[None, :]
    slabs = series[rows]                                 # (F, R, m)
    valid = jnp.arange(max_leaf)[None, :] < sizes[:, None]
    return slabs, rows.astype(jnp.int32), valid


def default_slab_impl() -> str:
    """Distance formulation for the slab layer on this backend.

    On TPU the batched ``pairwise`` Pallas kernel tiles the MXU directly; off
    TPU ``matmul`` (the identical ‖q‖²+‖s‖²−2·q·sᵀ algebra as one einsum) is
    the fast XLA form.  Both share the matmul decomposition the seed build
    path already routed through, so build-side results stay within float
    tolerance of the per-leaf reference either way.
    """
    return "pairwise" if jax.default_backend() == "tpu" else "matmul"


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def slab_l2(
    queries: jnp.ndarray,          # (F, Nq, m) per-slab query batches
    slabs: jnp.ndarray,            # (F, R, m)
    impl: str | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Distances from each slab's own query batch to the slab → (F, Nq, R).

    impl: "direct" (elementwise, bitwise-stable vs the scan path), "matmul"
    (one einsum of the kernel's decomposition), or "pairwise" (the batched
    ``slab_l2_kernel`` Pallas path; off-TPU with interpret=None it falls back
    to the mathematically identical matmul form, as :func:`pairwise_l2`
    does).
    """
    impl = impl or default_slab_impl()
    q = queries.astype(jnp.float32)
    s = slabs.astype(jnp.float32)
    if impl == "direct":
        diff = q[:, :, None, :] - s[:, None, :, :]
        return jnp.sqrt((diff * diff).sum(-1))
    if impl == "matmul":
        qn = (q * q).sum(-1)                             # (F, Nq)
        sn = (s * s).sum(-1)                             # (F, R)
        dot = jnp.einsum("fqm,frm->fqr", q, s,
                         preferred_element_type=jnp.float32)
        return jnp.sqrt(jnp.maximum(
            qn[:, :, None] + sn[:, None, :] - 2.0 * dot, 0.0))
    if impl == "pairwise":
        if interpret is None:
            if _use_interpret():
                return slab_l2(queries, slabs, "matmul")
            interpret = False
        F, Nq, m = q.shape
        _, R, _ = s.shape
        bq = bb = bk = 128
        qp = _pad_to(_pad_to(q, bq, 1), bk, 2)
        sp = _pad_to(_pad_to(s, bb, 1), bk, 2)
        qn = (qp ** 2).sum(-1)[:, None, :]               # (F, 1, Nq')
        sn = (sp ** 2).sum(-1)[:, None, :]               # (F, 1, R')
        out = kernel.slab_l2_kernel(qp, sp, qn, sn, bq=bq, bb=bb, bk=bk,
                                    interpret=interpret)
        return out[:, :Nq, :R]
    raise ValueError(f"unknown slab-l2 impl {impl!r}")


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def shared_slab_l2(
    queries: jnp.ndarray,          # (Q, m) one query batch shared by all slabs
    slabs: jnp.ndarray,            # (C, R, m)
    impl: str | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Distances from a shared query batch to every slab → (Q, C, R).

    The all-pairs form: with impl="pairwise" the slabs flatten into one
    (C·R, m) block and the ``l2_scan`` Pallas kernel runs over it directly —
    this is the engine's union-slab candidate pass and the build side's
    all-leaves sweep.
    """
    impl = impl or default_slab_impl()
    q = queries.astype(jnp.float32)
    s = slabs.astype(jnp.float32)
    C, R, m = s.shape
    if impl == "direct":
        diff = q[:, None, None, :] - s[None, :, :, :]
        return jnp.sqrt((diff * diff).sum(-1))
    if impl == "matmul":
        qn = (q * q).sum(-1)                             # (Q,)
        sn = (s * s).sum(-1)                             # (C, R)
        dot = jnp.einsum("qm,crm->qcr", q, s,
                         preferred_element_type=jnp.float32)
        return jnp.sqrt(jnp.maximum(
            qn[:, None, None] + sn[None, :, :] - 2.0 * dot, 0.0))
    if impl == "pairwise":
        flat = s.reshape(C * R, m)
        d = pairwise_l2(q, flat, interpret=interpret)
        return d.reshape(q.shape[0], C, R)
    raise ValueError(f"unknown slab-l2 impl {impl!r}")


def slab_masked_min(
    dists: jnp.ndarray,            # (F, Nq, R)
    valid: jnp.ndarray,            # (F, R) bool
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Vmapped masked min over slab rows → (min (F, Nq), argmin (F, Nq)).

    The min-reduction half of the slab layer; its top-k sibling is
    :func:`leaf_topk`, which the engine's candidate passes call with
    broadcast row ids.
    """
    d = jnp.where(valid[:, None, :], dists, _INF)
    return d.min(axis=-1), d.argmin(axis=-1)


# the oracle, re-exported for benchmarks that compare both paths
reference = ref.pairwise_l2
