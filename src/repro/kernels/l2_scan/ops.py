"""Jitted wrapper around the l2_scan kernel: padding, norms, masking, min."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref

_INF = jnp.float32(jnp.inf)


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bq", "bb", "bk", "interpret"))
def pairwise_l2(
    queries: jnp.ndarray,
    series: jnp.ndarray,
    *,
    bq: int = 128,
    bb: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(Q, m) × (B, m) → (Q, B) euclidean distances via the Pallas kernel.

    Off-TPU (interpret=None) the mathematically-identical jnp oracle runs
    instead: Pallas interpret mode executes the kernel body per grid step in
    Python — fine for validation (tests pass interpret=True explicitly),
    hopeless for the benchmark workloads.
    """
    if interpret is None:
        if _use_interpret():
            return ref.pairwise_l2_matmul(queries, series)
        interpret = False
    Q, m = queries.shape
    B, _ = series.shape
    bk = min(bk, max(128, 1 << (m - 1).bit_length()))  # never exceed padded m
    qp = _pad_to(_pad_to(queries, bq, 0), bk, 1)
    sp = _pad_to(_pad_to(series, bb, 0), bk, 1)
    qn = (qp.astype(jnp.float32) ** 2).sum(-1)[None, :]
    sn = (sp.astype(jnp.float32) ** 2).sum(-1)[None, :]
    out = kernel.pairwise_l2_kernel(
        qp, sp, qn, sn, bq=bq, bb=bb, bk=bk, interpret=interpret
    )
    return out[:Q, :B]


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_min_l2(
    queries: jnp.ndarray,          # (Q, m)
    slab: jnp.ndarray,             # (B, m) leaf slab (may contain padding)
    valid: jnp.ndarray,            # (B,) bool
    *,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query min distance over the valid rows of a leaf slab.

    Returns (min_dist (Q,), argmin (Q,) — index into the slab).
    """
    d = pairwise_l2(queries, slab, interpret=interpret)
    d = jnp.where(valid[None, :], d, _INF)
    return d.min(axis=1), d.argmin(axis=1)


# the oracle, re-exported for benchmarks that compare both paths
reference = ref.pairwise_l2
