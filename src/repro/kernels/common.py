"""Helpers shared by every kernel's jitted wrapper.

Each ``ops.py`` used to carry its own copy of the backend probe and the
padding helpers; they live here once.  The conventions they encode:

* **interpret-vs-oracle**: off-TPU (``interpret=None``) the wrappers run the
  mathematically-identical jnp oracle instead of the Pallas kernel — Pallas
  interpret mode executes the kernel body per grid step in Python, fine for
  validation (tests pass ``interpret=True`` explicitly), hopeless for real
  workloads.
* **zero padding is exact by construction**: operands are padded up to the
  TPU tile multiples with values whose contribution is the identity of the
  reduction they feed (zeros for matmul/L2 terms, ±inf for box edges), and
  the wrapper slices the padding back off.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def use_interpret() -> bool:
    """True when the Pallas kernels should be bypassed for the jnp oracle."""
    return jax.default_backend() != "tpu"


def pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    """Zero-pad ``axis`` up to the next multiple of ``mult``."""
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pad_rows(x: jnp.ndarray, mult: int, fill: float) -> jnp.ndarray:
    """Pad the leading axis up to a multiple of ``mult`` with ``fill``."""
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)], axis=0
    )
