"""Unified box lower-bound Pallas kernel (iSAX MINDIST ∪ DSTree EAPCA LB).

Lower bounds are computed for *every* leaf on *every* query up front in the
LeaFi search (the pruning cascade then runs on scalars), so this kernel's
shape is (Q queries × L leaves × d box dims).  It is VPU-bound — elementwise
max/mul with a small reduction — so the tiling goal is purely bandwidth: keep
(bq × bl × d) intermediates inside VMEM and stream the (L, d) box edges once.

Grid = (Q/bq, L/bl); per-step working set at bq=bl=128, d=16:
128·128·16·4 B = 1 MiB for the broadcast intermediate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _box_kernel(q_ref, lo_ref, hi_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)              # (bq, d)
    lo = lo_ref[...].astype(jnp.float32)            # (bl, d)
    hi = hi_ref[...].astype(jnp.float32)
    d = jnp.maximum(
        jnp.maximum(lo[None, :, :] - q[:, None, :], q[:, None, :] - hi[None, :, :]),
        0.0,
    )
    d = jnp.where(jnp.isfinite(d), d, 0.0)
    o_ref[...] = jnp.sqrt((d * d).sum(-1))          # (bq, bl)


def box_lb_kernel(
    q: jnp.ndarray,                # (Q, d), Q multiple of bq
    lo: jnp.ndarray,               # (L, d), L multiple of bl
    hi: jnp.ndarray,               # (L, d)
    *,
    bq: int = 128,
    bl: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    Q, d = q.shape
    L, _ = lo.shape
    grid = (Q // bq, L // bl)
    return pl.pallas_call(
        _box_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bl, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bl, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bl), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, L), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel"))
        ) if not interpret else None,
        interpret=interpret,
    )(q, lo, hi)
