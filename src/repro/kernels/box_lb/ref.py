"""Pure-jnp oracle for the unified box lower-bound kernel."""
from __future__ import annotations

import jax.numpy as jnp


def box_lb(q: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """q (Q, d) vs boxes lo/hi (L, d) → (Q, L) sqrt of summed sq box dists.

    Both the iSAX MINDIST and the DSTree EAPCA lower bound reduce to this
    after pre-scaling the coordinates (see ops.sax_lb / ops.eapca_lb).
    """
    d = jnp.maximum(jnp.maximum(lo[None] - q[:, None], q[:, None] - hi[None]), 0.0)
    d = jnp.where(jnp.isfinite(d), d, 0.0)   # ±inf edges ⇒ open box sides
    return jnp.sqrt((d * d).sum(-1))
