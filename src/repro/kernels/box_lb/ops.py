"""Jitted wrappers: generic box_lb plus the two index-specific reductions.

* ``sax_lb``:   MINDIST(q, word)² = (m/l)·Σ_d boxdist(paa_d, [lo_d, hi_d])²
                → pre-scale the PAA coords and edges by sqrt(m/l).
* ``eapca_lb``: Σ_s w_s·(boxdist(μ)² + boxdist(σ)²)
                → concat the μ and σ coordinate blocks, pre-scaled by √w_s.

After pre-scaling, both are the plain box_lb kernel — one kernel, two bounds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref
from ..common import pad_rows as _pad_rows, use_interpret as _use_interpret


@functools.partial(jax.jit, static_argnames=("bq", "bl", "interpret"))
def box_lb(q, lo, hi, *, bq: int = 128, bl: int = 128,
           interpret: bool | None = None):
    """q (Q, d) vs boxes (L, d) → (Q, L).

    Off-TPU the jnp oracle runs (see l2_scan.ops for the rationale)."""
    if interpret is None:
        if _use_interpret():
            return ref.box_lb(q, lo, hi)
        interpret = False
    Q, L = q.shape[0], lo.shape[0]
    qp = _pad_rows(q, bq, 0.0)
    # padded boxes are (-inf, +inf) ⇒ lb 0; sliced off below.
    lop = _pad_rows(lo, bl, -jnp.inf)
    hip = _pad_rows(hi, bl, jnp.inf)
    out = kernel.box_lb_kernel(qp, lop, hip, bq=bq, bl=bl, interpret=interpret)
    return out[:Q, :L]


@functools.partial(jax.jit, static_argnames=("length", "interpret"))
def sax_lb(query_paa: jnp.ndarray, edges: jnp.ndarray, *, length: int,
           interpret: bool | None = None) -> jnp.ndarray:
    """query_paa (Q, l), edges (L, l, 2) → (Q, L) iSAX MINDIST."""
    wl = edges.shape[1]
    scale = jnp.sqrt(jnp.float32(length) / wl)
    return box_lb(query_paa * scale, edges[..., 0] * scale,
                  edges[..., 1] * scale, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def eapca_lb(query_stats: jnp.ndarray, boxes: jnp.ndarray,
             seg_len: jnp.ndarray, *,
             interpret: bool | None = None) -> jnp.ndarray:
    """query_stats (Q, s, 2), boxes (L, s, 4), seg_len (s,) → (Q, L)."""
    w = jnp.sqrt(seg_len.astype(jnp.float32))
    q = jnp.concatenate([query_stats[..., 0] * w, query_stats[..., 1] * w], -1)
    lo = jnp.concatenate([boxes[..., 0] * w, boxes[..., 2] * w], -1)
    hi = jnp.concatenate([boxes[..., 1] * w, boxes[..., 3] * w], -1)
    return box_lb(q, lo, hi, interpret=interpret)


reference = ref.box_lb
