"""Env-gated runtime sanitizer wiring (``REPRO_CHECKIFY=1``).

The engine's padded-slab layout makes out-of-bounds indexing *silent*: XLA
clamps OOB gather/dynamic-slice indices, so a corrupted ``leaf_start`` (or a
compaction bug that aims a gather past the series rows) reads garbage instead
of crashing — exactly the failure class that cost the padding-leaf probe bug
a debugging session (see CHANGES.md, PR 3).  This module threads
``jax.experimental.checkify`` through the engine's jitted passes
(``engine.run_cascade`` / ``replay_cascade`` / ``compact_bsf_cascade`` and
the leaf-slab gathers in ``kernels.l2_scan.ops``) so those failures are loud
in CI: ``REPRO_CHECKIFY=1 make test``.

Checks enabled: ``index_checks`` (OOB gather / scatter / dynamic-slice) and
``nan_checks``.  ``float_checks``'s inf detection is deliberately *not*
enabled — the cascade's ±inf sentinels (−inf ⇒ a filter that never prunes,
+inf padding distances and bsf seeds) are load-bearing, so inf-freedom is
not an invariant of this code; NaN-freedom and in-bounds indexing are.
Note ``index_checks`` flags OOB indices even under explicit
``mode="drop"``/``"clip"`` — which is why the engine scatters its sentinel
slots into a real scratch row instead of relying on drop semantics.

Dispatch contract of :func:`call`:

* sanitizer disabled (the default): straight call, zero overhead;
* any argument is a tracer (the callee is being traced inside an enclosing
  jit / shard_map / scan): straight call — the instrumentation boundary is
  the outermost *eager* call, because ``err.throw()`` needs concrete values;
* otherwise: the callee runs under ``checkify.checkify`` and any recorded
  error is thrown as ``checkify.JaxRuntimeError``.

Sanitizer mode re-traces the callee through checkify per call site (the
checkified wrapper is cached per function, the inner jit cache still
applies); it is a CI/debug configuration, not a serving one.
"""
from __future__ import annotations

import functools
import os

import jax


def enabled() -> bool:
    """True when ``REPRO_CHECKIFY`` is set to anything but ``""``/``"0"``."""
    return os.environ.get("REPRO_CHECKIFY", "0") not in ("", "0")


@functools.lru_cache(maxsize=None)
def _checkified(fn):
    from jax.experimental import checkify
    return checkify.checkify(
        fn, errors=checkify.index_checks | checkify.nan_checks)


def _has_tracer(args, kwargs) -> bool:
    return any(isinstance(x, jax.core.Tracer)
               for x in jax.tree_util.tree_leaves((args, kwargs)))


def call(fn, *args, **kwargs):
    """``fn(*args, **kwargs)``, checkify-instrumented when enabled.

    Static (hashable Python) kwargs pass through to the callee's own jit
    wrapper unchanged; checkify only functionalizes the array computation.
    """
    if not enabled() or _has_tracer(args, kwargs):
        return fn(*args, **kwargs)
    err, out = _checkified(fn)(*args, **kwargs)
    err.throw()
    return out
