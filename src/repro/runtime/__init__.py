from .elastic import (ElasticMeshManager, HeartbeatRegistry,     # noqa: F401
                      StragglerDetector)
