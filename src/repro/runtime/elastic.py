"""Fault tolerance and elasticity for pod-scale runs.

Three cooperating pieces, all host-side control plane (the data plane stays
pure XLA):

* ``HeartbeatRegistry`` — liveness tracking.  Hosts stamp a monotonic
  heartbeat; the controller marks hosts dead after ``timeout_s`` silence.
  (In-process here; the transport on a real cluster is a KV store — the
  interface is transport-agnostic on purpose.)
* ``StragglerDetector`` — per-host step-time EWMA + variance; hosts slower
  than mean + k·σ for ``patience`` consecutive steps are quarantined: at
  synchronous-SGD scale one slow host gates the fleet, so quarantining is
  equivalent to failure (the elastic manager then reshapes without it).
* ``ElasticMeshManager`` — given the set of live hosts, picks the largest
  usable mesh (data axis shrinks to the largest divisor ≤ live hosts; the
  model axis is preserved because TP width is baked into parameter shapes),
  triggering re-lowering + checkpoint restore.  Because the data pipeline is
  stateless-addressed (see data/tokens.py), a reshape never replays or skips
  batches.

The failure drill in tests/test_runtime.py: kill a host → registry notices →
manager proposes the shrunk mesh → train loop re-lowers and resumes from the
last committed checkpoint.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence


class HeartbeatRegistry:
    def __init__(self, hosts: Sequence[int], timeout_s: float = 60.0,
                 clock=time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        now = clock()
        self._last: Dict[int, float] = {h: now for h in hosts}
        self._dead: set[int] = set()

    def beat(self, host: int) -> None:
        if host not in self._dead:
            self._last[host] = self._clock()

    def mark_dead(self, host: int) -> None:
        self._dead.add(host)

    def live_hosts(self) -> List[int]:
        now = self._clock()
        return sorted(h for h, t in self._last.items()
                      if h not in self._dead and now - t <= self.timeout_s)

    def dead_hosts(self) -> List[int]:
        now = self._clock()
        return sorted(h for h, t in self._last.items()
                      if h in self._dead or now - t > self.timeout_s)


class StragglerDetector:
    """EWMA step-time tracker; quarantine = treat as failed."""

    def __init__(self, hosts: Sequence[int], alpha: float = 0.1,
                 k_sigma: float = 3.0, patience: int = 5):
        self.alpha = alpha
        self.k_sigma = k_sigma
        self.patience = patience
        self._mean: Dict[int, float] = {h: 0.0 for h in hosts}
        self._var: Dict[int, float] = {h: 0.0 for h in hosts}
        self._strikes: Dict[int, int] = {h: 0 for h in hosts}
        self._initialized: set[int] = set()

    def observe(self, host: int, step_time_s: float) -> None:
        if host not in self._initialized:
            self._mean[host] = step_time_s
            self._initialized.add(host)
            return
        m = self._mean[host]
        self._mean[host] = (1 - self.alpha) * m + self.alpha * step_time_s
        self._var[host] = (1 - self.alpha) * self._var[host] \
            + self.alpha * (step_time_s - m) ** 2

    def fleet_stats(self) -> tuple[float, float]:
        """Robust (median, MAD·1.4826) — a straggler must not inflate the
        spread that decides whether it is a straggler."""
        means = sorted(self._mean.values())
        n = len(means)
        if n == 0:
            return 0.0, 0.0
        med = means[n // 2] if n % 2 else 0.5 * (means[n // 2 - 1]
                                                 + means[n // 2])
        devs = sorted(abs(x - med) for x in means)
        mad = devs[n // 2] if n % 2 else 0.5 * (devs[n // 2 - 1]
                                                + devs[n // 2])
        return med, 1.4826 * mad

    def check(self) -> List[int]:
        """Returns hosts to quarantine after this round of observations."""
        mu, sd = self.fleet_stats()
        # floor the spread at 20% of the median so benign jitter on a
        # tightly-clustered fleet never quarantines anyone.
        threshold = mu + self.k_sigma * max(sd, 0.2 * mu, 1e-9) + 1e-9
        out = []
        for h, m in self._mean.items():
            if m > threshold:
                self._strikes[h] += 1
                if self._strikes[h] >= self.patience:
                    out.append(h)
            else:
                self._strikes[h] = 0
        return out


@dataclasses.dataclass
class MeshPlan:
    data: int
    model: int
    pods: int
    dropped_hosts: List[int]

    @property
    def n_devices(self) -> int:
        return self.data * self.model * self.pods


class ElasticMeshManager:
    """Chooses the largest runnable mesh given live capacity.

    The model (TP) axis is structural — parameter shards are laid out for a
    fixed TP width — so elasticity happens on the data (and pod) axes:
    shrink `data` to the largest power-of-two (or divisor) that live hosts
    support, round down whole pods first when an entire pod is unreachable.
    """

    def __init__(self, data: int, model: int, pods: int = 1,
                 devices_per_host: int = 4):
        self.data0, self.model, self.pods0 = data, model, pods
        self.devices_per_host = devices_per_host

    def plan(self, live_hosts: Sequence[int],
             total_hosts: Optional[int] = None) -> MeshPlan:
        total = total_hosts or (self.data0 * self.model * self.pods0
                                // self.devices_per_host)
        live = len(live_hosts)
        if live == 0:
            raise RuntimeError("no live hosts")
        hosts_per_pod = max(total // self.pods0, 1)
        # drop unreachable whole pods first
        pods = max(1, min(self.pods0, live // hosts_per_pod))
        live_per_pod = live // pods
        live_devices = live_per_pod * self.devices_per_host
        # data axis: largest divisor of the original data width that fits
        data = self.data0
        while data > 1 and data * self.model > live_devices * 1:
            data //= 2
        dropped = sorted(set(range(total)) - set(live_hosts))
        return MeshPlan(data=data, model=self.model, pods=pods,
                        dropped_hosts=dropped)
