# Tier-1 verification and common dev entry points.
#
# `make test` is the exact command the ROADMAP's tier-1 gate runs; keep them
# in sync.  The suite must collect and pass on a bare runtime image (no
# requirements-dev.txt extras) — tests/_hypothesis_compat.py guarantees the
# property tests degrade rather than break collection.
#
# `make check` = lint + tests + the checkify-sanitized rerun
# (`make test-sanitize`), the full local gate.  `make lint` runs both
# halves of the static gate: ruff (style, skipped when not installed) and
# the stdlib-only invariant linter (`python -m repro.analysis.lint`, rules
# LF001–LF005 — see README "Static analysis & sanitizers"), which always
# runs and always gates.

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)
export PYTHONPATH

.PHONY: check test test-fast test-sanitize lint lint-invariants bench \
	bench-engine bench-build bench-dist bench-serve bench-serve-quick \
	bench-filters bench-obs bench-obs-quick dev-deps

check: test test-sanitize

test: lint
	python -m pytest -x -q

test-fast:
	python -m pytest -x -q -m "not slow"

# tier-1 under the checkify sanitizer: every sanitize.call-wrapped engine
# entry point runs with NaN/OOB/div checks compiled in (src/repro/sanitize).
test-sanitize:
	REPRO_CHECKIFY=1 python -m pytest -x -q

# ruff is a dev extra (requirements-dev.txt); the bare runtime image must
# still pass `make test`, so a missing ruff degrades to a notice, not a
# failure.  Config: ruff.toml.  The invariant linter is stdlib-only and
# never skips.
lint: lint-invariants
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src benchmarks tests examples; \
	else \
		echo "lint: ruff not installed (make dev-deps); skipping"; \
	fi

lint-invariants:
	python -m repro.analysis.lint src

bench:
	python -m benchmarks.run --quick

bench-engine:
	python -m benchmarks.run --suite engine

bench-build:
	python -m benchmarks.run --suite build

bench-dist:
	python -m benchmarks.run --suite dist

bench-serve:
	python -m benchmarks.run --suite serve

# CI-sized pipeline-sweep smoke (writes experiments/serve_bench_quick.json)
bench-serve-quick:
	python -m benchmarks.serve_bench --quick

bench-filters:
	python -m benchmarks.run --suite filters

bench-obs:
	python -m benchmarks.run --suite obs

# CI-sized overhead + shadow-sweep smoke (writes
# experiments/obs_bench_quick.json)
bench-obs-quick:
	python -m benchmarks.obs_bench --quick

dev-deps:
	pip install -r requirements-dev.txt
