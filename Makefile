# Tier-1 verification and common dev entry points.
#
# `make test` is the exact command the ROADMAP's tier-1 gate runs; keep them
# in sync.  The suite must collect and pass on a bare runtime image (no
# requirements-dev.txt extras) — tests/_hypothesis_compat.py guarantees the
# property tests degrade rather than break collection.

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)
export PYTHONPATH

.PHONY: test test-fast lint bench bench-engine bench-build bench-dist \
	bench-serve bench-serve-quick bench-filters dev-deps

test: lint
	python -m pytest -x -q

test-fast:
	python -m pytest -x -q -m "not slow"

# ruff is a dev extra (requirements-dev.txt); the bare runtime image must
# still pass `make test`, so a missing ruff degrades to a notice, not a
# failure.  Config: ruff.toml.
lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src benchmarks tests examples; \
	else \
		echo "lint: ruff not installed (make dev-deps); skipping"; \
	fi

bench:
	python -m benchmarks.run --quick

bench-engine:
	python -m benchmarks.run --suite engine

bench-build:
	python -m benchmarks.run --suite build

bench-dist:
	python -m benchmarks.run --suite dist

bench-serve:
	python -m benchmarks.run --suite serve

# CI-sized pipeline-sweep smoke (writes experiments/serve_bench_quick.json)
bench-serve-quick:
	python -m benchmarks.serve_bench --quick

bench-filters:
	python -m benchmarks.run --suite filters

dev-deps:
	pip install -r requirements-dev.txt
