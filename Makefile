# Tier-1 verification and common dev entry points.
#
# `make test` is the exact command the ROADMAP's tier-1 gate runs; keep them
# in sync.  The suite must collect and pass on a bare runtime image (no
# requirements-dev.txt extras) — tests/_hypothesis_compat.py guarantees the
# property tests degrade rather than break collection.

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)
export PYTHONPATH

.PHONY: test test-fast bench bench-engine dev-deps

test:
	python -m pytest -x -q

test-fast:
	python -m pytest -x -q -m "not slow"

bench:
	python -m benchmarks.run --quick

bench-engine:
	python -m benchmarks.engine_bench --out experiments/engine_bench.json

dev-deps:
	pip install -r requirements-dev.txt
