"""Build-phase wall-clock: batched leaf-slab collection vs the seed path.

The paper reports training-data generation as the largest build overhead
(Alg. 1 steps 2–3), and the seed reproduced it with per-leaf Python loops:
one RNG + ``dynamic_slice`` + masked-min dispatch per filter.  The engine's
leaf-slab layer replaces those with single jitted chunked sweeps
(``engine.nn_distance_all_leaves`` / ``nn_distance_own_leaf`` plus one
vmapped RNG pass).  This benchmark builds a ≥64-filter index, runs both
collection paths end to end, verifies they agree (the local-query samples
bitwise, the distance targets to float tolerance), and records the
speedup — per phase and total.

    PYTHONPATH=src python -m benchmarks.build_bench \
        --out experiments/build_bench.json
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import filter_training, tree

from . import common


def _timed(fn, repeat: int):
    out, dt = common.timed(fn, repeat=repeat)
    return out, dt * 1e3


def bench_build(n: int = 30_000, m: int = 128, leaf_capacity: int = 192,
                n_global: int = 400, n_local: int = 100,
                repeat: int = 3) -> Tuple[List[str], Dict]:
    rng = np.random.default_rng(1)
    S = rng.standard_normal((n, m), dtype=np.float32).cumsum(axis=1)
    index = tree.build_dstree(S, leaf_capacity=leaf_capacity)
    sizes = np.asarray(index.leaf_size)
    leaf_ids = np.arange(index.n_leaves)[sizes >= leaf_capacity // 4]
    assert len(leaf_ids) >= 64, f"want ≥64 filters, got {len(leaf_ids)}"
    key = jax.random.PRNGKey(0)
    kg, kl = jax.random.split(key)
    gq = filter_training.make_noisy_queries(S, n_global, kg)
    gq_j = jnp.asarray(gq)

    payload: Dict = {"n": n, "m": m, "L": index.n_leaves,
                     "n_filters": int(len(leaf_ids)),
                     "n_global": n_global, "n_local": n_local,
                     "phases": {}}

    # -- phase: local query generation (vmapped RNG vs per-leaf loop) -------
    lq_new, t_new = _timed(lambda: jnp.asarray(filter_training.make_local_queries(
        index, leaf_ids, n_local, kl)), repeat)
    lq_ref, t_ref = _timed(lambda: jnp.asarray(
        filter_training._reference_local_queries(
            index, leaf_ids, n_local, kl)), repeat)
    assert np.array_equal(np.asarray(lq_new), np.asarray(lq_ref))
    payload["phases"]["local_queries"] = {
        "batched_ms": t_new, "reference_ms": t_ref,
        "speedup": t_ref / max(t_new, 1e-12), "parity": "bitwise"}
    lq = np.asarray(lq_new)

    # -- phase: local NN targets (slab sweep vs per-leaf dynamic_slice) -----
    ld_new, t_new = _timed(lambda: jnp.asarray(
        filter_training.local_nn_distances(index, lq, leaf_ids)), repeat)
    ld_ref, t_ref = _timed(lambda: jnp.asarray(
        filter_training._reference_local_nn_distances(
            index, lq, leaf_ids)), repeat)
    err = float(np.abs(np.asarray(ld_new) - np.asarray(ld_ref)).max())
    payload["phases"]["local_nn"] = {
        "batched_ms": t_new, "reference_ms": t_ref,
        "speedup": t_ref / max(t_new, 1e-12), "max_abs_diff": err}

    # -- phase: node-wise NN targets (slab sweep vs blocked segment-min) ----
    dL_new, t_new = _timed(lambda: filter_training.nodewise_nn_distances(
        index, gq_j), repeat)
    dL_ref, t_ref = _timed(lambda: filter_training._reference_nodewise_nn_distances(
        index, gq_j), repeat)
    err = float(np.abs(np.asarray(dL_new) - np.asarray(dL_ref)).max())
    payload["phases"]["nodewise_nn"] = {
        "batched_ms": t_new, "reference_ms": t_ref,
        "speedup": t_ref / max(t_new, 1e-12), "max_abs_diff": err}

    # -- end-to-end collection (Alg. 1 steps 2-3) ---------------------------
    def run_batched():
        d = filter_training.collect_training_data(
            index, leaf_ids, n_global, n_local, key)
        return jnp.asarray(d.local_d_L)

    def run_reference():
        d = filter_training._reference_collect_training_data(
            index, leaf_ids, n_global, n_local, key)
        return jnp.asarray(d.local_d_L)

    _, t_new = _timed(run_batched, repeat)
    _, t_ref = _timed(run_reference, repeat)
    payload["collect_batched_ms"] = t_new
    payload["collect_reference_ms"] = t_ref
    payload["collect_speedup"] = t_ref / max(t_new, 1e-12)

    rows = [common.csv_line(
        f"build_collect/{name}", rec["batched_ms"] * 1e3,
        f"batched={rec['batched_ms']:.1f}ms;"
        f"reference={rec['reference_ms']:.1f}ms;"
        f"speedup={rec['speedup']:.2f}x")
        for name, rec in payload["phases"].items()]
    rows.append(common.csv_line(
        "build_collect/total", payload["collect_batched_ms"] * 1e3,
        f"batched={payload['collect_batched_ms']:.1f}ms;"
        f"reference={payload['collect_reference_ms']:.1f}ms;"
        f"speedup={payload['collect_speedup']:.2f}x;"
        f"filters={payload['n_filters']}"))
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/build_bench.json")
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--repeat", type=int, default=3)
    args = ap.parse_args()
    rows, payload = bench_build(n=args.n, repeat=args.repeat)
    common.write_suite_payload(rows, payload, args.out)


if __name__ == "__main__":
    main()
