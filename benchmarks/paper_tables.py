"""One function per paper table/figure (DESIGN.md §6 index).

Every function returns a list of CSV rows ``name,us_per_call,derived`` and a
dict payload that EXPERIMENTS.md consumes.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, filters
from . import common


# ---------------------------------------------------------------------------
# Fig. 1b / Fig. 8(k–o): pruning ratio without/with LeaFi (+ optimal)
# ---------------------------------------------------------------------------


def bench_pruning_ratio(setup: common.BenchSetup) -> Tuple[List[str], Dict]:
    rows, payload = [], {}
    for noise in common.NOISE_LEVELS:
        d_lb, d_L = setup.d_lb[noise], setup.d_L[noise]
        t0 = time.perf_counter()
        exact = baselines.exact_search(d_lb, d_L)
        leafi = baselines.leafi_search(d_lb, d_L,
                                       common.leafi_adjusted(setup, noise))
        optimal = baselines.leafi_search(d_lb, d_L, d_F=d_L)
        dt = (time.perf_counter() - t0) / 3
        pr = {
            "exact": exact.pruning_ratio.mean(),
            "leafi": leafi.pruning_ratio.mean(),
            "optimal": optimal.pruning_ratio.mean(),
        }
        payload[noise] = pr
        rows.append(common.csv_line(
            f"pruning_ratio/{setup.name}/noise{int(noise*100)}",
            dt * 1e6,
            f"exact={pr['exact']:.3f};leafi={pr['leafi']:.3f};"
            f"optimal={pr['optimal']:.3f}"))
    return rows, payload


# ---------------------------------------------------------------------------
# Fig. 7 / Fig. 8(a–j): search cost + recall @ 99% target, all baselines
# ---------------------------------------------------------------------------


def bench_query_time(setup: common.BenchSetup,
                     target: float = 0.99) -> Tuple[List[str], Dict]:
    rows, payload = [], {}
    # tune comparison approaches on the validation split (paper §5.1)
    eps = baselines.tune_epsilon(setup.val_d_lb, setup.val_d_L, target)
    de_thr = baselines.tune_delta(setup.val_d_lb, setup.val_d_L, target)
    pros = baselines.train_pros(setup.val_d_lb, setup.val_d_L)
    lt = baselines.train_lt(setup.val_d_lb, setup.val_d_L, target)

    for noise in common.NOISE_LEVELS:
        d_lb, d_L = setup.d_lb[noise], setup.d_L[noise]
        variants = {
            "exact": lambda: baselines.exact_search(d_lb, d_L),
            "leafi": lambda: baselines.leafi_search(
                d_lb, d_L, common.leafi_adjusted(setup, noise, target)),
            "eps": lambda: baselines.epsilon_search(d_lb, d_L, eps),
            "deps": lambda: baselines.delta_epsilon_search(d_lb, d_L, de_thr),
            "pros": lambda: baselines.pros_search(d_lb, d_L, pros),
            "lt": lambda: baselines.lt_search(d_lb, d_L, lt),
            "lr": lambda: baselines.lr_optimal_search(d_lb, d_L),
        }
        res = {}
        for name, fn in variants.items():
            t0 = time.perf_counter()
            r = fn()
            res[name] = {"recall": float(r.recall.mean()),
                         "searched": float(r.searched.mean()),
                         "sim_s": time.perf_counter() - t0}
        payload[noise] = res
        speedup = res["exact"]["searched"] / max(res["leafi"]["searched"], 1e-9)
        rows.append(common.csv_line(
            f"query_time/{setup.name}/noise{int(noise*100)}",
            res["leafi"]["sim_s"] * 1e6,
            f"leafi_recall={res['leafi']['recall']:.3f};"
            f"speedup_vs_exact={speedup:.2f}x"))
    return rows, payload


# ---------------------------------------------------------------------------
# Fig. 9: target vs achieved recall
# ---------------------------------------------------------------------------


def bench_recall_targets(setup: common.BenchSetup,
                         targets=(0.95, 0.97, 0.99, 0.995, 0.999)
                         ) -> Tuple[List[str], Dict]:
    rows, payload = [], {}
    for target in targets:
        recs, searched = [], []
        for noise in common.NOISE_LEVELS:
            r = baselines.leafi_search(
                setup.d_lb[noise], setup.d_L[noise],
                common.leafi_adjusted(setup, noise, target))
            recs.append(float(r.recall.mean()))
            searched.append(float(r.searched.mean()))
        payload[target] = {"recall": float(np.mean(recs)),
                           "searched": float(np.mean(searched))}
        rows.append(common.csv_line(
            f"recall_targets/{setup.name}/t{target}", 0.0,
            f"achieved={np.mean(recs):.4f};searched={np.mean(searched):.1f}"))
    return rows, payload


# ---------------------------------------------------------------------------
# Fig. 10: dataset size scaling
# ---------------------------------------------------------------------------


def bench_scalability(dataset: str = "randwalk",
                      sizes=(10_000, 25_000, 50_000, 100_000)
                      ) -> Tuple[List[str], Dict]:
    rows, payload = [], {}
    for n in sizes:
        setup = common.get_setup(dataset, "dstree", n=n)
        noise = 0.2
        exact = baselines.exact_search(setup.d_lb[noise], setup.d_L[noise])
        leafi = baselines.leafi_search(
            setup.d_lb[noise], setup.d_L[noise],
            common.leafi_adjusted(setup, noise))
        speedup = exact.searched.mean() / max(leafi.searched.mean(), 1e-9)
        payload[n] = {"speedup": float(speedup),
                      "recall": float(leafi.recall.mean()),
                      "n_leaves": setup.lfi.index.n_leaves}
        rows.append(common.csv_line(
            f"scalability/{dataset}/n{n}", 0.0,
            f"speedup={speedup:.2f}x;recall={leafi.recall.mean():.3f}"))
    return rows, payload


# ---------------------------------------------------------------------------
# Fig. 11a–c: node-size threshold sweep   /   Fig. 11d: memory budget sweep
# ---------------------------------------------------------------------------


def bench_node_threshold(dataset: str = "deep",
                         ratios=(5.0, 25.0, 100.0, 300.0)
                         ) -> Tuple[List[str], Dict]:
    rows, payload = [], {}
    for tf_ts in ratios:
        cfg = common.default_config("dstree", t_filter_over_t_series=tf_ts)
        setup = common.get_setup(dataset, "dstree", config=cfg)
        noise = 0.4
        leafi = baselines.leafi_search(
            setup.d_lb[noise], setup.d_L[noise],
            common.leafi_adjusted(setup, noise))
        payload[tf_ts] = {
            "th": 2 * tf_ts,
            "n_filters": int(setup.lfi.build_report["n_filters"]),
            "searched": float(leafi.searched.mean()),
            "pruning": float(leafi.pruning_ratio.mean()),
            "recall": float(leafi.recall.mean()),
        }
        rows.append(common.csv_line(
            f"node_threshold/{dataset}/th{int(2*tf_ts)}", 0.0,
            f"filters={payload[tf_ts]['n_filters']};"
            f"pruning={payload[tf_ts]['pruning']:.3f}"))
    return rows, payload


def bench_memory_budget(dataset: str = "deep",
                        budgets_mb=(0.5, 2, 8, 32, 128)
                        ) -> Tuple[List[str], Dict]:
    rows, payload = [], {}
    for mb in budgets_mb:
        cfg = common.default_config(
            "dstree", filter_memory_budget_bytes=int(mb * 2**20))
        setup = common.get_setup(dataset, "dstree", config=cfg)
        noise = 0.4
        leafi = baselines.leafi_search(
            setup.d_lb[noise], setup.d_L[noise],
            common.leafi_adjusted(setup, noise))
        payload[mb] = {
            "n_filters": int(setup.lfi.build_report["n_filters"]),
            "searched": float(leafi.searched.mean()),
            "recall": float(leafi.recall.mean()),
        }
        rows.append(common.csv_line(
            f"memory_budget/{dataset}/mb{mb}", 0.0,
            f"filters={payload[mb]['n_filters']};"
            f"searched={payload[mb]['searched']:.1f}"))
    return rows, payload


# ---------------------------------------------------------------------------
# Table 1 + Fig. 12: filter model type (MLP / CNN / RNN)
# ---------------------------------------------------------------------------


def bench_model_type(length: int = 96) -> Tuple[List[str], Dict]:
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((64, length)), jnp.float32)
    series_block = jnp.asarray(rng.standard_normal((4096, length)),
                               jnp.float32)
    rows, payload = [], {}

    # distance-calculation time per series (the t_S denominator)
    from repro.kernels.l2_scan import ops as l2_ops
    _, t_scan = common.timed(
        lambda: l2_ops.pairwise_l2(q, series_block).block_until_ready(),
        repeat=5)
    t_series = t_scan / (64 * 4096)

    key = jax.random.PRNGKey(0)
    variants = {
        "mlp": (filters.init_mlp(key, 64, length),
                lambda p: filters.apply_mlp(p, q)),
        "cnn": (filters.init_cnn(key, 64, length),
                lambda p: filters.apply_cnn(p, q)),
        "rnn": (filters.init_rnn(key, 64, length),
                lambda p: filters.apply_rnn(p, q)),
    }
    for name, (params, fn) in variants.items():
        jitted = jax.jit(fn)
        _, t = common.timed(lambda: jitted(params).block_until_ready(),
                            repeat=3)
        t_filter = t / (64 * 64)        # per (filter × query) inference
        th = 2 * t_filter / t_series
        payload[name] = {"t_filter_us": t_filter * 1e6, "th": th}
        rows.append(common.csv_line(
            f"model_type/{name}", t_filter * 1e6, f"th={th:.0f}"))
    payload["t_series_us"] = t_series * 1e6
    return rows, payload


# ---------------------------------------------------------------------------
# Table 2: ± local training data
# ---------------------------------------------------------------------------


def bench_local_data(dataset: str = "randwalk") -> Tuple[List[str], Dict]:
    rows, payload = [], {}
    for tag, n_local in (("with_local", 150), ("no_local", 1)):
        cfg = common.default_config("dstree", n_local=n_local,
                                    n_global=450 if n_local > 1 else 600)
        setup = common.get_setup(dataset, "dstree", config=cfg)
        recs, searched = [], []
        for noise in common.NOISE_LEVELS:
            r = baselines.leafi_search(
                setup.d_lb[noise], setup.d_L[noise],
                common.leafi_adjusted(setup, noise))
            recs.append(float(r.recall.mean()))
            searched.append(float(r.searched.mean()))
        payload[tag] = {"recall": float(np.mean(recs)),
                        "searched": float(np.mean(searched))}
        rows.append(common.csv_line(
            f"local_data/{dataset}/{tag}", 0.0,
            f"recall={np.mean(recs):.3f};searched={np.mean(searched):.1f}"))
    return rows, payload


# ---------------------------------------------------------------------------
# Table 3/4: build-time breakdown + space overhead
# ---------------------------------------------------------------------------


def bench_build_time(setup: common.BenchSetup) -> Tuple[List[str], Dict]:
    r = setup.lfi.build_report
    m = setup.lfi.index.length
    h = setup.lfi.config.hidden or m
    f_bytes = filters.mlp_param_bytes(m, h) * len(setup.lfi.leaf_ids)
    data_bytes = setup.series.nbytes
    idx_bytes = (setup.lfi.index.series.nbytes
                 - data_bytes + setup.lfi.index.leaf_start.nbytes
                 + setup.lfi.index.leaf_size.nbytes
                 + sum(v.nbytes for v in setup.lfi.index.payload.values()))
    payload = {
        "t_index_build_s": r["t_index_build"],
        "t_collect_s": r["t_collect"],
        "t_train_s": r["t_train"],
        "t_calibrate_s": r["t_calibrate"],
        "bytes_data": data_bytes,
        "bytes_index_structure": idx_bytes,
        "bytes_filters": f_bytes,
        "filter_overhead_pct": 100.0 * f_bytes / data_bytes,
    }
    rows = [common.csv_line(
        f"build_time/{setup.name}", r["t_train"] * 1e6,
        f"collect={r['t_collect']:.1f}s;train={r['t_train']:.1f}s;"
        f"filter_space={100.0 * f_bytes / data_bytes:.1f}%")]
    return rows, payload
