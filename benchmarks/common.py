"""Shared benchmark harness: datasets, index builds, matrix collection.

Benchmark scale is CPU-sized (25k series vs the paper's 25M) — the paper's
own hardware-agnostic surrogate (searched-leaf count, Fig. 1a footnote) is
the primary metric, so relative behaviours are comparable even though
absolute times are not.  Heavy artifacts (built indexes, (d_lb, d_L)
matrices) are cached under experiments/bench_cache/.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build, filter_training, search
from repro.data.series import SERIES_GENERATORS, DEFAULT_LENGTHS, make_query_set

CACHE_DIR = os.environ.get("BENCH_CACHE", "experiments/bench_cache")
DATASETS = ("randwalk", "seismic", "astro", "deep", "sift")
N_SERIES = int(os.environ.get("BENCH_N", 25_000))
N_QUERIES = int(os.environ.get("BENCH_Q", 100))
NOISE_LEVELS = (0.1, 0.2, 0.3, 0.4)


@dataclasses.dataclass
class BenchSetup:
    name: str
    backbone: str
    series: np.ndarray
    lfi: build.LeaFiIndex
    queries: Dict[float, np.ndarray]            # noise → (Q, m)
    d_lb: Dict[float, np.ndarray]               # noise → (Q, L)
    d_L: Dict[float, np.ndarray]
    d_pred: Dict[float, np.ndarray]             # conformal-raw predictions
    val_d_lb: np.ndarray                        # validation split matrices
    val_d_L: np.ndarray


def default_config(backbone: str = "dstree", **kw) -> build.LeaFiConfig:
    base = dict(
        backbone=backbone, leaf_capacity=192,
        n_global=450, n_local=150,                    # n_q = 600, 3:1 split
        t_filter_over_t_series=25.0,
        train=filter_training.TrainConfig(epochs=120, batch=96),
    )
    base.update(kw)
    return build.LeaFiConfig(**base)


def _cache_path(key: str) -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    return os.path.join(CACHE_DIR, key + ".pkl")


def _config_tag(config: Optional[build.LeaFiConfig]) -> str:
    if config is None:
        return ""
    import hashlib
    return "_" + hashlib.md5(repr(config).encode()).hexdigest()[:10]


def get_setup(dataset: str, backbone: str = "dstree",
              n: int = N_SERIES, force: bool = False,
              config: Optional[build.LeaFiConfig] = None) -> BenchSetup:
    key = f"{dataset}_{backbone}_{n}{_config_tag(config)}"
    path = _cache_path(key)
    if not force and os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)

    m = DEFAULT_LENGTHS[dataset]
    S = SERIES_GENERATORS[dataset](n, m, seed=1)
    cfg = config or default_config(backbone)
    lfi = build.build_leafi(S, cfg, key=jax.random.PRNGKey(0))

    queries, d_lb, d_L, d_pred = {}, {}, {}, {}
    for noise in NOISE_LEVELS:
        q = make_query_set(S, N_QUERIES, noise, seed=int(noise * 100))
        queries[noise] = q
        d_L[noise] = np.asarray(
            filter_training.nodewise_nn_distances(lfi.index, jnp.asarray(q)))
        from repro.core import bounds
        d_lb[noise] = np.asarray(bounds.lower_bounds(lfi.index,
                                                     jnp.asarray(q)))
        if lfi.filter_params is not None:
            d_pred[noise] = np.asarray(search.predictions_for_all_leaves(
                lfi.index, lfi.filter_params, lfi.leaf_ids,
                jnp.asarray(q), offsets=None))
        else:
            d_pred[noise] = np.full_like(d_lb[noise], -np.inf)

    # validation matrices (for tuning the comparison methods, paper §5.1)
    vq = make_query_set(S, 120, 0.25, seed=999)
    val_d_L = np.asarray(
        filter_training.nodewise_nn_distances(lfi.index, jnp.asarray(vq)))
    from repro.core import bounds
    val_d_lb = np.asarray(bounds.lower_bounds(lfi.index, jnp.asarray(vq)))

    setup = BenchSetup(dataset, backbone, S, lfi, queries, d_lb, d_L, d_pred,
                       val_d_lb, val_d_L)
    with open(path, "wb") as f:
        pickle.dump(setup, f)
    return setup


def leafi_adjusted(setup: BenchSetup, noise: float,
                   target: float = 0.99) -> np.ndarray:
    """Conformal-adjusted filter lower bounds d_F for a quality target.

    Zero-filter indexes (threshold above every leaf) degrade to exact
    search: d_F = −inf never prunes."""
    from repro.core import conformal
    if setup.lfi.tuner is None or len(setup.lfi.leaf_ids) == 0:
        return np.full_like(setup.d_lb[noise], -np.inf)
    offs = conformal.scatter_offsets(
        setup.lfi.tuner, setup.lfi.leaf_ids, setup.lfi.index.n_leaves, target)
    return setup.d_pred[noise] - offs[None, :]


def latency_percentiles(samples, pcts=(50, 95, 99)) -> Dict[str, float]:
    """{'p50': …, 'p95': …, 'p99': …} from a latency sample iterable.

    Shared with the serving runtime's rolling telemetry — one definition
    (``repro.serving.telemetry.latency_percentiles``) so benchmark reports
    and live counters can never disagree on what a percentile means."""
    from repro.serving.telemetry import latency_percentiles as _lp
    return _lp(samples, pcts)


def timed(fn, *args, repeat: int = 3, **kw):
    # block the warmup too: async dispatch must not bleed into the window
    jax.block_until_ready(fn(*args, **kw))              # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / repeat


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def write_suite_payload(rows: List[str], payload: Dict, out: str) -> None:
    """Shared suite emitter: print the CSV rows, dump the JSON payload."""
    for r in rows:
        print(r)
    d = os.path.dirname(out)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(out, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    print(f"# → {out}")
