"""Filter-inference kernel suite: per-filter sweep vs fused megakernel.

Sweeps filter count × weight dtype × implementation on one (Q, m, h) shape
and pins the measurement against the analytic three-term roofline bound
(:func:`repro.analysis.roofline.filter_mlp_roofline`).

Off-TPU the kernels run in Pallas interpret mode, where wall-clock is
dominated by per-grid-step Python dispatch — absolute numbers are
meaningless, but the *step-count* structure is exactly the TPU launch
structure: the per-filter kernel runs F·Q/bq steps, the fused kernel
F/bf·Q/bq, so the bf× interpret-mode gap at large F is the same gap the
grid does on hardware.  The roofline block carries the bandwidth-bound
projection (the number that matters on a v5e); both are reported side by
side in the payload.

    PYTHONPATH=src python -m benchmarks.run --suite filters
    make bench-filters
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.analysis import roofline
from repro.core import filters
from repro.kernels.common import use_interpret
from repro.kernels.filter_mlp import ops as mlp_ops
from repro.kernels.filter_mlp import ref as mlp_ref

from . import common

F_VALUES = (64, 256, 1024, 4096)
DTYPES = ("float32", "bfloat16", "int8")
BQ, BF = 128, 8


def _make_stack(F: int, m: int, h: int, rng) -> Dict[str, jnp.ndarray]:
    p = {
        "w1": jnp.asarray(rng.standard_normal((F, m, h)) * 0.2, jnp.float32),
        "b1": jnp.asarray(rng.standard_normal((F, h)) * 0.1, jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((F, h)) * 0.2, jnp.float32),
        "b2": jnp.asarray(rng.standard_normal((F,)), jnp.float32),
        "y_mean": jnp.asarray(rng.standard_normal((F,)), jnp.float32),
        "y_std": jnp.asarray(
            np.abs(rng.standard_normal((F,))) + 0.5, jnp.float32),
    }
    return p


def _per_filter_call(p, queries, off, interpret):
    """The pre-fusion composition: per-filter kernel + 3 broadcast passes."""
    z = mlp_ops.filter_predict(p["w1"], p["b1"], p["w2"], p["b2"], queries,
                               interpret=interpret)
    return z * p["y_std"][:, None] + p["y_mean"][:, None] - off[:, None]


def _fused_call(p, queries, off, interpret):
    return mlp_ops.filter_predict_fused(
        p["w1"], p["b1"], p["w2"], p["b2"], p["y_mean"], p["y_std"],
        queries, off, p.get("w1_scale"), p.get("w2_scale"),
        bq=BQ, bf=BF, interpret=interpret)


def bench_filters(f_values=F_VALUES, q: int = 128, m: int = 128,
                  h: int = 128) -> Tuple[List[str], Dict]:
    interpret = True if use_interpret() else False
    rng = np.random.default_rng(0)
    queries = jnp.asarray(rng.standard_normal((q, m)), jnp.float32)
    rows: List[str] = []
    results: List[Dict] = []

    # parity spot-check at the smallest size: every timed path against the
    # dequantized oracle (the fast paths must be *right* before being fast)
    F0 = int(f_values[0])
    p0 = _make_stack(F0, m, h, rng)
    off0 = jnp.asarray(np.abs(rng.standard_normal((F0,))), jnp.float32)
    parity = {}
    for dt in DTYPES:
        pq = filters.quantize_mlp(p0, dt)
        want = mlp_ref.filter_predict_destd(
            pq["w1"], pq["b1"], pq["w2"], pq["b2"], pq["y_mean"],
            pq["y_std"], queries, off0, pq.get("w1_scale"),
            pq.get("w2_scale"))
        got = _fused_call(pq, queries, off0, interpret)
        parity[f"fused_{dt}"] = float(jnp.max(jnp.abs(got - want)))
    parity["per_filter_float32"] = float(jnp.max(jnp.abs(
        _per_filter_call(p0, queries, off0, interpret)
        - mlp_ref.filter_predict_destd(
            p0["w1"], p0["b1"], p0["w2"], p0["b2"], p0["y_mean"],
            p0["y_std"], queries, off0))))

    for F in f_values:
        F = int(F)
        p = _make_stack(F, m, h, rng)
        off = jnp.asarray(np.abs(rng.standard_normal((F,))), jnp.float32)
        tiles = -(-q // BQ)
        cases = [("per_filter", "float32", p, F * tiles,
                  lambda p=p: _per_filter_call(p, queries, off, interpret))]
        for dt in DTYPES:
            pq = filters.quantize_mlp(p, dt)
            cases.append(
                ("fused", dt, pq, (-(-F // BF)) * tiles,
                 lambda pq=pq: _fused_call(pq, queries, off, interpret)))
        for impl, dt, _, steps, fn in cases:
            _, sec = common.timed(fn, repeat=1)
            rl = roofline.filter_mlp_roofline(
                F, q, m, h, variant=("fused" if impl == "fused"
                                     else "per_filter"),
                weight_dtype=dt, bq=BQ, bf=BF)
            rows.append(common.csv_line(
                f"filters/{impl}/{dt}/F{F}", sec * 1e6,
                f"steps={steps} bound_us={rl.bound_time * 1e6:.1f}"))
            results.append({
                "F": F, "Q": q, "m": m, "h": h, "impl": impl,
                "weight_dtype": dt, "interpret": interpret,
                "grid_steps": steps, "us_per_call": sec * 1e6,
                "roofline": rl.as_dict(),
            })

    # fused-vs-per-filter summary at each F (measured + bandwidth bound)
    summary = {}
    for F in f_values:
        F = int(F)
        pf = next(r for r in results
                  if r["F"] == F and r["impl"] == "per_filter")
        fu = next(r for r in results if r["F"] == F and r["impl"] == "fused"
                  and r["weight_dtype"] == "float32")
        summary[str(F)] = {
            "measured_speedup": pf["us_per_call"] / fu["us_per_call"],
            "bound_speedup": (pf["roofline"]["bound_time"]
                              / fu["roofline"]["bound_time"]),
        }
    payload = {
        "config": {"f_values": [int(F) for F in f_values], "Q": q, "m": m,
                   "h": h, "bq": BQ, "bf": BF, "interpret": interpret,
                   "hw": roofline.V5E.name},
        "parity_max_abs_err": parity,
        "results": results,
        "fused_speedup_f32": summary,
    }
    return rows, payload


if __name__ == "__main__":
    r, pl = bench_filters()
    common.write_suite_payload(r, pl, "experiments/filters_bench.json")
