"""Real wall-clock search latency (not the surrogate): early-termination
LeaFi vs exact on this host's CPU.

``search_early`` runs the paper's sequential semantics with genuine
leaf-scan skips (lax.while_loop + cond), so its timing reflects the pruning
ratio directly.  The batched path gets its wall-clock pruning wins from the
compact engine strategy instead — that comparison lives in
benchmarks/engine_bench.py.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple


from repro.core import search
from . import common


def paper_regime_setup(dataset: str = "randwalk") -> common.BenchSetup:
    """Leaf sizes near the paper's regime (split threshold 10k; ours 2k at
    25k series) so t_S·|N| ≫ t_F — the condition Eq. 4 requires for filters
    to pay in wall-clock, not just in searched-leaf count."""
    cfg = common.default_config("dstree", leaf_capacity=2048)
    return common.get_setup(dataset, "dstree", config=cfg)


def bench_wallclock(setup: common.BenchSetup, n_queries: int = 12,
                    target: float = 0.99) -> Tuple[List[str], Dict]:
    noise = 0.4
    qs = setup.queries[noise][:n_queries]
    lfi = setup.lfi

    def run(use_filters: bool):
        # warmup/compile on the first query
        kw = dict(filter_params=lfi.filter_params, leaf_ids=lfi.leaf_ids,
                  tuner=lfi.tuner,
                  quality_target=target if use_filters else None,
                  use_filters=use_filters)
        search.search_early(lfi.index, qs[0], **kw)
        t0 = time.perf_counter()
        searched = 0
        for q in qs:
            r = search.search_early(lfi.index, q, **kw)
            searched += int(r.searched[0])
        return (time.perf_counter() - t0) / len(qs), searched / len(qs)

    t_exact, s_exact = run(use_filters=False)
    t_leafi, s_leafi = run(use_filters=True)
    payload = {
        "exact_ms": t_exact * 1e3, "leafi_ms": t_leafi * 1e3,
        "exact_searched": s_exact, "leafi_searched": s_leafi,
        "wall_speedup": t_exact / max(t_leafi, 1e-12),
    }
    rows = [common.csv_line(
        f"wallclock/{setup.name}/{setup.backbone}", t_leafi * 1e6,
        f"exact={t_exact*1e3:.1f}ms;leafi={t_leafi*1e3:.1f}ms;"
        f"speedup={payload['wall_speedup']:.2f}x")]
    return rows, payload
