"""Scan-vs-compact engine wall-clock across pruning ratios.

The compact engine's claim is that search compute — not just the reported
searched-leaf count — shrinks with the pruning ratio.  This benchmark pins
that: one index, one query batch, and a sweep of filter aggressiveness
levels; at each level both engine strategies answer the same cascade (they
are bitwise-identical, see tests/test_engine.py) and we record wall-clock,
searched leaves, and the leaves the compact engine actually paid distance
compute for.

Pruning is controlled with synthetic rank-threshold filter predictions
(prune every leaf beyond the r best by lower bound) rather than trained
filters, so the sweep hits precise, reproducible ratios — the engine only
ever sees a (Q, L) prediction matrix either way.

    PYTHONPATH=src python -m benchmarks.engine_bench \
        --out experiments/engine_bench.json
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds, engine, tree
from repro.data.series import make_query_set

from . import common


def _rank_threshold_predictions(d_lb: np.ndarray, keep: int) -> np.ndarray:
    """d_F that filter-prunes every leaf beyond the ``keep`` best-lb ones."""
    ranks = np.argsort(np.argsort(d_lb, axis=1), axis=1)
    return np.where(ranks < keep, -np.inf, np.inf).astype(np.float32)


def bench_engine(n: int = 50_000, m: int = 128, leaf_capacity: int = 128,
                 n_queries: int = 32, k: int = 5,
                 repeat: int = 3) -> Tuple[List[str], Dict]:
    rng = np.random.default_rng(1)
    S = rng.standard_normal((n, m), dtype=np.float32).cumsum(axis=1)
    index = tree.build_dstree(S, leaf_capacity=leaf_capacity)
    L = index.n_leaves
    queries = make_query_set(S, n_queries, noise=0.3, seed=7)
    q = jnp.asarray(queries)
    d_lb = bounds.lower_bounds(index, q)
    lb_np = np.asarray(d_lb)
    series = jnp.asarray(index.series)
    starts = jnp.asarray(index.leaf_start)
    sizes = jnp.asarray(index.leaf_size)

    def run(strategy, d_F, dist_impl=None):
        res = engine.run_cascade(series, starts, sizes, q, d_lb,
                                 jnp.asarray(d_F), k=k,
                                 max_leaf=index.max_leaf_size,
                                 strategy=strategy, dist_impl=dist_impl)
        jax.block_until_ready(res.topk_d)
        return res

    levels = [("none", None)] + [("keep%d" % r, r)
                                 for r in (L // 2, L // 8, L // 32, L // 64)]
    rows, payload = [], {"n": n, "m": m, "L": L, "k": k,
                         "n_queries": n_queries, "levels": []}
    for name, keep in levels:
        d_F = (np.full_like(lb_np, -np.inf) if keep is None
               else _rank_threshold_predictions(lb_np, keep))
        rec = {"level": name}
        # "pairwise" = compact with the union-slab all-pairs candidate pass
        # (the l2_scan Pallas kernel path on TPU; same matmul algebra off it)
        plans = (("scan", "scan", None), ("compact", "compact", None),
                 ("pairwise", "compact", "pairwise"))
        for tag, strategy, dist_impl in plans:
            res = run(strategy, d_F, dist_impl)           # warmup / compile
            t0 = time.perf_counter()
            for _ in range(repeat):
                res = run(strategy, d_F, dist_impl)
            dt = (time.perf_counter() - t0) / repeat
            rec[f"{tag}_ms"] = dt * 1e3
            rec[f"{tag}_searched"] = float(
                np.asarray(res.n_searched).mean())
            rec[f"{tag}_computed"] = float(
                np.asarray(res.n_computed).mean())
        rec["pruning_ratio"] = 1.0 - rec["compact_searched"] / L
        rec["speedup"] = rec["scan_ms"] / max(rec["compact_ms"], 1e-12)
        rec["speedup_pairwise"] = rec["scan_ms"] / max(rec["pairwise_ms"],
                                                       1e-12)
        payload["levels"].append(rec)
        rows.append(common.csv_line(
            f"engine/{name}", rec["compact_ms"] * 1e3,
            f"prune={rec['pruning_ratio']:.3f};"
            f"scan={rec['scan_ms']:.1f}ms;"
            f"compact={rec['compact_ms']:.1f}ms;"
            f"pairwise={rec['pairwise_ms']:.1f}ms;"
            f"speedup={rec['speedup']:.2f}x"))
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/engine_bench.json")
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--queries", type=int, default=32)
    args = ap.parse_args()
    rows, payload = bench_engine(n=args.n, n_queries=args.queries)
    common.write_suite_payload(rows, payload, args.out)


if __name__ == "__main__":
    main()
