"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle vs XLA fallback.

On this CPU container the Pallas kernels run in interpret mode, so absolute
numbers measure the *oracle* path; the kernel's VMEM-tiling quality is
assessed structurally in EXPERIMENTS.md §Perf (block shapes vs v5e VMEM),
not by wall-clock here.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.filter_mlp import ref as mlp_ref
from repro.kernels.l2_scan import ref as l2_ref
from . import common


def bench_kernels() -> Tuple[List[str], Dict]:
    rng = np.random.default_rng(0)
    rows, payload = [], {}

    Q, B, m = 64, 8192, 256
    q = jnp.asarray(rng.standard_normal((Q, m)), jnp.float32)
    s = jnp.asarray(rng.standard_normal((B, m)), jnp.float32)
    ref_fn = jax.jit(l2_ref.pairwise_l2_matmul)
    _, t_ref = common.timed(lambda: ref_fn(q, s).block_until_ready(), repeat=5)
    flops = 2 * Q * B * m
    payload["l2_scan"] = {"oracle_s": t_ref, "gflops": flops / t_ref / 1e9}
    rows.append(common.csv_line("kernel/l2_scan_oracle", t_ref * 1e6,
                                f"gflops={flops / t_ref / 1e9:.1f}"))

    F, h = 512, 256
    w1 = jnp.asarray(rng.standard_normal((F, m, h)) * 0.1, jnp.float32)
    b1 = jnp.zeros((F, h)); w2 = jnp.asarray(rng.standard_normal((F, h)), jnp.float32)
    b2 = jnp.zeros((F,))
    ref2 = jax.jit(mlp_ref.filter_predict)
    _, t2 = common.timed(lambda: ref2(w1, b1, w2, b2, q).block_until_ready(),
                         repeat=3)
    per_pair = t2 / (F * Q)
    payload["filter_mlp"] = {"oracle_s": t2, "us_per_pair": per_pair * 1e6}
    rows.append(common.csv_line("kernel/filter_mlp_oracle", t2 * 1e6,
                                f"us_per_filterquery={per_pair*1e6:.2f}"))

    L, d = 4096, 16
    lo = jnp.asarray(rng.standard_normal((L, d)) - 1, jnp.float32)
    hi = lo + 2.0
    qq = jnp.asarray(rng.standard_normal((Q, d)), jnp.float32)
    from repro.kernels.box_lb import ref as box_ref
    ref3 = jax.jit(box_ref.box_lb)
    _, t3 = common.timed(lambda: ref3(qq, lo, hi).block_until_ready(),
                         repeat=5)
    payload["box_lb"] = {"oracle_s": t3,
                         "gbounds_per_s": Q * L / t3 / 1e9}
    rows.append(common.csv_line("kernel/box_lb_oracle", t3 * 1e6,
                                f"bounds_per_s={Q*L/t3/1e6:.1f}M"))
    return rows, payload
