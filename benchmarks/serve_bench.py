"""Serving-runtime benchmark: micro-batched mixed-target open-loop traffic.

Measures what the serving subsystem claims: the dynamic micro-batcher
sustains heterogeneous traffic — mixed per-query quality targets arriving
open-loop (Poisson) — at throughput comparable to the homogeneous
one-target batch path, while hitting each group's requested recall.

Methodology (per engine strategy, scan vs compact):

* **homogeneous baseline** — one full batch per target, timed hot, combined
  at the trace's target mix (uniform): the pre-serving path, where every
  batch shares one ``(L,)`` offset vector.  Weighting matters — a 0.99
  batch genuinely does more work than a 0.9 one, so comparing mixed traffic
  against a single mid-target batch would misread workload as overhead.
* **fixed-schedule replay** — the mixed trace drives the batcher under a
  deterministic service-time *model* (a fixed per-bucket cost), so the
  batch schedule is identical across passes; pass 1 warms exactly the
  programs the schedule needs (the compact strategy's survivor-count
  buckets depend on live batch composition, so no static warmup can reach
  them all), pass 2 measures real per-batch wall-clock, and the schedule is
  then replayed against those measured costs for honest latency/throughput
  (back-to-back service, idle only when the queue is empty).
* two load points: **saturating** (arrivals at ~3× capacity — measured
  throughput is capacity, and p50/p95/p99 are queueing-dominated) and
  **sustained** (~0.7× capacity, real clock — the SLO-flavoured latency
  numbers).

The headline throughput ratio compares *steady-state full batches* (total
valid requests / total wall over full-bucket batches) against the
homogeneous baseline: ramp-up partial batches are a property of trace
length, not of the batcher, and full-batch cost is the apples-to-apples
unit this machine can time reproducibly.  The makespan-based number
(ramp included) is reported alongside.

Reported per strategy: homogeneous vs mixed throughput (acceptance:
within 1.2×), latency percentiles at both load points, padding waste,
pruning ratio, per-target-group achieved recall against the cached
exact-NN oracle, and the telemetry-suggested ``max_survivors`` capacity
with its observed overflow fraction.

On top of the strategy comparison, a **pipeline sweep** (k=1) crosses
serving depth {serial, 1 in flight} × strategy {scan, compact} × executor
{single-host engine, shard_map distributed} under the same fixed-schedule
replay.  Pipelined passes derive per-batch costs from *inter-harvest gaps*
(``t_done[i] − t_done[i−1]``; dispatch of batch N+1 overlaps execution of
batch N, so the gap — not the submit wall — is what a saturated server
pays per batch), and the headline is the saturated-p99 over sustained-p99
ratio per cell: overlap raises capacity, so the overload queue drains
faster and tail latency approaches the sustained profile.

    PYTHONPATH=src python -m benchmarks.serve_bench \
        --out experiments/serve_bench.json
    PYTHONPATH=src python -m benchmarks.serve_bench --quick
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving import (DistributedExecutor, MicroBatcher, ServingSession,
                           Telemetry, poisson_trace, run_trace)

from . import common

TARGETS = (0.9, 0.95, 0.99)


def _homogeneous_qps(session: ServingSession, pool: np.ndarray,
                     batch: int, k: int) -> Tuple[float, Dict[float, float]]:
    """Queries/s of the one-target-per-batch path at the trace's target mix."""
    q = pool[np.arange(batch) % len(pool)]
    per_target = {}
    for t in TARGETS:
        _, dt = common.timed(
            lambda t=t: session.search(q, quality_targets=np.full(batch, t),
                                       k=k, record=False).dists,
            repeat=3)
        per_target[t] = dt
    qps = batch / float(np.mean(list(per_target.values())))
    return qps, {t: dt * 1e3 for t, dt in per_target.items()}


def _replay(trace, batch_log,
            costs: Optional[Sequence[float]] = None
            ) -> Tuple[np.ndarray, float]:
    """Replay a fixed batch schedule against measured per-batch costs.

    The schedule (composition + order) came from the deterministic model
    clock; execution is back-to-back except when the server outpaces
    arrivals.  ``costs`` defaults to the measured ``wall`` seconds (serial
    execution); pipelined runs pass inter-harvest gaps instead.  Returns
    (per-request latencies, makespan)."""
    arrival = {r.rid: r.arrival for r in trace}
    if costs is None:
        costs = [b["wall"] for b in batch_log]
    finish, lat = 0.0, []
    for b, c in zip(batch_log, costs):
        arr = [arrival[rid] for rid in b["rids"]]
        finish = max(finish, max(arr)) + c
        lat += [finish - a for a in arr]
    return np.asarray(lat), finish - min(arrival.values())


def _pipelined_costs(batch_log) -> List[float]:
    """Per-batch cost of a pipelined pass: inter-harvest gaps.

    Harvests retire in FIFO dispatch order, so ``t_done`` is monotone over
    the log; the gap between consecutive harvests is what a saturated
    pipelined server pays per batch (submit + any residual device wait
    beyond the overlap).  The first batch pays its full dispatch→done
    span — there is nothing to hide it behind."""
    costs = []
    prev = None
    for b in batch_log:
        start = b["t_disp"] if prev is None else prev
        costs.append(max(b["t_done"] - start, 0.0))
        prev = b["t_done"]
    return costs


def _serve_fixed_schedule(session: ServingSession, trace, *, batch: int,
                          max_wait: float, model_batch_s: float,
                          oracle) -> Tuple[dict, np.ndarray, float]:
    """Two passes over the model-clock schedule: warm, then measure."""
    def model(b):
        return model_batch_s * max(b.bucket / batch, 0.25)

    for _ in range(2):
        session.telemetry = Telemetry()
        report = session.serve(
            trace, batcher=MicroBatcher(max_batch=batch, max_wait=max_wait),
            recall_oracle=oracle, service_time=model)
    lat, makespan = _replay(trace, report["batches"])
    return report, lat, makespan


def _pipeline_pass(session: ServingSession, trace, *, batch: int,
                   max_wait: float, model_batch_s: float, oracle,
                   depth: int):
    """Two fixed-schedule passes (warm, then measure) at one pipeline depth.

    The warm cache and batch sequence counter reset per pass so both passes
    (and both depths) replay the identical deterministic schedule."""
    def model(b):
        return model_batch_s * max(b.bucket / batch, 0.25)

    report = None
    for _ in range(2):
        session.telemetry = Telemetry()
        session.warm_cache.reset()
        session._seq = 0
        report = session.serve(
            trace, batcher=MicroBatcher(max_batch=batch, max_wait=max_wait),
            recall_oracle=oracle, service_time=model, pipeline=depth)
    costs = (_pipelined_costs(report["batches"]) if depth
             else [b["wall"] for b in report["batches"]])
    lat, makespan = _replay(trace, report["batches"], costs)
    return report, costs, lat, makespan


def bench_pipeline(lfi, pool: np.ndarray, d_nn: np.ndarray, *, batch: int,
                   n_requests: int, max_wait: float, seed: int,
                   execs: Sequence[str] = ("single", "dist"), k: int = 1
                   ) -> Tuple[List[str], Dict]:
    """Depth {serial, 1 in flight} × strategy × executor sweep (k=1).

    Every cell serves the same kind of mixed-target saturating (3× capacity)
    and sustained (0.7×) traces under the fixed-schedule-replay methodology;
    pipelined cells charge inter-harvest gaps instead of serial walls.  The
    per-cell headline is ``p99_sat_over_sustained`` — how far the overload
    tail sits above the steady-state tail.

    The sustained pass stretches the batcher deadline to the batch-fill
    time at its arrival rate (capped at 500 ms): with the saturated pass's
    tight deadline, 0.7× of *full-batch* capacity arrives as near-singleton
    buckets whose per-request cost is up to ``batch/pow2_floor`` higher, so
    the nominally-sustainable rate queue-collapses and the "sustained" tail
    reads worse than the saturated one.  Near-full buckets make the load
    point actually sustainable; the fill wait is part of its latency.
    """
    import jax

    from repro.core import distributed

    rows, out = [], {}
    for exec_mode in execs:
        for strategy in ("scan", "compact"):
            executor = None
            if exec_mode == "dist":
                D = max(len(jax.devices()), 1)
                mesh = distributed.make_search_mesh(1, D)
                executor = DistributedExecutor(lfi, mesh, strategy=strategy)
            session = ServingSession(lfi, strategy=strategy, warm_start=True,
                                     executor=executor)
            session.warmup(max_batch=batch, ks=(k,), queries=pool,
                           targets=TARGETS)
            q = pool[np.arange(batch) % len(pool)]
            t = np.asarray(TARGETS)[np.arange(batch) % len(TARGETS)]
            _, model_batch_s = common.timed(
                lambda: session._search_async(q, t, k).result(), repeat=3)
            homog = batch / model_batch_s

            def make_trace(rate, off):
                tr = poisson_trace(pool, rate=rate, n_requests=n_requests,
                                   targets=TARGETS, ks=(k,),
                                   seed=seed + off)
                return tr, {r.rid: float(d_nn[r.pool_row]) for r in tr}

            trace_hi, oracle_hi = make_trace(3.0 * homog, 0)
            rate_lo = 0.7 * homog
            trace_lo, oracle_lo = make_trace(rate_lo, 1)
            wait_lo = max(max_wait, min(0.5, batch / max(rate_lo, 1e-9)))
            schedules = {}
            for depth in (0, 1):
                rep_hi, costs_hi, lat_hi, mk_hi = _pipeline_pass(
                    session, trace_hi, batch=batch, max_wait=max_wait,
                    model_batch_s=model_batch_s, oracle=oracle_hi,
                    depth=depth)
                rep_lo, costs_lo, lat_lo, mk_lo = _pipeline_pass(
                    session, trace_lo, batch=batch, max_wait=wait_lo,
                    model_batch_s=model_batch_s, oracle=oracle_lo,
                    depth=depth)
                full = [i for i, b in enumerate(rep_hi["batches"])
                        if b["n_valid"] == batch]
                cap = ((sum(rep_hi["batches"][i]["n_valid"] for i in full)
                        / sum(costs_hi[i] for i in full)) if full
                       else n_requests / max(mk_hi, 1e-12))
                pct_hi = common.latency_percentiles(lat_hi * 1e3)
                pct_lo = common.latency_percentiles(lat_lo * 1e3)
                ratio = pct_hi["p99"] / max(pct_lo["p99"], 1e-9)
                name = "serial" if depth == 0 else f"pipe{depth}"
                key = f"{exec_mode}/{strategy}/{name}"
                schedules[depth] = [
                    (b["bucket"], b["k"], tuple(b["rids"]))
                    for b in rep_hi["batches"]]
                out[key] = {
                    "model_batch_ms": model_batch_s * 1e3,
                    "capacity_qps": cap,
                    "saturated_latency_ms": pct_hi,
                    "sustained_latency_ms": pct_lo,
                    "p99_sat_over_sustained": ratio,
                    "saturated_makespan_s": mk_hi,
                    "sustained_makespan_s": mk_lo,
                    "sustained_max_wait_ms": wait_lo * 1e3,
                    "n_batches": rep_hi["n_batches"],
                    "recall_by_target": rep_lo["recall_by_target"],
                }
                rows.append(common.csv_line(
                    f"serve-pipe/{key}", pct_hi["p99"],
                    f"cap={cap:.1f}qps;"
                    f"sat_p99={pct_hi['p99']:.1f}ms;"
                    f"sus_p99={pct_lo['p99']:.1f}ms;"
                    f"ratio={ratio:.2f}"))
            out[f"{exec_mode}/{strategy}/schedule_identical"] = \
                schedules[0] == schedules[1]
    return rows, out


def bench_serve(dataset: str = "randwalk", backbone: str = "dstree",
                batch: int = 32, k: int = 5, n_requests: int = 512,
                max_wait_ms: float = 10.0, seed: int = 0,
                quick: bool = False) -> Tuple[List[str], Dict]:
    setup = common.get_setup(dataset, backbone)
    lfi = setup.lfi
    pool = setup.queries[0.3]                         # (Q, m) query pool
    d_nn = setup.d_L[0.3].min(axis=1)                 # exact oracle, cached
    # the batcher floors max_batch to a power of two; match it here so the
    # homogeneous baseline and the full-batch filter time the same bucket
    batch = 1 << (max(int(batch), 1).bit_length() - 1)

    rows, payload = [], {"dataset": dataset, "backbone": backbone,
                         "batch": batch, "k": k, "n_requests": n_requests,
                         "targets": list(TARGETS),
                         "max_wait_ms": max_wait_ms, "quick": quick,
                         "strategies": {}}
    if quick:
        # --quick: pipeline sweep only, single-host, small trace — the
        # CI-sized smoke of the serving pipeline (make bench-serve-quick)
        prows, payload["pipeline"] = bench_pipeline(
            lfi, pool, d_nn, batch=batch, n_requests=n_requests,
            max_wait=max_wait_ms / 1e3, seed=seed, execs=("single",))
        return prows, payload
    for strategy in ("scan", "compact"):
        session = ServingSession(lfi, strategy=strategy)
        session.warmup(max_batch=batch, ks=(k,), queries=pool,
                       targets=TARGETS)
        homog, per_target_ms = _homogeneous_qps(session, pool, batch, k)
        model_batch_s = batch / homog

        def make_trace(rate, seed_off):
            tr = poisson_trace(pool, rate=rate, n_requests=n_requests,
                               targets=TARGETS, ks=(k,), seed=seed + seed_off)
            return tr, {r.rid: float(d_nn[r.pool_row]) for r in tr}

        # saturating load: throughput is capacity, not offered rate
        trace_hi, oracle_hi = make_trace(3.0 * homog, 0)
        report, lat_hi, makespan = _serve_fixed_schedule(
            session, trace_hi, batch=batch, max_wait=max_wait_ms / 1e3,
            model_batch_s=model_batch_s, oracle=oracle_hi)
        mixed_makespan = n_requests / makespan
        full = [b for b in report["batches"] if b["n_valid"] == batch]
        mixed = (sum(b["n_valid"] for b in full) /
                 sum(b["wall"] for b in full)) if full else mixed_makespan
        pct_hi = common.latency_percentiles(lat_hi * 1e3)

        # sustained load, real clock: the SLO-flavoured latency profile
        # (one soak pass eats composition-dependent compiles, then measure)
        trace_lo, oracle_lo = make_trace(0.7 * homog, 1)
        for _ in range(2):
            session.telemetry = Telemetry()
            report_lo = session.serve(
                trace_lo, batcher=MicroBatcher(max_batch=batch,
                                               max_wait=max_wait_ms / 1e3),
                recall_oracle=oracle_lo)
        pct_lo = {p: report_lo[p] * 1e3 for p in ("p50", "p95", "p99")}

        surv = np.asarray(session.telemetry.survivors)
        cap = session.telemetry.suggest_max_survivors()
        rec = {
            "homogeneous_qps": homog,
            "homogeneous_batch_ms_per_target": per_target_ms,
            "mixed_qps": mixed,
            "mixed_qps_makespan": mixed_makespan,
            "homog_over_mixed": homog / max(mixed, 1e-12),
            "saturated_latency_ms": pct_hi,
            "sustained_latency_ms": pct_lo,
            "n_batches": report["n_batches"],
            "padding_fraction": report["padding_fraction"],
            "pruning_ratio": report["pruning_ratio"],
            "recall_by_target": report["recall_by_target"],
            "suggested_max_survivors": int(cap),
            "survivor_overflow_fraction": float((surv > cap).mean())
            if surv.size else 0.0,
        }
        payload["strategies"][strategy] = rec
        recall_txt = ";".join(
            f"r@{t}={v['recall']:.3f}"
            for t, v in report["recall_by_target"].items())
        rows.append(common.csv_line(
            f"serve/{strategy}", pct_lo["p50"] * 1e3,
            f"homog={homog:.1f}qps;mixed={mixed:.1f}qps;"
            f"ratio={rec['homog_over_mixed']:.2f};"
            f"p50={pct_lo['p50']:.0f}ms;p95={pct_lo['p95']:.0f}ms;"
            f"p99={pct_lo['p99']:.0f}ms;{recall_txt}"))

    prows, payload["pipeline"] = bench_pipeline(
        lfi, pool, d_nn, batch=batch, n_requests=n_requests,
        max_wait=max_wait_ms / 1e3, seed=seed)
    rows += prows
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="suite payload path (default "
                         "experiments/serve_bench.json, or "
                         "experiments/serve_bench_quick.json with --quick)")
    ap.add_argument("--dataset", default="randwalk")
    ap.add_argument("--backbone", default="dstree")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--quick", action="store_true",
                    help="small single-host pipeline sweep only (CI smoke)")
    args = ap.parse_args()
    if args.quick:
        args.batch = min(args.batch, 16)
        args.requests = min(args.requests, 160)
    out = args.out or ("experiments/serve_bench_quick.json" if args.quick
                       else "experiments/serve_bench.json")
    rows, payload = bench_serve(dataset=args.dataset, backbone=args.backbone,
                                batch=args.batch, n_requests=args.requests,
                                quick=args.quick)
    common.write_suite_payload(rows, payload, out)


if __name__ == "__main__":
    main()
