"""Distributed scan-vs-compact shard-body wall-clock across pruning ratios.

The distributed engine's claim mirrors the single-device one: the per-shard
compute term should scale with (1 − pruning ratio) instead of staying
O(local leaves).  This benchmark pins that on a 1×N host-device mesh: one
leaf-sharded index, one query batch, and a sweep of filter aggressiveness
levels; at each level both shard strategies — ``"scan"`` (masked bsf scan
over every local leaf) and ``"compact"`` (fixed-width survivor compaction,
``engine.compact_bsf_cascade``) — answer the same two-phase exchange, and we
record wall-clock, the psum'd searched-leaf total, and their bitwise parity.

Pruning is controlled synthetically (as in ``engine_bench``): filter slots
are zeroed so the stacked-MLP prediction collapses to its bias, and the bias
of every leaf outside the globally best ``keep`` fraction (ranked by mean
box lower bound over the query batch) is set huge — those leaves
filter-prune at any finite bsf.  The compact strategy's static survivor
capacity is sized per level from the kept-per-shard maximum, the same
statistic a deployment would tune it from.

The sweep runs in a subprocess so the forced host-device count never leaks
into (or collides with) the parent's already-initialized jax runtime — the
same isolation trick tests/test_distributed.py uses.

    PYTHONPATH=src python -m benchmarks.dist_bench \
        --out experiments/dist_bench.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Tuple

KEEP_FRACTIONS = (1.0, 0.5, 0.25, 0.1, 0.05, 0.02)


def _child(args) -> Dict:
    """The measured sweep; runs with the forced host-device count active."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import build, distributed, tree
    from repro.data.series import make_query_set

    D = args.devices
    rng = np.random.default_rng(1)
    S = rng.standard_normal((args.n, args.m), dtype=np.float32).cumsum(axis=1)
    index = tree.build_dstree(S, leaf_capacity=args.leaf_capacity)
    lfi = build.LeaFiIndex(index, None, np.empty(0, np.int64), None,
                           build.LeaFiConfig(), {})
    sharded = distributed.shard_leafi(lfi, n_shards=D)
    # shrink the (all-zero) filter slots to a realistic small hidden dim so
    # the stacked-MLP prediction einsum doesn't dominate both strategies
    h = 8
    sharded.w1 = sharded.w1[..., :h]
    sharded.b1 = sharded.b1[..., :h]
    sharded.w2 = sharded.w2[..., :h]

    queries = jnp.asarray(make_query_set(S, args.queries, noise=0.3, seed=7))
    qc = np.asarray(sharded.query_coords(queries))

    # mean box lower bound per (shard, leaf) over the batch — the global
    # promise ranking the keep levels cut on (padding leaves rank last)
    lo, hi = np.asarray(sharded.lb_lo), np.asarray(sharded.lb_hi)
    sizes = np.asarray(sharded.leaf_size)
    d = np.maximum(np.maximum(lo[:, None] - qc[None, :, None],
                              qc[None, :, None] - hi[:, None]), 0.0)
    d = np.where(np.isfinite(d), d, 0.0)
    score = np.sqrt((d * d).sum(-1)).mean(axis=1)        # (S, P)
    valid = sizes > 0
    score = np.where(valid, score, np.inf)
    L_valid = int(valid.sum())
    order = np.argsort(score, axis=None)                 # global flat ranking

    from repro.core.engine import _next_pow2

    mesh = distributed.make_search_mesh(1, D)
    levels = []
    for frac in KEEP_FRACTIONS:
        r = max(int(round(frac * L_valid)), 1)
        keep = np.zeros(score.shape, bool)
        keep.flat[order[:r]] = True
        # pruned leaves: an active zero-filter whose bias (= its prediction)
        # exceeds any finite bsf → filter-pruned in phase 2
        prune = valid & ~keep
        lvl = dataclasses.replace(
            sharded,
            has_filter=jnp.asarray(prune),
            b2=jnp.asarray(np.where(prune, np.float32(1e30), 0.0)))
        # per-query survivors never exceed the kept-per-shard maximum, so
        # this capacity provably avoids the overflow fallback
        cap = _next_pow2(max(int(keep.sum(axis=1).max()), 1))

        rec = {"level": f"keep{r}", "keep_frac": frac, "kept": r,
               "max_survivors": cap}
        outs = {}
        for strategy in ("scan", "compact"):
            # dist_impl="direct" keeps the candidate pass on the scan's
            # distance algebra (it is also the off-TPU default)
            run, *_ = distributed.make_distributed_search(
                mesh, lvl, strategy=strategy, max_survivors=cap,
                dist_impl="direct")
            with mesh:
                nn, tot = run(queries)                   # warmup / compile
                jax.block_until_ready(nn)
                t0 = time.perf_counter()
                for _ in range(args.repeat):
                    nn, tot = run(queries)
                jax.block_until_ready(nn)
                dt = (time.perf_counter() - t0) / args.repeat
            outs[strategy] = (np.asarray(nn), np.asarray(tot))
            rec[f"{strategy}_ms"] = dt * 1e3
            rec[f"{strategy}_searched"] = float(np.asarray(tot).mean())
        # the shard strategies must agree: float tolerance on nn, a small
        # slack on counts (ulp-tied prune decisions can flip between two
        # separately compiled programs — see tests/test_distributed.py)
        np.testing.assert_allclose(outs["compact"][0], outs["scan"][0],
                                   rtol=2e-6, err_msg=str(rec))
        assert np.abs(outs["compact"][1].astype(np.int64)
                      - outs["scan"][1].astype(np.int64)).max() <= 8, rec
        rec["pruning_ratio"] = 1.0 - rec["compact_searched"] / L_valid
        rec["speedup"] = rec["scan_ms"] / max(rec["compact_ms"], 1e-12)
        levels.append(rec)
        print(f"# {rec['level']}: prune={rec['pruning_ratio']:.3f} "
              f"scan={rec['scan_ms']:.1f}ms compact={rec['compact_ms']:.1f}ms "
              f"({rec['speedup']:.2f}x)", file=sys.stderr)

    return {"n": args.n, "m": args.m, "L": L_valid, "n_shards": D,
            "leaf_capacity": args.leaf_capacity, "n_queries": args.queries,
            "levels": levels}


def bench_dist(n: int = 48_000, m: int = 128, leaf_capacity: int = 128,
               n_queries: int = 64, devices: int = 4,
               repeat: int = 3) -> Tuple[List[str], Dict]:
    """Run the sweep in a fresh subprocess with D forced host devices."""
    from . import common

    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}"
                        ).strip()
    cmd = [sys.executable, "-m", "benchmarks.dist_bench", "--run-child",
           "--n", str(n), "--m", str(m),
           "--leaf-capacity", str(leaf_capacity),
           "--queries", str(n_queries), "--devices", str(devices),
           "--repeat", str(repeat)]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=3600)
    if r.returncode != 0:
        raise RuntimeError(
            f"dist_bench child failed:\n{r.stdout[-2000:]}\n{r.stderr[-4000:]}")
    payload = json.loads(r.stdout)
    rows = [common.csv_line(
        f"dist/{rec['level']}", rec["compact_ms"] * 1e3,
        f"prune={rec['pruning_ratio']:.3f};scan={rec['scan_ms']:.1f}ms;"
        f"compact={rec['compact_ms']:.1f}ms;cap={rec['max_survivors']};"
        f"speedup={rec['speedup']:.2f}x")
        for rec in payload["levels"]]
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dist_bench.json")
    ap.add_argument("--run-child", action="store_true",
                    help="internal: run the measured sweep in-process "
                         "(expects XLA_FLAGS already set)")
    ap.add_argument("--n", type=int, default=48_000)
    ap.add_argument("--m", type=int, default=128)
    ap.add_argument("--leaf-capacity", type=int, default=128)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--repeat", type=int, default=3)
    args = ap.parse_args()

    if args.run_child:
        json.dump(_child(args), sys.stdout, default=float)
        return

    from . import common
    rows, payload = bench_dist(
        n=args.n, m=args.m, leaf_capacity=args.leaf_capacity,
        n_queries=args.queries, devices=args.devices, repeat=args.repeat)
    common.write_suite_payload(rows, payload, args.out)


if __name__ == "__main__":
    main()
