"""Traced-vs-untraced cascade throughput: the observability overhead pin.

The cascade trace (``engine.run_cascade(trace=True)``) promises two things:
``trace=False`` compiles to the byte-identical untraced program (so the
default path pays nothing), and ``trace=True`` stays cheap — a few masked
int32 reductions next to the distance compute.  This benchmark pins the
second claim: one index, one query batch, a sweep of synthetic
rank-threshold pruning levels spanning the paper's operating range
(~0.65–0.98 pruning ratio), and at each level both engine strategies run
traced and untraced.  The headline number is the compact path's traced
overhead percentage (LF005 keeps the committed payload fresh; the <5%
budget is asserted by the payload's ``max_compact_overhead_pct``).

    PYTHONPATH=src python -m benchmarks.obs_bench \
        --out experiments/obs_bench.json
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds, engine, tree
from repro.data.series import make_query_set

from . import common
from .engine_bench import _rank_threshold_predictions


def bench_obs(n: int = 20_000, m: int = 128, leaf_capacity: int = 128,
              n_queries: int = 32, k: int = 5,
              repeat: int = 10) -> Tuple[List[str], Dict]:
    rng = np.random.default_rng(1)
    S = rng.standard_normal((n, m), dtype=np.float32).cumsum(axis=1)
    index = tree.build_dstree(S, leaf_capacity=leaf_capacity)
    L = index.n_leaves
    queries = make_query_set(S, n_queries, noise=0.3, seed=7)
    q = jnp.asarray(queries)
    d_lb = bounds.lower_bounds(index, q)
    lb_np = np.asarray(d_lb)
    series = jnp.asarray(index.series)
    starts = jnp.asarray(index.leaf_start)
    sizes = jnp.asarray(index.leaf_size)

    def run(strategy, d_F, trace):
        res = engine.run_cascade(series, starts, sizes, q, d_lb,
                                 jnp.asarray(d_F), k=k,
                                 max_leaf=index.max_leaf_size,
                                 strategy=strategy, trace=trace)
        jax.block_until_ready(res.topk_d)
        return res

    def timed(strategy, d_F, trace):
        res = run(strategy, d_F, trace)            # warmup / compile
        best = float("inf")                        # min-of-repeats: noise-
        for _ in range(repeat):                    # robust overhead pin
            t0 = time.perf_counter()
            res = run(strategy, d_F, trace)
            best = min(best, time.perf_counter() - t0)
        return best, res

    # rank thresholds spanning the paper's pruning operating range
    ratios = (0.65, 0.80, 0.90, 0.98)
    rows, payload = [], {"n": n, "m": m, "L": L, "k": k,
                         "n_queries": n_queries, "repeat": repeat,
                         "levels": []}
    for target in ratios:
        keep = max(int(round(L * (1.0 - target))), 1)
        d_F = _rank_threshold_predictions(lb_np, keep)
        rec = {"target_pruning": target, "keep": keep}
        for strategy in ("scan", "compact"):
            dt_off, res_off = timed(strategy, d_F, trace=False)
            dt_on, res_on = timed(strategy, d_F, trace=True)
            assert np.array_equal(np.asarray(res_off.topk_d),
                                  np.asarray(res_on.topk_d)), strategy
            tr = res_on.trace
            pruned = (np.asarray(tr.pruned_box) + np.asarray(tr.pruned_seed)
                      + np.asarray(tr.pruned_filter))
            assert np.array_equal(
                pruned, L - np.asarray(tr.survivors)
                - np.asarray(tr.probed)), strategy
            rec[f"{strategy}_ms"] = dt_off * 1e3
            rec[f"{strategy}_traced_ms"] = dt_on * 1e3
            rec[f"{strategy}_overhead_pct"] = \
                100.0 * (dt_on - dt_off) / max(dt_off, 1e-12)
        rec["pruning_ratio"] = 1.0 - float(
            np.asarray(res_on.n_searched).mean()) / L
        payload["levels"].append(rec)
        rows.append(common.csv_line(
            f"obs/prune{target:.2f}", rec["compact_traced_ms"] * 1e3,
            f"compact={rec['compact_ms']:.2f}ms;"
            f"traced={rec['compact_traced_ms']:.2f}ms;"
            f"overhead={rec['compact_overhead_pct']:+.1f}%;"
            f"scan_overhead={rec['scan_overhead_pct']:+.1f}%"))
    payload["max_compact_overhead_pct"] = max(
        lv["compact_overhead_pct"] for lv in payload["levels"])
    rows.append(common.csv_line(
        "obs/max_compact_overhead", payload["max_compact_overhead_pct"],
        "budget=5%"))
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/obs_bench.json")
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--repeat", type=int, default=5)
    args = ap.parse_args()
    rows, payload = bench_obs(n=args.n, n_queries=args.queries,
                              repeat=args.repeat)
    common.write_suite_payload(rows, payload, args.out)


if __name__ == "__main__":
    main()
