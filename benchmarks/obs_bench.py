"""Traced/audited-vs-plain cascade throughput: the observability overhead pin.

The cascade trace (``engine.run_cascade(trace=True)``) and the per-leaf
audit (``audit=True``) promise two things: with the flag off the engine
compiles to the byte-identical plain program (so the default path pays
nothing), and with it on the cost stays small — masked int32/f32
reductions next to the distance compute.  This benchmark pins the second
claim: one index, one query batch, a sweep of synthetic rank-threshold
pruning levels spanning the paper's operating range (~0.65–0.98 pruning
ratio), and at each level both engine strategies run plain, traced, and
audited.  The headline numbers are the compact path's traced and audited
overhead percentages (LF005 keeps the committed payload fresh; the <5%
budgets are asserted by the payload's ``max_compact_overhead_pct`` /
``max_compact_audit_overhead_pct``).

A second section sweeps the serving-side **shadow sampler** rate: a small
LeaFi index serves an open-loop trace while a deterministic fraction of
requests is re-executed exactly off the critical path; the shadow-sampled
true recall must agree with the calibration-split estimate within its
binomial confidence interval (the Lernaean-Hydra-style online/offline
consistency check).

    PYTHONPATH=src python -m benchmarks.obs_bench \
        --out experiments/obs_bench.json
    PYTHONPATH=src python -m benchmarks.obs_bench --quick   # CI-sized
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds, engine, tree
from repro.data.series import make_query_set
from repro.obs import audit as obs_audit

from . import common
from .engine_bench import _rank_threshold_predictions


def bench_trace_audit(n: int = 20_000, m: int = 128,
                      leaf_capacity: int = 128, n_queries: int = 32,
                      k: int = 5, repeat: int = 10) -> Tuple[List[str], Dict]:
    rng = np.random.default_rng(1)
    S = rng.standard_normal((n, m), dtype=np.float32).cumsum(axis=1)
    index = tree.build_dstree(S, leaf_capacity=leaf_capacity)
    L = index.n_leaves
    queries = make_query_set(S, n_queries, noise=0.3, seed=7)
    q = jnp.asarray(queries)
    d_lb = bounds.lower_bounds(index, q)
    lb_np = np.asarray(d_lb)
    series = jnp.asarray(index.series)
    starts = jnp.asarray(index.leaf_start)
    sizes = jnp.asarray(index.leaf_size)

    def run(strategy, d_F, trace=False, audit=False):
        res = engine.run_cascade(series, starts, sizes, q, d_lb,
                                 jnp.asarray(d_F), k=k,
                                 max_leaf=index.max_leaf_size,
                                 strategy=strategy, trace=trace,
                                 audit=audit)
        jax.block_until_ready(res.topk_d)
        return res

    def timed(strategy, d_F, *flag_sets):
        """Round-robin timing across flag variants.

        Each repeat runs every variant back-to-back, so a transient load
        burst on the host inflates all variants equally instead of
        corrupting one variant's whole block — the per-variant minima
        stay comparable, which is what the overhead ratios need.
        """
        results = [run(strategy, d_F, **fl) for fl in flag_sets]  # compile
        best = [float("inf")] * len(flag_sets)
        for _ in range(repeat):
            for i, fl in enumerate(flag_sets):
                t0 = time.perf_counter()
                results[i] = run(strategy, d_F, **fl)
                best[i] = min(best[i], time.perf_counter() - t0)
        return best, results

    # rank thresholds spanning the paper's pruning operating range
    ratios = (0.65, 0.80, 0.90, 0.98)
    rows, payload = [], {"n": n, "m": m, "L": L, "k": k,
                         "n_queries": n_queries, "repeat": repeat,
                         "levels": []}
    for target in ratios:
        keep = max(int(round(L * (1.0 - target))), 1)
        d_F = _rank_threshold_predictions(lb_np, keep)
        rec = {"target_pruning": target, "keep": keep}
        for strategy in ("scan", "compact"):
            (dt_off, dt_on, dt_audit), (res_off, res_on, res_a) = timed(
                strategy, d_F, {}, {"trace": True}, {"audit": True})
            assert np.array_equal(np.asarray(res_off.topk_d),
                                  np.asarray(res_on.topk_d)), strategy
            assert np.array_equal(np.asarray(res_off.topk_d),
                                  np.asarray(res_a.topk_d)), strategy
            tr = res_on.trace
            pruned = (np.asarray(tr.pruned_box) + np.asarray(tr.pruned_seed)
                      + np.asarray(tr.pruned_filter))
            assert np.array_equal(
                pruned, L - np.asarray(tr.survivors)
                - np.asarray(tr.probed)), strategy
            assert not np.asarray(obs_audit.accounting_residual_leaf(
                res_a.audit, n_queries)).any(), strategy
            rec[f"{strategy}_ms"] = dt_off * 1e3
            rec[f"{strategy}_traced_ms"] = dt_on * 1e3
            rec[f"{strategy}_audited_ms"] = dt_audit * 1e3
            rec[f"{strategy}_overhead_pct"] = \
                100.0 * (dt_on - dt_off) / max(dt_off, 1e-12)
            rec[f"{strategy}_audit_overhead_pct"] = \
                100.0 * (dt_audit - dt_off) / max(dt_off, 1e-12)
        rec["pruning_ratio"] = 1.0 - float(
            np.asarray(res_on.n_searched).mean()) / L
        payload["levels"].append(rec)
        rows.append(common.csv_line(
            f"obs/prune{target:.2f}", rec["compact_traced_ms"] * 1e3,
            f"compact={rec['compact_ms']:.2f}ms;"
            f"traced={rec['compact_traced_ms']:.2f}ms;"
            f"audited={rec['compact_audited_ms']:.2f}ms;"
            f"overhead={rec['compact_overhead_pct']:+.1f}%;"
            f"audit_overhead={rec['compact_audit_overhead_pct']:+.1f}%;"
            f"scan_overhead={rec['scan_overhead_pct']:+.1f}%"))
    payload["max_compact_overhead_pct"] = max(
        lv["compact_overhead_pct"] for lv in payload["levels"])
    payload["max_compact_audit_overhead_pct"] = max(
        lv["compact_audit_overhead_pct"] for lv in payload["levels"])
    rows.append(common.csv_line(
        "obs/max_compact_overhead", payload["max_compact_overhead_pct"],
        "budget=5%"))
    rows.append(common.csv_line(
        "obs/max_compact_audit_overhead",
        payload["max_compact_audit_overhead_pct"], "budget=5%"))
    return rows, payload


def bench_shadow(n: int = 8_000, m: int = 96, leaf_capacity: int = 128,
                 n_requests: int = 96, batch: int = 16, epochs: int = 15,
                 target: float = 0.95,
                 rates: Tuple[float, ...] = (0.1, 0.25, 0.5, 1.0),
                 ci_slack: float = 0.05) -> Tuple[List[str], Dict]:
    """Shadow-rate sweep: online true recall vs the calibration estimate.

    At every rate the same trace is served; the shadow-sampled true-recall
    estimate must land within the binomial CI (plus ``ci_slack`` for the
    finite calibration split itself) of the calibration-split estimate
    ``min(target, calib_best_quality)``.  ``rate=1.0`` shadows everything,
    so its estimate *is* the trace's true recall.
    """
    from repro.core import build, filter_training
    from repro.serving import MicroBatcher, ServingSession, poisson_trace

    rng = np.random.default_rng(11)
    S = rng.standard_normal((n, m), dtype=np.float32).cumsum(axis=1)
    lfi = build.build_leafi(S, build.LeaFiConfig(
        backbone="dstree", leaf_capacity=leaf_capacity, n_global=60,
        n_local=20, t_filter_over_t_series=20.0,
        train=filter_training.TrainConfig(epochs=epochs)))
    calib_est = min(float(target),
                    float(lfi.build_report.get("calib_best_quality", 1.0)))
    pool = make_query_set(S, 64, noise=0.3, seed=13)
    rows: List[str] = []
    payload = {"n": n, "m": m, "n_requests": n_requests, "target": target,
               "calib_estimate": calib_est, "ci_slack": ci_slack,
               "rates": []}
    for rate in rates:
        session = ServingSession(lfi, audit=True, shadow_rate=rate,
                                 shadow_seed=5)
        trace = poisson_trace(pool, rate=500.0, n_requests=n_requests,
                              targets=(target,), ks=(1,), seed=17)
        t0 = time.perf_counter()
        report = session.serve(trace,
                               batcher=MicroBatcher(max_batch=batch))
        serve_s = time.perf_counter() - t0
        sh = report.get("shadow", {"n_shadowed": 0,
                                   "recall_mean": float("nan"),
                                   "misses": []})
        n_sh = sh["n_shadowed"]
        ci = (1.96 * np.sqrt(calib_est * (1.0 - calib_est) / n_sh)
              if n_sh else float("inf"))
        agrees = (not n_sh or
                  abs(sh["recall_mean"] - calib_est) <= ci + ci_slack)
        assert agrees, (
            f"shadow recall {sh['recall_mean']:.3f} vs calibration "
            f"estimate {calib_est:.3f} outside CI±slack "
            f"({ci:.3f}+{ci_slack})")
        flagged = session.telemetry.filters_needing_attention()
        rec = {"rate": rate, "n_shadowed": n_sh,
               "shadow_recall": sh["recall_mean"],
               "n_misses": len(sh["misses"]),
               "binomial_ci": ci, "agrees_with_calib": bool(agrees),
               "n_flagged_leaves": len(flagged), "serve_s": serve_s}
        payload["rates"].append(rec)
        rows.append(common.csv_line(
            f"obs/shadow{rate:.2f}", sh["recall_mean"],
            f"n_shadowed={n_sh};misses={rec['n_misses']};"
            f"calib={calib_est:.3f};ci={ci:.3f};"
            f"flagged={rec['n_flagged_leaves']}"))
    return rows, payload


def bench_obs(quick: bool = False) -> Tuple[List[str], Dict]:
    """The full obs suite: overhead pins + the shadow-rate sweep."""
    if quick:
        rows, payload = bench_trace_audit(n=6_000, n_queries=16, repeat=3)
        sh_rows, sh_payload = bench_shadow(n=3_000, n_requests=48,
                                           epochs=5, rates=(0.25, 1.0))
    else:
        rows, payload = bench_trace_audit()
        sh_rows, sh_payload = bench_shadow()
    payload["shadow_sweep"] = sh_payload
    return rows + sh_rows, payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (writes experiments/"
                         "obs_bench_quick.json unless --out is given)")
    args = ap.parse_args()
    out = args.out or ("experiments/obs_bench_quick.json" if args.quick
                       else "experiments/obs_bench.json")
    rows, payload = bench_obs(quick=args.quick)
    common.write_suite_payload(rows, payload, out)


if __name__ == "__main__":
    main()
