"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes the full payloads to
experiments/bench_results.json (EXPERIMENTS.md is generated from those).

  PYTHONPATH=src python -m benchmarks.run                  # full sweep
  PYTHONPATH=src python -m benchmarks.run --quick          # randwalk-only
  PYTHONPATH=src python -m benchmarks.run --suite build    # one suite only

Standalone suites (``--suite``) run a single benchmark module and write its
own experiments/ payload: ``build`` → build_bench (batched vs per-leaf
training-data collection), ``engine`` → engine_bench (scan vs compact vs
pairwise cascade execution), ``dist`` → dist_bench (scan vs fixed-width
compact shard bodies on a 1×N host-device mesh), ``serve`` → serve_bench
(micro-batched mixed-quality-target open-loop serving vs the homogeneous
batch path), ``filters`` → filters_bench (per-filter vs fused filter
inference kernels × weight dtype, with the roofline bound pin), ``obs`` →
obs_bench (traced vs untraced cascade throughput across pruning ratios —
the observability overhead pin).
"""
from __future__ import annotations

import argparse
import json
import os
import time

from . import (build_bench, common, dist_bench, engine_bench, filters_bench,
               kernels_bench, obs_bench, paper_tables, serve_bench, wallclock)

SUITES = {
    "build": (build_bench.bench_build, "experiments/build_bench.json"),
    "engine": (engine_bench.bench_engine, "experiments/engine_bench.json"),
    "dist": (dist_bench.bench_dist, "experiments/dist_bench.json"),
    "serve": (serve_bench.bench_serve, "experiments/serve_bench.json"),
    "filters": (filters_bench.bench_filters,
                "experiments/filters_bench.json"),
    "obs": (obs_bench.bench_obs, "experiments/obs_bench.json"),
}


def _run_suite(name: str, out: str | None) -> None:
    fn, default_out = SUITES[name]
    rows, payload = fn()
    common.write_suite_payload(rows, payload, out or default_out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="randwalk-only, skips sweeps")
    ap.add_argument("--datasets", default=None,
                    help="comma-separated subset")
    ap.add_argument("--suite", default=None, choices=sorted(SUITES),
                    help="run one registered suite and exit")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.suite:
        _run_suite(args.suite, args.out)
        return
    args.out = args.out or "experiments/bench_results.json"

    datasets = (args.datasets.split(",") if args.datasets
                else (("randwalk",) if args.quick else common.DATASETS))
    all_rows, payloads = [], {}
    t_start = time.perf_counter()

    for ds in datasets:
        for backbone in ("dstree", "isax"):
            setup = common.get_setup(ds, backbone)
            tag = f"{ds}/{backbone}"
            for fn in (paper_tables.bench_pruning_ratio,
                       paper_tables.bench_query_time,
                       paper_tables.bench_recall_targets,
                       paper_tables.bench_build_time):
                rows, payload = fn(setup)
                all_rows += [r.replace(f"/{ds}/", f"/{tag}/") for r in rows]
                payloads[f"{fn.__name__}/{tag}"] = payload

    if not args.quick:
        for fn, key in ((paper_tables.bench_scalability, "scalability"),
                        (paper_tables.bench_node_threshold, "node_threshold"),
                        (paper_tables.bench_memory_budget, "memory_budget"),
                        (paper_tables.bench_local_data, "local_data")):
            rows, payload = fn()
            all_rows += rows
            payloads[key] = payload

    rows, payload = paper_tables.bench_model_type()
    all_rows += rows
    payloads["model_type"] = payload

    for ds in ("randwalk", "sift") if not args.quick else ("randwalk",):
        setup = common.get_setup(ds, "dstree")
        rows, payload = wallclock.bench_wallclock(setup)
        all_rows += rows
        payloads[f"wallclock/{ds}"] = payload
    # paper-regime leaves (large |N|): where Eq. 4 predicts wall-clock wins
    setup = wallclock.paper_regime_setup("sift" if not args.quick
                                         else "randwalk")
    rows, payload = wallclock.bench_wallclock(setup)
    all_rows += [r.replace("wallclock/", "wallclock_bigleaf/") for r in rows]
    payloads["wallclock_bigleaf"] = payload

    rows, payload = kernels_bench.bench_kernels()
    all_rows += rows
    payloads["kernels"] = payload

    # scan-vs-compact engine wall-clock across pruning ratios
    rows, payload = engine_bench.bench_engine(
        n=10_000 if args.quick else 50_000)
    all_rows += rows
    payloads["engine"] = payload

    for r in all_rows:
        print(r)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payloads, f, indent=1, default=float)
    print(f"# total {time.perf_counter() - t_start:.1f}s "
          f"→ {len(all_rows)} rows → {args.out}")


if __name__ == "__main__":
    main()
